"""§Perf hillclimb driver: three (arch x shape) pairs, baseline vs change,
re-lowered and re-analysed.  Results are appended to
experiments/perf_hillclimb.json and summarized for EXPERIMENTS.md §Perf.

  H1  dbrx-132b  train_4k   (worst useful-FLOPs fraction, compute-bound)
      change: masked dense-expert MoE -> hierarchical batched-scatter
      capacity dispatch (exact ~1.25x-active FLOPs instead of E/k = 4x).
  H2  dbrx-132b  decode_32k (most collective-bound)
      change: int8 serving weights (weight gathers halve).
  H3  gemma2-2b  train_4k   (most representative of the paper's technique:
      the FL round's collectives ARE the paper's TransT/TransL)
      changes: (a) no-SP + 2x microbatches; (b) int8 FSDP all-gathers.

Run INSIDE the 512-device dry-run env:
  PYTHONPATH=src:. python benchmarks/perf_hillclimb.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import json          # noqa: E402
import pathlib       # noqa: E402
import sys           # noqa: E402

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax           # noqa: E402

from repro.configs import get_config                      # noqa: E402
from repro.configs.shapes import get_shape                # noqa: E402
from repro.launch.mesh import make_production_mesh        # noqa: E402
from repro.launch.steps import step_for_shape             # noqa: E402
from repro.roofline.analysis import analyze_compiled      # noqa: E402

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / \
    "perf_hillclimb.json"


def measure(arch, shape_name, label, **kw):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh()
    jit_fn, structs = step_for_shape(cfg, mesh, shape, **kw)
    with mesh:
        compiled = jit_fn.lower(*structs).compile()
    rep = analyze_compiled(compiled, arch=arch, shape=shape_name,
                           mesh="16x16", n_devices=256)
    mem = compiled.memory_analysis()
    rec = {
        "experiment": label, "arch": arch, "shape": shape_name,
        "kwargs": {k: str(v) for k, v in kw.items()},
        "hlo_flops_per_dev": rep.flops,
        "hlo_bytes_per_dev": rep.hbm_bytes,
        "hlo_coll_bytes_per_dev": rep.coll_bytes,
        "coll_breakdown": {k: v for k, v in rep.coll_breakdown.items()
                           if k != "counts"},
        "peak_gib": (mem.temp_size_in_bytes + mem.argument_size_in_bytes
                     + mem.output_size_in_bytes
                     - mem.alias_size_in_bytes) / 2**30,
    }
    print(f"[{label}] flops/dev={rep.flops:.3e} "
          f"coll/dev={rep.coll_bytes / 2**20:.0f}MiB "
          f"peak={rec['peak_gib']:.1f}GiB", flush=True)
    return rec


def main():
    records = []

    # H1: dbrx train — dense-expert vs hierarchical dispatch
    records.append(measure("dbrx-132b", "train_4k", "H1/baseline-dense",
                           microbatches=8, moe_mode="dense"))
    records.append(measure("dbrx-132b", "train_4k", "H1/hierarchical",
                           microbatches=8, moe_mode="hierarchical"))

    # H2: dbrx decode — bf16 vs int8 serving weights
    records.append(measure("dbrx-132b", "decode_32k", "H2/baseline-bf16"))
    records.append(measure("dbrx-132b", "decode_32k", "H2/int8-weights",
                           quantize_weights=True))

    # H3: gemma2 train — SP baseline vs no-SP+microbatch vs int8 gathers
    records.append(measure("gemma2-2b", "train_4k", "H3/baseline-SP"))
    records.append(measure("gemma2-2b", "train_4k", "H3/noSP-mb2",
                           seq_parallel=False, microbatches=2))
    records.append(measure("gemma2-2b", "train_4k", "H3/SP-int8comm",
                           quantize_comm=True))

    OUT.write_text(json.dumps(records, indent=1))
    print(f"\nwrote {OUT}")


if __name__ == "__main__":
    main()
