from repro.optim.optimizers import adagrad, adam, sgd  # noqa: F401
