"""REPRO001 — eager ``jnp`` arithmetic on params/deltas outside jit.

The PR 5 incident class: ``_roundtrip_leaf`` ran ``g * scale`` eagerly
on one engine and under ``jax.jit`` on the other; XLA fuses a
multiply-add into one FMA under jit but eager dispatch executes two
rounded ops, so the two paths produced different bits and broke the
sweep-vs-independent parity pin.  Any arithmetic on model parameters or
update deltas that runs eagerly is one refactor away from that bug, so
in the hot packages (``federated/``, ``runtime/``, ``experiments/``)
every eager param-flavored BinOp — and every arithmetic lambda handed to
``jax.tree.map`` alongside param-flavored arguments — must either move
under jit or carry a justification for why bit-parity tolerates it.
"""

from __future__ import annotations

import ast

from ..core import FileContext, Rule, register
from ..scopes import FuncNode, dotted_parts, final_name

SCOPED_DIRS = {"federated", "runtime", "experiments"}

# snake-case segments that mark a value as model-params/updates flavored
PARAMY = {"params", "param", "delta", "deltas", "theta", "updates",
          "momentum"}
# ...unless a sibling segment says it's a count/size/name, not an array
NOT_ARRAY = {"n", "num", "count", "size", "len", "bytes", "idx", "ord",
             "name", "names", "key", "keys", "shape", "spec", "specs",
             "cfg", "config", "t", "time", "dtype"}

ARITH_OPS = (ast.Add, ast.Sub, ast.Mult, ast.Div, ast.MatMult, ast.Pow)

# operands that make a BinOp host-container or host-scalar math, not
# array math: list/tuple displays (concat/repeat of pytree lists) and
# the values they build from comprehensions
DISPLAY = (ast.List, ast.Tuple, ast.Set, ast.Dict, ast.ListComp,
           ast.SetComp, ast.DictComp, ast.GeneratorExp)
HOST_CASTS = {"float", "int", "len"}


def _segments(name: str):
    return set(name.lower().split("_")) - {""}


def _paramy_name(node: ast.AST):
    """The dotted name if any component looks param-like, else None.
    Subtrees under ``float()``/``int()``/``len()`` are host scalars by
    construction and don't count."""
    skip = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and final_name(sub.func) in HOST_CASTS:
            for inner in ast.walk(sub):
                skip.add(id(inner))
            skip.discard(id(sub))  # keep walking siblings
    for sub in ast.walk(node):
        if id(sub) in skip:
            continue
        if isinstance(sub, (ast.Name, ast.Attribute)):
            parts = dotted_parts(sub)
            segs = set()
            for p in parts:
                segs |= _segments(p)
            if segs & PARAMY and not segs & NOT_ARRAY:
                return ".".join(parts) if parts else None
    return None


def _host_container_math(node: ast.BinOp) -> bool:
    """`[x] * n` / `list + list` / `(m,) + p.shape` — not array math."""
    for side in (node.left, node.right):
        if isinstance(side, DISPLAY):
            return True
        if isinstance(side, ast.Attribute) and side.attr == "shape":
            return True
    return False


def _is_tree_map(func: ast.AST) -> bool:
    name = final_name(func)
    if name == "tree_map":
        return True
    return name == "map" and "tree" in dotted_parts(func)


@register
class EagerParamMath(Rule):
    id = "REPRO001"
    name = "eager-param-math"

    def check_file(self, ctx: FileContext):
        parts = set(ctx.rel.split("/"))
        if not parts & SCOPED_DIRS:
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.BinOp) and isinstance(node.op, ARITH_OPS):
                self._check_binop(ctx, node)
            elif isinstance(node, ast.AugAssign) \
                    and isinstance(node.op, ARITH_OPS):
                self._check_augassign(ctx, node)
            elif isinstance(node, ast.Call) and _is_tree_map(node.func):
                self._check_tree_map(ctx, node)

    def _eager(self, ctx: FileContext, node: ast.AST) -> bool:
        if ctx.in_traced_scope(node):
            return False
        # arithmetic inside a lambda is judged at the tree.map call site
        for anc in ctx.ancestors(node):
            if isinstance(anc, ast.Lambda):
                return False
            if isinstance(anc, FuncNode):
                break
        return True

    def _check_binop(self, ctx: FileContext, node: ast.BinOp):
        if not self._eager(ctx, node) or _host_container_math(node):
            return
        hint = _paramy_name(node.left) or _paramy_name(node.right)
        if hint:
            ctx.add(node, self.id,
                    f"eager arithmetic on param-like value '{hint}' outside "
                    "a jitted scope — eager-vs-jit FMA contraction breaks "
                    "bit-parity (jit the op or justify-suppress)")

    def _check_augassign(self, ctx: FileContext, node: ast.AugAssign):
        if not self._eager(ctx, node):
            return
        hint = _paramy_name(node.target) or _paramy_name(node.value)
        if hint:
            ctx.add(node, self.id,
                    f"eager augmented arithmetic on param-like value "
                    f"'{hint}' outside a jitted scope — eager-vs-jit FMA "
                    "contraction breaks bit-parity")

    def _check_tree_map(self, ctx: FileContext, node: ast.Call):
        if ctx.in_traced_scope(node):
            return
        lam = next((a for a in node.args if isinstance(a, ast.Lambda)), None)
        if lam is None:
            return
        has_arith = any(
            isinstance(sub, ast.BinOp) and isinstance(sub.op, ARITH_OPS)
            and not _host_container_math(sub)
            for sub in ast.walk(lam.body))
        if not has_arith:
            return
        hint = None
        for arg in node.args:
            if arg is not lam:
                hint = _paramy_name(arg)
                if hint:
                    break
        if hint:
            ctx.add(node, self.id,
                    f"eager tree.map arithmetic over param-like value "
                    f"'{hint}' outside a jitted scope — eager-vs-jit FMA "
                    "contraction breaks bit-parity")
