"""Sweep orchestration: whole populations of FL trials as one workload.

``grid``   — TrialSpec/SweepSpec product grids with eager validation.
``runner`` — sequential and vectorized (trials-as-an-axis) execution.
``store``  — append-only JSONL results, resume keys, paper-style tables.
"""

from repro.experiments.grid import (CANONICAL_PREFERENCE,  # noqa: F401
                                    SweepSpec, TrialSpec, parse_preferences,
                                    spec_from_dict)
from repro.experiments.runner import (TrialResult, build_server,  # noqa: F401
                                      run_sweep, run_trial, run_vectorized)
from repro.experiments.store import (ResultStore,  # noqa: F401
                                     aggregate_over_seeds, improvement_pct,
                                     pair_with_baselines, paper_table)
