"""BAD fixture: hash-ordered iteration feeding order-sensitive sinks.

Dict views and sets iterate in hash/insertion order; pushing events or
drawing from an rng inside such a loop makes results depend on that
order.  REPRO002 must fire on both loops.
"""


def schedule(events_by_trial, queue):
    for _trial, evs in events_by_trial.items():   # REPRO002: queue push
        for ev in evs:
            queue.push(ev)


def jitter(cids, rng):
    for cid in set(cids):                         # REPRO002: rng draw
        yield cid, rng.uniform()
