"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory with recurrent gate connections), per arXiv:2405.04517.

Both use exponential gating with the max-stabilizer m_t.  Training/prefill
runs a ``lax.scan`` over time (one traced step -> compact HLO); decode carries
the recurrent state explicitly.  State is O(1) in sequence length, which is
what makes the ssm family native for the long_500k shape.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init
from repro.sharding.ctx import logical_constraint


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm_params(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    w = int(d * cfg.xlstm_proj_factor)
    h = cfg.n_heads
    hd = w // h
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, w), dtype),
        "w_z": dense_init(ks[1], (d, w), dtype),
        "conv_w": dense_init(ks[2], (4, w), dtype, fan_in=4),
        "conv_b": jnp.zeros((w,), dtype),
        "w_q": dense_init(ks[3], (h, hd, hd), dtype, fan_in=hd),
        "w_k": dense_init(ks[4], (h, hd, hd), dtype, fan_in=hd),
        "w_v": dense_init(ks[5], (h, hd, hd), dtype, fan_in=hd),
        "w_i": dense_init(ks[6], (w, h), dtype),
        "w_f": dense_init(ks[7], (w, h), dtype),
        "b_i": jnp.zeros((h,), dtype),
        "b_f": jnp.full((h,), 3.0, dtype),  # forget-gate bias: remember early
        "w_down": dense_init(jax.random.fold_in(key, 99), (w, d), dtype),
    }


class MLSTMState(NamedTuple):
    C: jax.Array   # (B, H, hd, hd)
    n: jax.Array   # (B, H, hd)
    m: jax.Array   # (B, H)
    conv_tail: jax.Array  # (B, 3, W)


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> MLSTMState:
    w = int(cfg.d_model * cfg.xlstm_proj_factor)
    h = cfg.n_heads
    hd = w // h
    return MLSTMState(
        C=jnp.zeros((batch, h, hd, hd), jnp.float32),
        n=jnp.zeros((batch, h, hd), jnp.float32),
        m=jnp.full((batch, h), -jnp.inf, jnp.float32),
        conv_tail=jnp.zeros((batch, 3, w), dtype),
    )


def _causal_conv(x, conv_w, conv_b):
    cw = conv_w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(cw):
        shifted = x if i == 0 else jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * conv_w[cw - 1 - i]
    return out + conv_b


def _mlstm_qkvif(params, x, h, hd):
    """Shared projections. x: (B,S,d) -> q,k,v:(B,S,H,hd); i,f:(B,S,H)."""
    xu = jnp.einsum("bsd,dw->bsw", x, params["w_up"])
    xu = logical_constraint(xu, ("batch", None, "ff"))
    xc = jax.nn.silu(_causal_conv(xu, params["conv_w"], params["conv_b"]))
    b, s, w = xc.shape
    xh = xc.reshape(b, s, h, hd)
    q = jnp.einsum("bshd,hde->bshe", xh, params["w_q"])
    k = jnp.einsum("bshd,hde->bshe", xh, params["w_k"]) * (hd ** -0.5)
    v = jnp.einsum("bshd,hde->bshe", xh, params["w_v"])
    i_pre = jnp.einsum("bsw,wh->bsh", xc, params["w_i"]) + params["b_i"]
    f_pre = jnp.einsum("bsw,wh->bsh", xc, params["w_f"]) + params["b_f"]
    z = jax.nn.silu(jnp.einsum("bsd,dw->bsw", x, params["w_z"]))
    return q, k, v, i_pre.astype(jnp.float32), f_pre.astype(jnp.float32), z


def _mlstm_step(carry, inp):
    C, n, m = carry
    q, k, v, i_pre, f_pre = inp           # (B,H,hd) x3, (B,H) x2
    logf = -jax.nn.softplus(-f_pre)       # log sigmoid(f)
    m_new = jnp.maximum(logf + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(logf + m - m_new)
    C = f_g[..., None, None] * C + i_g[..., None, None] * (
        v[..., :, None] * k[..., None, :])
    n = f_g[..., None] * n + i_g[..., None] * k
    hq = jnp.einsum("bhde,bhe->bhd", C, q)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)),
                        jnp.exp(-m_new))
    h_t = hq / denom[..., None]
    return (C, n, m_new), h_t


DEFAULT_MLSTM_CHUNK = 128


def mlstm_chunkwise(q, k, v, i_pre, f_pre, *, chunk: int = DEFAULT_MLSTM_CHUNK,
                    state=None):
    """Chunkwise-parallel stabilized mLSTM (the TPU-native training form).

    q,k,v: (B,H,S,hd) f32; i_pre,f_pre: (B,H,S) f32.
    Cross-chunk: lax.scan over (C, n, m) state; within-chunk: quadratic
    (L x L) decay-masked attention — residual memory is O(S/L) states
    instead of O(S), which is what makes mLSTM training feasible.
    Returns (h (B,H,S,hd), (C, n, m) final state)."""
    bsz, nh, s, hd = q.shape
    l = min(chunk, s)
    assert s % l == 0, (s, l)
    nc = s // l
    logf = -jax.nn.softplus(-f_pre)                    # log sigmoid

    def to_chunks(x):
        return x.reshape(bsz, nh, nc, l, *x.shape[3:]).transpose(
            2, 0, 1, 3, *range(4, x.ndim + 1))

    qs, ks_, vs = to_chunks(q), to_chunks(k), to_chunks(v)
    is_ = to_chunks(i_pre)
    lfs = to_chunks(logf)

    if state is None:
        c0 = jnp.zeros((bsz, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((bsz, nh, hd), jnp.float32)
        m0 = jnp.full((bsz, nh), -jnp.inf, jnp.float32)
    else:
        c0, n0, m0 = state

    def chunk_step(carry, inp):
        c_st, n_st, m_st = carry                       # stabilized C, n; true m
        qc, kc, vc, ic, lfc = inp                      # (B,H,L,...)
        b_cum = jnp.cumsum(lfc, axis=-1)               # inclusive (B,H,L)
        u = ic - b_cum                                 # (B,H,L)
        m_run = jnp.maximum(m_st[..., None],
                            jax.lax.cummax(u, axis=2)) # M_t (B,H,L)
        # intra-chunk decay-masked scores
        w_decay = jnp.exp(u[:, :, None, :] - m_run[..., None])  # (B,H,Lq,Ls)
        tri = jnp.tril(jnp.ones((l, l), bool))
        w_decay = jnp.where(tri, w_decay, 0.0)
        sc = jnp.einsum("bhtd,bhsd->bhts", qc, kc) * w_decay
        num_intra = jnp.einsum("bhts,bhsd->bhtd", sc, vc)
        den_intra = sc.sum(axis=-1)
        # inter-chunk contribution
        scale_in = jnp.exp(m_st[..., None] - m_run)    # (B,H,L)
        num_inter = jnp.einsum("bhte,bhde->bhtd", qc, c_st) * scale_in[..., None]
        den_inter = jnp.einsum("bhtd,bhd->bht", qc, n_st) * scale_in
        m_t = b_cum + m_run
        denom = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_t))
        h_c = (num_intra + num_inter) / denom[..., None]
        # state update to end of chunk
        b_tot = b_cum[..., -1:]                        # (B,H,1)
        m_end = jnp.maximum(m_st, u.max(axis=-1))      # M_L'
        w_end = jnp.exp(u - m_end[..., None])          # (B,H,L)
        c_new = (jnp.exp(m_st - m_end)[..., None, None] * c_st
                 + jnp.einsum("bhs,bhsd,bhse->bhde", w_end, vc, kc))
        n_new = (jnp.exp(m_st - m_end)[..., None] * n_st
                 + jnp.einsum("bhs,bhsd->bhd", w_end, kc))
        m_new = b_tot[..., 0] + m_end
        return (c_new, n_new, m_new), h_c

    (c_f, n_f, m_f), hs = jax.lax.scan(
        chunk_step, (c0, n0, m0), (qs, ks_, vs, is_, lfs))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(bsz, nh, s, hd)
    return h, (c_f, n_f, m_f)


def mlstm_block(params, x, cfg: ModelConfig):
    """Full-sequence mLSTM block (chunkwise-parallel). x: (B,S,d)."""
    h = cfg.n_heads
    w = int(cfg.d_model * cfg.xlstm_proj_factor)
    hd = w // h
    q, k, v, i_pre, f_pre, z = _mlstm_qkvif(params, x, h, hd)
    b, s = x.shape[:2]
    hs, _ = mlstm_chunkwise(
        q.transpose(0, 2, 1, 3).astype(jnp.float32),
        k.transpose(0, 2, 1, 3).astype(jnp.float32),
        v.transpose(0, 2, 1, 3).astype(jnp.float32),
        i_pre.transpose(0, 2, 1), f_pre.transpose(0, 2, 1))
    hs = hs.transpose(0, 2, 1, 3).reshape(b, s, w).astype(x.dtype)
    out = hs * z
    return jnp.einsum("bsw,wd->bsd", out, params["w_down"])


def mlstm_decode_step(params, x, state: MLSTMState, cfg: ModelConfig):
    """x: (B,1,d)."""
    h = cfg.n_heads
    w = int(cfg.d_model * cfg.xlstm_proj_factor)
    hd = w // h
    xu = jnp.einsum("bsd,dw->bsw", x, params["w_up"])       # (B,1,W)
    conv_in = jnp.concatenate([state.conv_tail, xu], axis=1)
    xc = jnp.einsum("bcw,cw->bw", conv_in[:, -4:], params["conv_w"])
    xc = jax.nn.silu(xc + params["conv_b"])                  # (B,W)
    xh = xc.reshape(-1, h, hd)
    q = jnp.einsum("bhd,hde->bhe", xh, params["w_q"])
    k = jnp.einsum("bhd,hde->bhe", xh, params["w_k"]) * (hd ** -0.5)
    v = jnp.einsum("bhd,hde->bhe", xh, params["w_v"])
    i_pre = (xc @ params["w_i"] + params["b_i"]).astype(jnp.float32)
    f_pre = (xc @ params["w_f"] + params["b_f"]).astype(jnp.float32)
    (C, n, m), h_t = _mlstm_step(
        (state.C, state.n, state.m),
        (q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
         i_pre, f_pre))
    z = jax.nn.silu(jnp.einsum("bsd,dw->bsw", x, params["w_z"]))[:, 0]
    out = (h_t.reshape(-1, w).astype(x.dtype) * z)[:, None]
    y = jnp.einsum("bsw,wd->bsd", out, params["w_down"])
    return y, MLSTMState(C=C, n=n, m=m, conv_tail=conv_in[:, 1:])


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm_params(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    ks = jax.random.split(key, 11)
    p = {"w_down": dense_init(ks[9], (d, d), dtype),
         "w_z_gate": dense_init(ks[10], (d, d), dtype)}
    for idx, gate in enumerate(("z", "i", "f", "o")):
        p[f"w_{gate}"] = dense_init(ks[idx], (d, d), dtype)
        # recurrent connection: block-diagonal per head
        p[f"r_{gate}"] = dense_init(ks[idx + 4], (h, hd, hd), dtype, fan_in=hd)
        p[f"b_{gate}"] = (jnp.full((d,), 3.0, dtype) if gate == "f"
                          else jnp.zeros((d,), dtype))
    return p


class SLSTMState(NamedTuple):
    c: jax.Array   # (B, d)
    n: jax.Array   # (B, d)
    m: jax.Array   # (B, H)
    h: jax.Array   # (B, d)


def init_slstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SLSTMState:
    d = cfg.d_model
    return SLSTMState(
        c=jnp.zeros((batch, d), jnp.float32),
        n=jnp.zeros((batch, d), jnp.float32),
        m=jnp.full((batch, cfg.n_heads), -jnp.inf, jnp.float32),
        h=jnp.zeros((batch, d), jnp.float32),
    )


def _slstm_step(params, n_heads, carry, x_t):
    """x_t: (B,d) pre-projected gate inputs dict."""
    c, n, m, h_prev = carry
    b, d = c.shape
    hd = d // n_heads
    hh = h_prev.reshape(b, n_heads, hd)

    def rec(gate):
        r = jnp.einsum("bhd,hde->bhe", hh,
                       params[f"r_{gate}"].astype(jnp.float32))
        return x_t[gate] + r.reshape(b, d)

    z = jnp.tanh(rec("z"))
    i_pre = rec("i").reshape(b, n_heads, hd)
    f_pre = rec("f").reshape(b, n_heads, hd)
    o = jax.nn.sigmoid(rec("o"))
    logf = -jax.nn.softplus(-f_pre)
    # head-wise stabilizer (max over head dims)
    m_new = jnp.maximum((logf + m[..., None]).max(-1), i_pre.max(-1))
    i_g = jnp.exp(i_pre - m_new[..., None]).reshape(b, d)
    f_g = jnp.exp(logf + m[..., None] - m_new[..., None]).reshape(b, d)
    c_new = f_g * c + i_g * z
    n_new = f_g * n + i_g
    h_new = o * (c_new / jnp.maximum(n_new, 1e-6))
    return (c_new, n_new, m_new, h_new), h_new


def _slstm_gate_inputs(params, x):
    return {g: (jnp.einsum("bsd,de->bse", x, params[f"w_{g}"])
                + params[f"b_{g}"]).astype(jnp.float32)
            for g in ("z", "i", "f", "o")}


def slstm_block(params, x, cfg: ModelConfig):
    """Full-sequence sLSTM block (strictly sequential). x: (B,S,d)."""
    b, s, d = x.shape
    gates = _slstm_gate_inputs(params, x)
    xs = {g: gates[g].transpose(1, 0, 2) for g in gates}
    st0 = (jnp.zeros((b, d), jnp.float32), jnp.zeros((b, d), jnp.float32),
           jnp.full((b, cfg.n_heads), -jnp.inf, jnp.float32),
           jnp.zeros((b, d), jnp.float32))
    step = lambda carry, x_t: _slstm_step(params, cfg.n_heads, carry, x_t)
    _, hs = jax.lax.scan(step, st0, xs)
    hs = hs.transpose(1, 0, 2).astype(x.dtype)        # (B,S,d)
    out = hs * jax.nn.silu(jnp.einsum("bsd,de->bse", x, params["w_z_gate"]))
    return jnp.einsum("bsd,de->bse", out, params["w_down"])


def slstm_decode_step(params, x, state: SLSTMState, cfg: ModelConfig):
    """x: (B,1,d)."""
    gates = _slstm_gate_inputs(params, x)
    x_t = {g: gates[g][:, 0] for g in gates}
    carry = (state.c, state.n, state.m, state.h)
    (c, n, m, h), h_out = _slstm_step(params, cfg.n_heads, carry, x_t)
    out = (h_out.astype(x.dtype)
           * jax.nn.silu(jnp.einsum("bsd,de->bse", x, params["w_z_gate"])[:, 0]))
    y = jnp.einsum("bsd,de->bse", out[:, None], params["w_down"])
    return y, SLSTMState(c=c, n=n, m=m, h=h)
