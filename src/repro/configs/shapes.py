"""Assigned input shapes.

Each shape names the step it lowers:
  * train shapes   -> ``fl_train_step``  (FL round: local grad steps + weighted psum)
  * prefill shapes -> ``prefill_step``   (forward, build KV cache)
  * decode shapes  -> ``serve_step``     (ONE new token against a seq_len KV cache)
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = InputShape("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = InputShape("decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = InputShape("long_500k", seq_len=524_288, global_batch=1, kind="decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def get_shape(name: str) -> InputShape:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}") from None
