#!/usr/bin/env python
"""Summarize a --trace run: phase/occupancy tables from a Chrome trace.

Reads the trace-event JSON written by ``repro.obs.export`` (plus,
optionally, the metrics JSONL written next to it) and prints:

  * schema validation (exit status 2 if the trace violates
    src/repro/obs/trace_schema.json),
  * a wall-clock phase table (total ms + span counts per phase),
  * a per-trial-lane virtual-time table: simulated span, busy time
    (round / agg_window spans), occupancy = busy / span,
  * a metrics summary (pack widths, padding waste, staleness, caches)
    when a metrics file is given.

Usage:
  python tools/trace_report.py out.trace.json [--metrics out.metrics.jsonl]
  python tools/trace_report.py out.trace.json --json    # machine-readable

Run by the CI sweep-smoke job against the traced smoke sweep's artifacts.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from collections import defaultdict
from typing import Any, Dict, List, Optional

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.obs.export import (VIRTUAL_PID, VIRTUAL_US_PER_S, WALL_PID,
                              read_metrics_jsonl, validate_chrome_trace)

# virtual spans whose union tiles a lane's busy time: sync rounds and
# async/buffered aggregation windows (in-flight spans overlap; excluded)
_BUSY_SPANS = ("round", "agg_window")


def report(trace_path: str,
           metrics_path: Optional[str] = None) -> Dict[str, Any]:
    with open(trace_path, encoding="utf-8") as f:
        trace = json.load(f)
    errors = validate_chrome_trace(trace)
    events = trace.get("traceEvents", [])

    track_names: Dict[tuple, str] = {}
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            track_names[(ev["pid"], ev["tid"])] = ev["args"]["name"]

    phases: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"calls": 0, "wall_ms": 0.0})
    lanes: Dict[int, Dict[str, float]] = defaultdict(
        lambda: {"t0": float("inf"), "t1": 0.0, "busy": 0.0})
    # scheduler admit/retire instants: wall events sharing the trial's tid
    # (the continuous-batching scheduler emits one of each per trial)
    sched: Dict[int, Dict[str, Any]] = defaultdict(dict)
    for ev in events:
        # tolerate malformed events here: they still land in ``errors``
        # via the validator, and main() exits 2 on any violation
        if not isinstance(ev, dict) or ev.get("ph") != "X":
            continue
        if ev.get("pid") == WALL_PID and "dur" in ev:
            p = phases[ev.get("cat", "span")]
            p["calls"] += 1
            p["wall_ms"] += ev["dur"] / 1e3
            if ev.get("name") in ("admit", "retire") and "tid" in ev:
                args = ev.get("args") or {}
                sched[ev["tid"]][f"{ev['name']}_ms"] = ev["ts"] / 1e3
                if "lane" in args:
                    sched[ev["tid"]]["pool_lane"] = args["lane"]
        elif (ev.get("pid") == VIRTUAL_PID and "tid" in ev
              and "ts" in ev and "dur" in ev):
            lane = lanes[ev["tid"]]
            lane["t0"] = min(lane["t0"], ev["ts"])
            lane["t1"] = max(lane["t1"], ev["ts"] + ev["dur"])
            if ev.get("name") in _BUSY_SPANS:
                lane["busy"] += ev["dur"]

    lane_rows: List[Dict[str, Any]] = []
    for tid in sorted(set(lanes) | set(sched)):
        lane = lanes[tid]
        span_us = lane["t1"] - lane["t0"]
        row = {
            "track": track_names.get((VIRTUAL_PID, tid),
                                     track_names.get((WALL_PID, tid),
                                                     f"tid {tid}")),
            "t_sim_s": lane["t1"] / VIRTUAL_US_PER_S,
            "busy_s": lane["busy"] / VIRTUAL_US_PER_S,
            "occupancy": lane["busy"] / span_us if span_us > 0 else 0.0,
        }
        row.update(sched.get(tid, {}))
        lane_rows.append(row)

    out: Dict[str, Any] = {
        "trace": trace_path,
        "valid": not errors,
        "errors": errors,
        "n_events": len(events),
        "phases": {k: dict(v) for k, v in sorted(phases.items())},
        "lanes": lane_rows,
    }

    if metrics_path:
        rows = read_metrics_jsonl(metrics_path)
        counters = {r["name"]: r["value"] for r in rows
                    if r.get("kind") == "counter"}
        hists = {r["name"]: r for r in rows if r.get("kind") == "histogram"}
        ph_calls = {r["name"]: r for r in rows if r.get("kind") == "phase"}
        samples = defaultdict(list)
        for r in rows:
            if r.get("kind") == "sample":
                samples[r["name"]].append(r["value"])
        steps_pad = counters.get("pack_steps_padded", 0.0)
        out["metrics"] = {
            "counters": counters,
            "histograms": hists,
            "phase_calls": {k: v.get("calls", 0)
                            for k, v in ph_calls.items()},
            "mean_lanes_live": (sum(samples["lanes_live"])
                                / len(samples["lanes_live"])
                                if samples["lanes_live"] else 0.0),
            "mean_pack_width": (sum(samples["pack_width"])
                                / len(samples["pack_width"])
                                if samples["pack_width"] else 0.0),
            "padding_waste": (1.0 - counters.get("pack_steps_real", 0.0)
                              / steps_pad if steps_pad else 0.0),
            "mean_pool_occupancy": (sum(samples["pool_occupancy"])
                                    / len(samples["pool_occupancy"])
                                    if samples["pool_occupancy"] else None),
            "mean_queue_depth": (sum(samples["queue_depth"])
                                 / len(samples["queue_depth"])
                                 if samples["queue_depth"] else None),
        }
    return out


def _print_tables(rep: Dict[str, Any]):
    print(f"trace: {rep['trace']}  ({rep['n_events']} events, "
          f"{'valid' if rep['valid'] else 'INVALID'})")
    print("\nwall-clock phases")
    print(f"  {'phase':<10} {'calls':>7} {'total ms':>10}")
    for name, p in rep["phases"].items():
        print(f"  {name:<10} {int(p['calls']):>7} {p['wall_ms']:>10.2f}")
    if rep["lanes"]:
        served = any("admit_ms" in lane for lane in rep["lanes"])
        print("\nvirtual-clock lanes")
        if served:
            # scheduler drain view: pool lane + wall admit/retire instants
            print(f"  {'t_sim s':>9} {'busy s':>9} {'occup':>6} "
                  f"{'pool':>4} {'admit ms':>9} {'retire ms':>9}  track")
            for lane in rep["lanes"]:
                pool = lane.get("pool_lane")
                adm, ret = lane.get("admit_ms"), lane.get("retire_ms")
                print(f"  {lane['t_sim_s']:>9.3g} {lane['busy_s']:>9.3g} "
                      f"{lane['occupancy']:>6.1%} "
                      f"{pool if pool is not None else '-':>4} "
                      f"{adm if adm is not None else float('nan'):>9.1f} "
                      f"{ret if ret is not None else float('nan'):>9.1f}  "
                      f"{lane['track']}")
        else:
            print(f"  {'t_sim s':>9} {'busy s':>9} {'occup':>6}  track")
            for lane in rep["lanes"]:
                print(f"  {lane['t_sim_s']:>9.3g} {lane['busy_s']:>9.3g} "
                      f"{lane['occupancy']:>6.1%}  {lane['track']}")
    met = rep.get("metrics")
    if met:
        print("\nmetrics")
        print(f"  mean lanes live : {met['mean_lanes_live']:.2f}")
        print(f"  mean pack width : {met['mean_pack_width']:.2f}")
        print(f"  padding waste   : {met['padding_waste']:.1%}")
        if met.get("mean_pool_occupancy") is not None:
            print(f"  pool occupancy  : {met['mean_pool_occupancy']:.1%}")
        if met.get("mean_queue_depth") is not None:
            print(f"  mean queue depth: {met['mean_queue_depth']:.2f}")
        for name, calls in sorted(met["phase_calls"].items()):
            print(f"  phase calls     : {name} x{calls}")
        for name in ("staleness", "store_write_s"):
            h = met["histograms"].get(name)
            if h and h.get("count"):
                print(f"  {name:<15} : n={h['count']} mean={h['mean']:.4g} "
                      f"p90={h['p90']:.4g} max={h['max']:.4g}")
        for name in ("sync_dispatched", "sync_dropouts", "sync_stragglers_cut",
                     "event_dispatched", "event_dropouts",
                     "trials_admitted", "trials_retired",
                     "eval_fn_cache_hits", "eval_fn_cache_misses"):
            if name in met["counters"]:
                print(f"  {name:<20}: {met['counters'][name]:g}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Summarize a Chrome trace + metrics JSONL emitted by "
                    "repro --trace runs")
    ap.add_argument("trace", help="path to the .trace.json file")
    ap.add_argument("--metrics", default=None,
                    help="path to the companion .metrics.jsonl")
    ap.add_argument("--json", action="store_true",
                    help="print the report as JSON instead of tables")
    args = ap.parse_args(argv)

    rep = report(args.trace, args.metrics)
    if args.json:
        print(json.dumps(rep, indent=2))
    else:
        _print_tables(rep)
    if not rep["valid"]:
        for err in rep["errors"][:20]:
            print(f"SCHEMA VIOLATION: {err}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
