from repro.roofline.analysis import RooflineReport, analyze_compiled
from repro.roofline.hardware import TPU_V5E

__all__ = ["RooflineReport", "analyze_compiled", "TPU_V5E"]
