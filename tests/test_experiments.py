"""Tests for the sweep-orchestration subsystem (repro.experiments):
grid validation at expansion time, vectorized-vs-independent trial parity,
store resume semantics, and the paper-style table emitter."""

import jax
import numpy as np
import pytest

from repro.experiments import (CANONICAL_PREFERENCE, ResultStore, SweepSpec,
                               TrialSpec, paper_table, parse_preferences,
                               run_sweep, run_trial, run_vectorized)
from repro.experiments.grid import spec_from_dict

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs a multi-device mesh (XLA_FLAGS="
           "--xla_force_host_platform_device_count=4)")


def tiny_spec(**kw):
    base = dict(dataset="emnist", aggregator="fedavg", seed=0,
                tuner="fedtune", m0=3, e0=1.0, rounds=3,
                target_accuracy=0.99, batch_size=5, eval_points=128)
    base.update(kw)
    return TrialSpec(**base)


# ---------------------------------------------------------------------------
# grid expansion + validation
# ---------------------------------------------------------------------------

def test_grid_expands_product_and_collapses_fixed_baselines():
    sweep = SweepSpec(datasets=("emnist",),
                      aggregators=("fedavg", "fedadam"),
                      preferences=parse_preferences("0,14"),
                      seeds=(0, 1), base=tiny_spec())
    specs = sweep.expand()
    # fedtune: 2 agg x 2 pref x 2 seeds = 8; fixed: 2 agg x 2 seeds = 4
    assert len(specs) == 12
    assert len({s.key() for s in specs}) == 12
    fixed = [s for s in specs if s.tuner == "fixed"]
    assert len(fixed) == 4
    assert all(s.preference == CANONICAL_PREFERENCE for s in fixed)
    # every fedtune trial's baseline twin is in the grid
    keys = {s.key() for s in specs}
    for s in specs:
        if s.tuner == "fedtune":
            assert s.baseline_key() in keys


def test_grid_unknown_aggregator_raises_at_expansion():
    sweep = SweepSpec(aggregators=("fedavg", "fedsgd"), base=tiny_spec())
    with pytest.raises(ValueError, match="fedavg"):
        sweep.expand()


def test_grid_unknown_client_exec_and_mode_raise():
    with pytest.raises(ValueError, match="sequential"):
        tiny_spec(client_exec="warp").validate()
    with pytest.raises(ValueError, match="sync"):
        tiny_spec(mode="psychic").validate()
    with pytest.raises(ValueError, match="emnist"):
        tiny_spec(dataset="mnist").validate()
    with pytest.raises(ValueError, match="preference"):
        tiny_spec(preference=(1.0, 1.0, 0.0, 0.0)).validate()


def test_spec_key_roundtrip_through_dict():
    s = tiny_spec(aggregator="fednova", preference=(0.5, 0.5, 0.0, 0.0))
    assert spec_from_dict(s.to_dict()) == s


def test_parse_preferences_forms():
    assert len(parse_preferences("all")) == 15
    assert parse_preferences("0") == [(1.0, 0.0, 0.0, 0.0)]
    assert parse_preferences("1,0,0,0;0,1,0,0") == [(1.0, 0.0, 0.0, 0.0),
                                                   (0.0, 1.0, 0.0, 0.0)]
    with pytest.raises(ValueError):
        parse_preferences("99")


# ---------------------------------------------------------------------------
# vectorized multi-trial parity: T=4 packed == 4 independent FLServer.run()
# ---------------------------------------------------------------------------

def assert_trial_parity(base, vec):
    """Round records must be identical: accuracies, FedTune (M, E)
    trajectories, and cost totals."""
    assert base.history_acc == vec.history_acc
    assert base.history_m == vec.history_m
    assert base.history_e == vec.history_e
    assert base.final_accuracy == vec.final_accuracy
    assert (base.final_m, base.final_e) == (vec.final_m, vec.final_e)
    np.testing.assert_allclose(base.cost, vec.cost, rtol=0, atol=0)
    assert base.reached == vec.reached
    assert base.rounds == vec.rounds


def test_vectorized_matches_independent_runs_fedavg():
    specs = [tiny_spec(seed=s) for s in range(4)]
    base = [run_trial(s) for s in specs]
    vec = run_vectorized(specs)
    for b, v in zip(base, vec):
        assert_trial_parity(b, v)


def test_vectorized_matches_independent_runs_fedadam():
    """One adaptive-server aggregator: per-trial optimizer state (m, v) must
    stay private to each packed trial."""
    specs = [tiny_spec(seed=s, aggregator="fedadam") for s in range(4)]
    base = [run_trial(s) for s in specs]
    vec = run_vectorized(specs)
    for b, v in zip(base, vec):
        assert_trial_parity(b, v)


def test_vectorized_mixed_aggregators_and_fixed_tuner():
    """Trials with different aggregators and tuners pack into one cohort
    without cross-talk."""
    specs = [tiny_spec(seed=0, aggregator="fedavg"),
             tiny_spec(seed=1, aggregator="fednova"),
             tiny_spec(seed=0, tuner="fixed",
                       preference=CANONICAL_PREFERENCE)]
    base = [run_trial(s) for s in specs]
    vec = run_vectorized(specs)
    for b, v in zip(base, vec):
        assert_trial_parity(b, v)


def test_vectorized_rejects_unpackable_trials():
    with pytest.raises(ValueError, match="sequential engine"):
        run_vectorized([tiny_spec(mode="async")])
    with pytest.raises(ValueError, match="pack"):
        run_vectorized([tiny_spec()], pack="origami")


@multidevice
def test_sharded_pack_matches_batched_pack():
    """The clients-mesh packed cohort (per-trial segment sum + psum) agrees
    with the single-device pack up to float reassociation."""
    specs = [tiny_spec(seed=s) for s in range(3)]
    vb = run_vectorized(specs, pack="batched")
    vs = run_vectorized(specs, pack="sharded")
    for b, s in zip(vb, vs):
        assert b.history_m == s.history_m
        assert b.history_e == s.history_e
        np.testing.assert_allclose(b.history_acc, s.history_acc, atol=1e-3)
        np.testing.assert_allclose(b.cost, s.cost, rtol=1e-6)


# ---------------------------------------------------------------------------
# store: resume + table emission
# ---------------------------------------------------------------------------

def test_store_resume_skips_completed_keys(tmp_path):
    store = ResultStore(str(tmp_path / "r.jsonl"))
    specs = [tiny_spec(seed=s, rounds=2) for s in range(2)]
    run_sweep(specs, store=store)
    assert store.completed_keys() == {s.key() for s in specs}
    # a re-invocation would filter on completed_keys: nothing pending
    pending = [s for s in specs if s.key() not in store.completed_keys()]
    assert pending == []
    # corrupt tail (killed mid-write) is skipped, earlier records survive
    with open(store.path, "a") as f:
        f.write('{"key": "trunc')
    assert len(store.load()) == 2


def test_paper_table_reports_fedtune_vs_fixed(tmp_path):
    store = ResultStore(str(tmp_path / "t.jsonl"))
    specs = [tiny_spec(rounds=2),
             tiny_spec(rounds=2, tuner="fixed",
                       preference=CANONICAL_PREFERENCE)]
    run_sweep(specs, store=store)
    table = paper_table(store.load())
    assert "emnist" in table and "fedavg" in table and "%" in table
    # unpaired records tabulate to nothing, not an error
    assert "no fedtune" in paper_table([])
