"""Finding reporters: human text and byte-stable JSON.

The JSON form is the ratchet's currency — it must be byte-identical for
identical inputs (sorted findings, ``sort_keys``, fixed indent, no
timestamps/absolute paths), because the determinism test diffs two runs
and CI diffs against the checked-in baseline.
"""

from __future__ import annotations

import json
from typing import List

from .core import AnalysisResult, Finding, Suppression

JSON_VERSION = 1


def to_json(result: AnalysisResult, *, new_findings: List[Finding]) -> str:
    doc = {
        "version": JSON_VERSION,
        "n_files": result.n_files,
        "findings": [f.to_dict() for f in result.findings],
        "new_findings": [f.to_dict() for f in new_findings],
        "suppressed": [
            {**s.finding.to_dict(), "justification": s.justification}
            for s in result.suppressed
        ],
        "errors": list(result.errors),
    }
    return json.dumps(doc, sort_keys=True, indent=2) + "\n"


def to_text(result: AnalysisResult, *, new_findings: List[Finding],
            show_suppressed: bool = False) -> str:
    lines: List[str] = []
    new_keys = {f.sort_key() for f in new_findings}
    for f in result.findings:
        marker = "" if f.sort_key() in new_keys else " [baseline]"
        lines.append(
            f"{f.path}:{f.line}:{f.col + 1}: {f.rule} {f.message}{marker}")
    if show_suppressed:
        for s in result.suppressed:
            f = s.finding
            lines.append(
                f"{f.path}:{f.line}:{f.col + 1}: {f.rule} suppressed — "
                f"{s.justification}")
    for err in result.errors:
        lines.append(f"error: {err}")
    lines.append(
        f"{len(result.findings)} finding(s) "
        f"({len(new_findings)} new, {len(result.suppressed)} suppressed) "
        f"in {result.n_files} file(s)")
    return "\n".join(lines) + "\n"
