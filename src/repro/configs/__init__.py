"""Architecture registry: the 10 assigned architectures (+ paper models)."""

from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig, reduced  # noqa: F401
from repro.configs.shapes import SHAPES, InputShape, get_shape  # noqa: F401

_ARCH_MODULES = {
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "command-r-35b": "repro.configs.command_r_35b",
    "minitron-8b": "repro.configs.minitron_8b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "internvl2-1b": "repro.configs.internvl2_1b",
    "dbrx-132b": "repro.configs.dbrx_132b",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    """Look up an assigned architecture config by id (``--arch <id>``)."""
    try:
        module = importlib.import_module(_ARCH_MODULES[name])
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}") from None
    return module.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {name: get_config(name) for name in ARCH_NAMES}
