"""Pallas TPU kernel: RG-LRU diagonal linear recurrence
    h_t = a_t * h_{t-1} + b_t        (RecurrentGemma's sequence mixer).

Tiling: grid (batch, width_blocks, time_chunks); the time axis (last grid
dim) is sequential on TPU, so the hidden state h lives in a VMEM scratch
(BLOCK_B, BLOCK_W) carried across chunks.  Within a chunk the recurrence is
a fori_loop over time steps on VREG-resident rows — the channel dimension
(lane axis, 128-aligned) provides the vector parallelism; there is no
cross-channel coupling, which is exactly why this maps well onto the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_B = 8
BLOCK_W = 512
CHUNK_T = 128


def _kernel(a_ref, b_ref, o_ref, h_scr, *, chunk_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, h):
        h = a_ref[:, t, :].astype(jnp.float32) * h \
            + b_ref[:, t, :].astype(jnp.float32)
        o_ref[:, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk_t, step, h_scr[...])
    h_scr[...] = h


@functools.partial(jax.jit, static_argnames=(
    "block_b", "block_w", "chunk_t", "interpret"))
def rglru_scan(a, b, *, block_b: int = BLOCK_B, block_w: int = BLOCK_W,
               chunk_t: int = CHUNK_T, interpret: bool = False):
    """a, b: (B, T, W) -> h: (B, T, W) with h_t = a_t h_{t-1} + b_t."""
    bsz, t, w = a.shape
    bb = min(block_b, bsz)
    bw = min(block_w, w)
    ct = min(chunk_t, t)
    assert bsz % bb == 0 and w % bw == 0 and t % ct == 0, (bsz, t, w)

    kernel = functools.partial(_kernel, chunk_t=ct)
    return pl.pallas_call(
        kernel,
        grid=(bsz // bb, w // bw, t // ct),
        in_specs=[
            pl.BlockSpec((bb, ct, bw), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((bb, ct, bw), lambda bi, wi, ti: (bi, ti, wi)),
        ],
        out_specs=pl.BlockSpec((bb, ct, bw), lambda bi, wi, ti: (bi, ti, wi)),
        out_shape=jax.ShapeDtypeStruct((bsz, t, w), a.dtype),
        scratch_shapes=[pltpu.VMEM((bb, bw), jnp.float32)],
        interpret=interpret,
    )(a, b)
