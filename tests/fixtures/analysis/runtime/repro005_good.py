"""GOOD fixture: instrumented stages using cataloged names only."""

from repro import obs


class MiniEngine:
    @obs.traced("plan_event", phase="plan")
    def plan_event(self, st):
        obs.registry.inc("event_dispatched")
        return st

    def apply_event(self, st):
        with obs.span("apply_event", phase="apply"):
            return st
