"""GOOD fixture: every exempt pattern REPRO003 must NOT flag.

``is None`` dispatch, static ``.shape`` reads, closure constants
(``prox_mu``-style), config-typed parameters, and ``jnp.where`` are all
trace-safe.
"""

import jax
import jax.numpy as jnp

MU = 0.1


@jax.jit
def step(x, lr, flag=None):
    if flag is None:          # `is None` dispatch is host-side and fine
        lr = lr * 0.5
    if x.shape[0] > 1:        # static shape read, not a tracer value
        x = x[:1]
    if MU > 0.0:              # closure constant, compile-time Python
        x = x - MU * x
    return jnp.where(x > 0, x - lr, x)


@jax.jit
def apply(params, cfg, x):
    if cfg.deep:              # config params are static by convention
        return params["w"] @ x
    return x
