"""The paper's own measurement-study models (Table 2 + §5.1).

ResNet-10/18/26/34 for 32x32 single-channel spectrograms (speech-to-command),
ResNet-10/18 for CIFAR-100-like, and the 2-layer MLP for EMNIST.  These are
vision models, configured by a separate lightweight dataclass (the LM
``ModelConfig`` does not apply).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ResNetConfig:
    name: str
    stage_blocks: Tuple[int, int, int, int]   # BasicBlocks per stage
    width: int                                # first-stage channels
    n_classes: int
    in_channels: int = 1
    image_size: int = 32
    source: str = "arXiv:1512.03385 (He et al.); Table 2 of FedTune"


@dataclass(frozen=True)
class MLPConfig:
    name: str
    in_dim: int
    hidden: Tuple[int, ...]
    n_classes: int
    source: str = "FedTune §5.1 (EMNIST MLP, one hidden layer of 200 ReLU)"


def resnet(name: str, blocks, n_classes=35, in_channels=1, width=8) -> ResNetConfig:
    # width=8 reproduces the paper's Table 2 parameter counts
    # (ResNet-10 ~79.7K, ResNet-18 ~177.2K).
    return ResNetConfig(name=name, stage_blocks=tuple(blocks), width=width,
                        n_classes=n_classes, in_channels=in_channels)


# Table 2 of the paper: BasicBlock counts per stage.
RESNET10 = resnet("resnet10", (1, 1, 1, 1))
RESNET18 = resnet("resnet18", (2, 2, 2, 2))
RESNET26 = resnet("resnet26", (3, 3, 3, 3))
RESNET34 = resnet("resnet34", (3, 4, 6, 3))

MLP_EMNIST = MLPConfig(name="mlp_emnist", in_dim=28 * 28, hidden=(200,), n_classes=62)

PAPER_MODELS = {
    m.name: m for m in (RESNET10, RESNET18, RESNET26, RESNET34, MLP_EMNIST)
}
