"""Distributed step functions for the production mesh.

  fl_train_step  — one FL round on the mesh.  Participant slots live on the
    ("pod", "data") axes; each slot computes the gradient of its local batch
    weighted by n_k/n, and the FSDP/DP gradient reduction that GSPMD inserts
    IS the FedAvg aggregation (the paper's upload/download collective).
    ``local_passes`` > 1 accumulates E microbatch gradients before the
    weighted reduction — cost-faithful to E local passes (ExCompute per
    round, unchanged collective bytes), see DESIGN.md §3.
  prefill_step   — full-sequence forward building the KV cache (last logits).
  serve_step     — ONE token against a seq_len KV cache (ring-buffered /
    recurrent for sub-quadratic archs; full-attention archs at long_500k are
    served under the documented sliding-window variant).

Each ``make_*`` returns (jit_fn, input_specs_dict) where the specs are
ShapeDtypeStructs — the dry-run lowers without allocating anything.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import lm as lm_mod
from repro.models import stacked as stacked_mod
from repro.sharding import specs as sh
from repro.sharding.ctx import activation_rules

DEFAULT_LR = 3e-4
DEFAULT_MOMENTUM = 0.9


def _quantize_dequantize_ste(w):
    """int8 fake-quantization with a straight-through gradient.  Because the
    int8 tensor inherits the FSDP sharding, XLA's parameter all-gathers move
    int8 bytes (2x smaller than bf16); dequantization happens post-gather."""
    if w.ndim < 2 or w.dtype not in (jnp.bfloat16, jnp.float32):
        return w
    scale = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127)
    q = q.astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).astype(w.dtype)
    return deq + (w - jax.lax.stop_gradient(w))


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _ns(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _fit_ns(mesh: Mesh, spec: P, struct) -> NamedSharding:
    return _ns(mesh, sh.fit_spec(spec, struct.shape, mesh))


def _batch_spec(mesh: Mesh, rules, struct) -> NamedSharding:
    spec = P(*([rules.get("batch")] + [None] * (struct.ndim - 1)))
    return _fit_ns(mesh, spec, struct)


def param_struct(cfg: ModelConfig, dtype=jnp.bfloat16, *,
                 stacked: bool = False):
    """ShapeDtypeStruct pytree of the model params (no allocation)."""
    init = (stacked_mod.init_params_stacked if stacked
            else lm_mod.init_params)
    return jax.eval_shape(lambda: init(cfg, jax.random.PRNGKey(0), dtype))


def _frontend_struct(cfg: ModelConfig, batch: int, dtype):
    f = cfg.frontend
    return jax.ShapeDtypeStruct((batch, f.seq_len, f.feature_dim), dtype)


# ---------------------------------------------------------------------------
# FL train step
# ---------------------------------------------------------------------------

def make_fl_train_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape, *,
                       multi_pod: bool = False, lr: float = DEFAULT_LR,
                       momentum: float = DEFAULT_MOMENTUM,
                       local_passes: int = 1, microbatches: int = 1,
                       remat: bool = True, dtype=jnp.bfloat16,
                       seq_parallel: bool = True,
                       quantize_comm: bool = False,
                       moe_mode: str = "dense"):
    """One FL round.

    local_passes = E: the cohort re-passes the SAME round batch E times
      (gradient accumulated; E x compute, unchanged collective bytes —
      exactly the paper's CompT/CompL vs TransT/TransL trade).
    microbatches: split the round batch to bound activation memory
      (FLOPs unchanged)."""
    rules = sh.train_rules(multi_pod)
    if not seq_parallel:
        rules["seq"] = None
    b, s = shape.global_batch, shape.seq_len
    assert b % microbatches == 0, (b, microbatches)
    mb_size = b // microbatches

    n_rows = mesh.shape["data"] * mesh.shape.get("pod", 1)

    def loss(params, batch):
        from repro.models import ffn as ffn_mod
        if quantize_comm:  # int8 FSDP all-gathers (straight-through estimator)
            params = jax.tree.map(_quantize_dequantize_ste, params)
        with activation_rules(mesh, rules), \
                ffn_mod.moe_impl(moe_mode, rows=n_rows):
            l, metrics = stacked_mod.loss_fn(params, cfg, batch, remat=remat)
        return l, metrics

    def fl_train_step(params, momentum_state, batch):
        """batch: {tokens (B,S), labels (B,S), weight (B,), frontend?}."""
        def one_micro(grads_acc, mb):
            (l, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(params, mb)
            grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
            return grads_acc, (l, metrics)

        zeros = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)
        micro = jax.tree.map(
            lambda x: x.reshape((microbatches, mb_size) + x.shape[1:]),
            batch)
        if microbatches == 1:
            grads, (l, metrics) = one_micro(zeros, batch)
        else:
            grads, (ls, metricss) = jax.lax.scan(one_micro, zeros, micro)
            l = ls.mean()
            metrics = jax.tree.map(lambda x: x.mean(), metricss)
        if local_passes > 1:   # E passes over the same round batch
            def e_pass(grads_acc, _):
                g2, _aux = (jax.lax.scan(one_micro, grads_acc, micro)
                            if microbatches > 1
                            else one_micro(grads_acc, batch))
                return g2, None
            grads, _ = jax.lax.scan(e_pass, grads, None,
                                    length=local_passes - 1)
        grads = jax.tree.map(
            lambda g: g / (microbatches * local_passes), grads)
        # SGD with momentum on the aggregated (FedAvg-weighted) gradient
        new_m = jax.tree.map(
            lambda m, g: momentum * m + g.astype(m.dtype),
            momentum_state, grads)
        new_p = jax.tree.map(
            lambda p, m: (p - lr * m.astype(p.dtype)), params, new_m)
        return new_p, new_m, l, metrics

    p_struct = param_struct(cfg, dtype, stacked=True)
    m_struct = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), p_struct)
    batch_struct: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "weight": jax.ShapeDtypeStruct((b,), jnp.float32),
    }
    if cfg.frontend is not None:
        batch_struct["frontend"] = _frontend_struct(cfg, b, dtype)

    p_shard = sh.param_shardings(p_struct, mesh, rules)
    m_shard = jax.tree.map(lambda s_: s_, p_shard)
    bspec = rules.get("batch")
    batch_shard = {
        k: _fit_ns(mesh, P(*([bspec] + [None] * (v.ndim - 1))), v)
        for k, v in batch_struct.items()
    }
    jit_fn = jax.jit(  # noqa: REPRO006 -- one compile per (arch, shape, mesh) by design: dryrun measures each distinct sharded program exactly once
        fl_train_step,
        in_shardings=(p_shard, m_shard, batch_shard),
        out_shardings=(p_shard, m_shard, _ns(mesh, P()), _ns(mesh, P())),
        donate_argnums=(0, 1),
    )
    return jit_fn, (p_struct, m_struct, batch_struct)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape, *,
                      multi_pod: bool = False, dtype=jnp.bfloat16,
                      decode_window: Optional[int] = None):
    rules = sh.decode_rules(multi_pod, shard_seq=False)
    rules["batch"] = ("pod", "data") if multi_pod else "data"
    b, s = shape.global_batch, shape.seq_len

    def prefill_step(params, tokens, frontend=None):
        from repro.models import ffn as ffn_mod
        with activation_rules(mesh, rules), ffn_mod.moe_impl("dense"):
            cache = stacked_mod.init_cache_stacked(
                cfg, b, s, decode_window=decode_window, dtype=dtype)
            logits, cache = stacked_mod.prefill(params, cfg, tokens, cache,
                                                frontend=frontend)
        return logits, cache

    p_struct = param_struct(cfg, dtype, stacked=True)
    tok_struct = jax.ShapeDtypeStruct((b, s), jnp.int32)
    p_shard = sh.param_shardings(p_struct, mesh, rules)
    args: Tuple = (p_struct, tok_struct)
    in_sh: Tuple = (p_shard, _batch_spec(mesh, rules, tok_struct))
    if cfg.frontend is not None:
        fe = _frontend_struct(cfg, b, dtype)
        args = args + (fe,)
        in_sh = in_sh + (_batch_spec(mesh, rules, fe),)
    jit_fn = jax.jit(prefill_step, in_shardings=in_sh)
    return jit_fn, args


# ---------------------------------------------------------------------------
# serve (decode one token)
# ---------------------------------------------------------------------------

def _quantizable(path_leaf_shape, leaf) -> bool:
    return leaf.ndim >= 2 and leaf.size >= (1 << 20) and \
        leaf.dtype in (jnp.bfloat16, jnp.float32)


def quantize_param_structs(p_struct):
    """Split a param ShapeDtypeStruct tree into (int8 mirror, scales tree).
    Small tensors pass through unquantized (scale=None)."""
    def q(leaf):
        if _quantizable(None, leaf):
            return jax.ShapeDtypeStruct(leaf.shape, jnp.int8)
        return leaf

    def s(leaf):
        if _quantizable(None, leaf):
            return jax.ShapeDtypeStruct(leaf.shape[:-1] + (1,), jnp.float32)
        return None

    return jax.tree.map(q, p_struct), jax.tree.map(s, p_struct)


def dequantize_params(params_q, scales, dtype=jnp.bfloat16):
    def deq(q, s):
        if s is None:
            return q
        return (q.astype(jnp.float32) * s).astype(dtype)
    return jax.tree.map(deq, params_q, scales,
                        is_leaf=lambda x: x is None)


def quantize_params(params):
    """Runtime quantization (for the serve launcher / tests)."""
    def q(w):
        if not _quantizable(None, w):
            return w, None
        scale = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-1,
                        keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-8)
        qw = jnp.clip(jnp.round(w.astype(jnp.float32) / scale),
                      -127, 127).astype(jnp.int8)
        return qw, scale
    pairs = jax.tree.map(q, params)
    qs = jax.tree.map(lambda p: p[0], pairs,
                      is_leaf=lambda x: isinstance(x, tuple))
    ss = jax.tree.map(lambda p: p[1], pairs,
                      is_leaf=lambda x: isinstance(x, tuple))
    return qs, ss


def make_serve_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape, *,
                    multi_pod: bool = False, dtype=jnp.bfloat16,
                    quantize_weights: bool = False,
                    resident_experts: bool = False):
    b, s = shape.global_batch, shape.seq_len
    # Sub-quadratic archs decode natively; full-attention archs at very long
    # context get the documented sliding-window serving variant.
    force_window = (not cfg.subquadratic) and s > 65536
    decode_window = cfg.long_context_window if force_window else None
    # batch too small to shard? shard the cache sequence dim instead.
    n_batch_shards = mesh.shape.get("pod", 1) * mesh.shape["data"]
    shard_seq = b < n_batch_shards
    rules = sh.decode_rules(multi_pod, shard_seq=shard_seq)
    if resident_experts:
        # §Perf H2b: keep ALL weights resident by sharding the MoE expert
        # d_ff dim over "data" instead of FSDP-gathering d_model-sharded
        # weights per token; collectives become small activation psums.
        rules["residual"] = None
        rules["moe_inner"] = "data"

    p_struct = param_struct(cfg, dtype, stacked=True)
    scale_struct = None
    if quantize_weights:
        p_struct, scale_struct = quantize_param_structs(p_struct)

    def serve_step(params, cache, token, pos, scales=None):
        from repro.models import ffn as ffn_mod
        if quantize_weights:
            params = dequantize_params(params, scales, dtype)
        with activation_rules(mesh, rules), ffn_mod.moe_impl("dense"):
            logits, cache = stacked_mod.decode_step(params, cfg, token, pos,
                                                    cache)
        return logits, cache

    cache_struct = jax.eval_shape(
        lambda: stacked_mod.init_cache_stacked(
            cfg, b, s, decode_window=decode_window, dtype=dtype))
    tok_struct = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos_struct = jax.ShapeDtypeStruct((), jnp.int32)

    p_shard = sh.param_shardings(p_struct, mesh, rules)
    cache_shard = jax.tree.map(
        lambda leaf, spec: _fit_ns(mesh, spec, leaf),
        cache_struct, sh.cache_specs(cache_struct, rules))
    in_sh = [p_shard, cache_shard, _batch_spec(mesh, rules, tok_struct),
             _ns(mesh, P())]
    args = [p_struct, cache_struct, tok_struct, pos_struct]
    if quantize_weights:
        in_sh.append(jax.tree.map(lambda s_: _ns(mesh, P()), scale_struct))
        args.append(scale_struct)
    jit_fn = jax.jit(
        serve_step,
        in_shardings=tuple(in_sh),
        out_shardings=None,
        donate_argnums=(1,),
    )
    return jit_fn, tuple(args)


def step_for_shape(cfg: ModelConfig, mesh: Mesh, shape: InputShape, *,
                   multi_pod: bool = False, **kw):
    """Dispatch on the shape kind -> (jit_fn, example ShapeDtypeStructs)."""
    if shape.kind == "train":
        return make_fl_train_step(cfg, mesh, shape, multi_pod=multi_pod, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, mesh, shape, multi_pod=multi_pod, **kw)
    if shape.kind == "decode":
        return make_serve_step(cfg, mesh, shape, multi_pod=multi_pod, **kw)
    raise ValueError(shape.kind)
