"""Analytic byte/FLOP model for the federated aggregation kernels.

``fed_reduce`` is a streaming reduction: arithmetic intensity is well
under 1 FLOP/byte, so its roofline is the memory term alone — the wall
time lower bound on a chip is ``bytes / hbm_bandwidth``.  The byte model
below is what ``benchmarks/kernel_bench.py`` checks measured time
against (``bound_fraction`` = bound / measured: 1.0 means streaming at
bandwidth), both for the host CPU (against a measured stream rate) and
analytically for TPU_V5E.

The fused kernel's traffic for (M, N) rows into (T, N) lanes:

  read   rows        M * N * 4 bytes    (streamed exactly once)
  read   quant_ref   T * N * 4          (only when the round trip fuses;
                                         the (M, N) gather re-reads it
                                         from cache/VMEM, counted once)
  read   base        T * N * 4
  write  out         T * N * 4

versus the pre-fusion separate-call sequence, which streams the rows
once per stage (quantize round trip: read + write; weighted reduce:
read) plus each stage's lane-sized traffic — the rows term alone is
~3x, which is the whole speedup story for M >> T.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.roofline.hardware import TPU_V5E, Chip

F32 = 4


@dataclass(frozen=True)
class KernelTraffic:
    """HBM traffic + FLOPs of one kernel dispatch (or call sequence)."""
    name: str
    bytes_hbm: float
    flops: float

    def bound_s(self, chip: Chip = TPU_V5E) -> float:
        """Roofline wall-time lower bound on ``chip`` (memory term vs
        compute term — for these kernels the memory term always wins)."""
        return max(self.bytes_hbm / chip.hbm_bandwidth,
                   self.flops / chip.peak_flops_bf16)

    def bound_s_at(self, stream_bytes_per_s: float) -> float:
        """Memory-roofline bound at a measured stream bandwidth (the CPU
        path of the kernel benchmark)."""
        return self.bytes_hbm / stream_bytes_per_s


def fed_reduce_traffic(m: int, n: int, t: int, *, quant: bool = False,
                       base: bool = True) -> KernelTraffic:
    """Fused kernel: one pass over the rows, lane-sized side inputs."""
    b = m * n * F32                        # rows, streamed once
    if quant:
        b += t * n * F32                   # quant_ref
    if base:
        b += t * n * F32                   # base
    b += t * n * F32                       # out
    # weight mul + fold add per element, plus ~6 elementwise ops for the
    # quantization round trip (sub, div, round, clip, mul, add)
    f = 2.0 * m * n + (6.0 * m * n if quant else 0.0)
    return KernelTraffic("fed_reduce_fused", float(b), f)


def fed_reduce_separate_traffic(m: int, n: int, t: int, *,
                                quant: bool = False,
                                base: bool = True) -> KernelTraffic:
    """The pre-fusion sequence: per-trial quantize round trip (read rows
    + refs, write rows), then per-trial weighted reduce (read rows again,
    write lanes), then lane base add.  Rows stream ~3x."""
    b = m * n * F32                        # reduce: read rows
    f = 2.0 * m * n
    if quant:
        b += 2 * m * n * F32               # roundtrip: read + write rows
        b += t * n * F32                   # refs
        f += 6.0 * m * n
    if base:
        b += 2 * t * n * F32               # base add: read lanes + base
        f += t * n
    b += t * n * F32                       # out
    return KernelTraffic("fed_reduce_separate", float(b), f)
