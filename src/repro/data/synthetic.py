"""Synthetic federated datasets (offline substitute for the paper's
speech-to-command / EMNIST / CIFAR-100, see DESIGN.md §5).

The three defining FL data properties are reproduced and tested:
  * massively distributed — thousands of clients, few examples each;
  * unbalanced            — client sizes follow a clipped log-normal
                            (1..~316 points, matching the paper's Fig. 2a);
  * non-IID               — per-client label distributions drawn from a
                            Dirichlet, plus a per-client feature shift.

Construction: class-conditional Gaussian mixtures.  Each class c has a mean
vector mu_c; client k draws labels from Dirichlet-skewed class weights and
features  x = sep * mu_y + client_shift_k + noise.  A fraction of labels is
flipped so accuracy climbs gradually over many rounds (the regime FedTune's
accuracy-gated decisions need).  Client features are generated lazily from
per-client seeds — only the participants of a round are materialized.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class DataSpec:
    name: str
    n_classes: int
    shape: Tuple[int, ...]          # per-example feature shape
    n_train_clients: int
    n_test_clients: int
    size_log_mean: float = 3.0      # client-size log-normal parameters
    size_log_std: float = 1.2
    size_min: int = 1
    size_max: int = 316
    dirichlet_alpha: float = 0.5    # label skew (smaller = more non-IID)
    separation: float = 1.1         # class-mean scaling (difficulty)
    noise: float = 1.0
    client_shift: float = 0.35      # non-IID feature skew
    label_noise: float = 0.08
    seed: int = 0


@dataclass
class FederatedDataset:
    spec: DataSpec
    client_sizes: np.ndarray                  # (K,) train client sizes
    _class_means: np.ndarray = field(repr=False, default=None)
    _test_cache: Optional[Tuple[np.ndarray, np.ndarray]] = field(
        default=None, repr=False)
    _test_exhausted: bool = field(default=False, repr=False)

    # ------------------------------------------------------------------
    @property
    def n_clients(self) -> int:
        return len(self.client_sizes)

    @property
    def feat_dim(self) -> int:
        return int(np.prod(self.spec.shape))

    def client_data(self, client_id: int) -> Tuple[np.ndarray, np.ndarray]:
        """Materialize one training client -> (x (n, *shape), y (n,))."""
        return self._materialize(client_id, self.client_sizes[client_id],
                                 test=False)

    def test_data(self, max_points: int = 2048) -> Tuple[np.ndarray, np.ndarray]:
        """Pooled test set from the held-out test clients.

        Generation stops once ``max_points`` examples exist, and the result
        is cached.  The cache is only a valid answer for a LARGER request if
        it already holds ``max_points`` examples or the test clients were
        exhausted building it — otherwise it is regenerated at the larger
        size (same rng seed, so the previously returned points are a prefix
        of the regenerated set).  A first small call therefore never
        permanently truncates the test set for later callers."""
        if self._test_cache is not None and (
                len(self._test_cache[1]) >= max_points or self._test_exhausted):
            x, y = self._test_cache
            return x[:max_points], y[:max_points]
        rng = np.random.default_rng(self.spec.seed + 777)
        xs, ys = [], []
        total = 0
        exhausted = True
        for tc in range(self.spec.n_test_clients):
            n = int(np.clip(rng.lognormal(self.spec.size_log_mean,
                                          self.spec.size_log_std),
                            self.spec.size_min, self.spec.size_max))
            x, y = self._materialize(10_000_000 + tc, n, test=True)
            xs.append(x)
            ys.append(y)
            total += n
            if total >= max_points:
                exhausted = False
                break
        x = np.concatenate(xs)[:max_points]
        y = np.concatenate(ys)[:max_points]
        self._test_cache = (x, y)
        self._test_exhausted = exhausted
        return x, y

    # ------------------------------------------------------------------
    def _materialize(self, client_key: int, n: int, *, test: bool):
        s = self.spec
        rng = np.random.default_rng(
            (s.seed * 1_000_003 + client_key) % (2 ** 63))
        # label distribution: Dirichlet over classes (non-IID)
        label_p = rng.dirichlet(np.full(s.n_classes, s.dirichlet_alpha))
        y = rng.choice(s.n_classes, size=n, p=label_p)
        shift = rng.normal(0.0, s.client_shift, size=(self.feat_dim,))
        x = (s.separation * self._class_means[y]
             + shift[None, :]
             + rng.normal(0.0, s.noise, size=(n, self.feat_dim)))
        if s.label_noise > 0:
            flip = rng.random(n) < s.label_noise
            y = np.where(flip, rng.integers(0, s.n_classes, n), y)
        x = x.astype(np.float32).reshape((n,) + s.shape)
        return x, y.astype(np.int32)


def make_dataset(spec: DataSpec) -> FederatedDataset:
    rng = np.random.default_rng(spec.seed)
    feat_dim = int(np.prod(spec.shape))
    class_means = rng.normal(0.0, 1.0, size=(spec.n_classes, feat_dim))
    class_means /= np.linalg.norm(class_means, axis=1, keepdims=True)
    class_means *= np.sqrt(feat_dim) / 8.0
    sizes = np.clip(
        rng.lognormal(spec.size_log_mean, spec.size_log_std,
                      size=spec.n_train_clients),
        spec.size_min, spec.size_max).astype(np.int64)
    return FederatedDataset(spec=spec, client_sizes=sizes,
                            _class_means=class_means.astype(np.float32))


# ---------------------------------------------------------------------------
# the paper's three datasets (plus reduced variants for CPU benchmarks)
# ---------------------------------------------------------------------------

def speech_command_like(*, reduced: bool = False, seed: int = 0) -> FederatedDataset:
    """35-class 32x32x1 'spectrograms'; 2112 train / 506 test clients."""
    if reduced:
        return make_dataset(DataSpec(
            name="speech_command_like_reduced", n_classes=10, shape=(16, 16, 1),
            n_train_clients=128, n_test_clients=32, seed=seed))
    return make_dataset(DataSpec(
        name="speech_command_like", n_classes=35, shape=(32, 32, 1),
        n_train_clients=2112, n_test_clients=506, seed=seed))


def emnist_like(*, reduced: bool = False, seed: int = 0) -> FederatedDataset:
    """62-class 28x28 handwriting; writer-partitioned 70/30."""
    if reduced:
        return make_dataset(DataSpec(
            name="emnist_like_reduced", n_classes=16, shape=(28 * 28,),
            n_train_clients=128, n_test_clients=32, seed=seed))
    return make_dataset(DataSpec(
        name="emnist_like", n_classes=62, shape=(28 * 28,),
        n_train_clients=2520, n_test_clients=1080, seed=seed))


def cifar100_like(*, reduced: bool = False, seed: int = 0) -> FederatedDataset:
    """100-class 32x32x3; 1200 clients x 50 points (1000 train / 200 test)."""
    spec = DataSpec(
        name="cifar100_like" + ("_reduced" if reduced else ""),
        n_classes=20 if reduced else 100,
        shape=(16, 16, 3) if reduced else (32, 32, 3),
        n_train_clients=100 if reduced else 1000,
        n_test_clients=25 if reduced else 200,
        size_log_mean=np.log(50.0), size_log_std=1e-6,   # fixed 50/client
        size_min=50, size_max=50, seed=seed)
    return make_dataset(spec)
