"""jit'd dispatch wrappers for the Pallas kernels.

On a TPU backend the Pallas kernels run natively; elsewhere (this CPU
container, and any host without Mosaic) they execute in interpret mode for
tests or fall back to the pure-jnp reference paths used by the model zoo.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import ref
from repro.kernels.fed_aggregate import fed_aggregate as _fed_aggregate_pallas
from repro.kernels.fed_reduce import fed_reduce as _fed_reduce_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.rglru_scan import rglru_scan as _rglru_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fed_aggregate(weights, deltas, base=None, *, force_pallas: bool = False,
                  interpret: Optional[bool] = None):
    """Weighted aggregation of participant deltas (server-side hot spot)."""
    if on_tpu() or force_pallas:  # noqa: REPRO003 -- host-side backend dispatch flag, never traced; this wrapper runs eagerly and jits its target
        itp = (not on_tpu()) if interpret is None else interpret
        return _fed_aggregate_pallas(weights, deltas, base, interpret=itp)
    return ref.fed_aggregate_ref(weights, deltas, base)


_fed_reduce_ref_jit = jax.jit(
    ref.fed_reduce_ref,
    static_argnames=("num_segments", "normalize", "leaf_sizes"))


def fed_reduce(weights, rows, segments, num_segments, base=None, *,
               normalize: bool = False, leaf_sizes=None, quant_ref=None,
               quant_enabled=None, force_pallas: bool = False,
               interpret: Optional[bool] = None):
    """Fused segment aggregation of a packed multi-trial cohort: weight
    normalization + optional int8 round trip + segment-sum + per-lane base
    add, one dispatch for all lanes.  Lane t is BIT-identical to a
    standalone ``num_segments=1`` call over that lane's rows (the parity
    contract every sweep engine leans on; see kernels/ref.py)."""
    if on_tpu() or force_pallas:
        itp = (not on_tpu()) if interpret is None else interpret
        return _fed_reduce_pallas(
            weights, rows, segments, num_segments, base,
            normalize=normalize, leaf_sizes=leaf_sizes, quant_ref=quant_ref,
            quant_enabled=quant_enabled, interpret=itp)
    return _fed_reduce_ref_jit(
        weights, rows, segments, num_segments, base, normalize=normalize,
        leaf_sizes=leaf_sizes, quant_ref=quant_ref,
        quant_enabled=quant_enabled)


def flash_attention(q, k, v, *, causal=True, window=None, cap=None,
                    force_pallas: bool = False,
                    interpret: Optional[bool] = None):
    """(B,H,S,D) x (B,Kh,T,D) -> (B,H,S,D)."""
    if on_tpu() or force_pallas:
        itp = (not on_tpu()) if interpret is None else interpret
        return _flash_pallas(q, k, v, causal=causal, window=window, cap=cap,
                             interpret=itp)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   cap=cap)


def rglru_scan(a, b, *, force_pallas: bool = False,
               interpret: Optional[bool] = None):
    """Diagonal linear recurrence (RecurrentGemma mixer)."""
    if on_tpu() or force_pallas:
        itp = (not on_tpu()) if interpret is None else interpret
        return _rglru_pallas(a, b, interpret=itp)
    return ref.rglru_scan_ref(a, b)
