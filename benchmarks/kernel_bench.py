"""Kernel micro-benchmarks: wall time of the jnp reference paths (the CPU
executable analogues; the Pallas kernels themselves target TPU and are
validated in interpret mode by tests)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import BenchSettings, emit
from repro.kernels import ref

KEY = jax.random.PRNGKey(0)


def _time(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6


def main(settings: BenchSettings):
    # fed_aggregate: the per-round server reduction
    m, n = 20, 1_000_000
    w = jnp.full((m,), 1.0 / m)
    d = jax.random.normal(KEY, (m, n))
    agg = jax.jit(ref.fed_aggregate_ref)
    emit("kernel/fed_aggregate_ref_20x1M", _time(agg, w, d),
         f"bytes={d.nbytes}")

    # flash attention reference at a prefill-ish shape
    q = jax.random.normal(KEY, (1, 8, 1024, 64))
    k = jax.random.normal(KEY, (1, 2, 1024, 64))
    v = jax.random.normal(KEY, (1, 2, 1024, 64))
    fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    emit("kernel/flash_attention_ref_1k", _time(fa, q, k, v),
         "flops=%.3g" % (4 * 1024 * 1024 * 8 * 64))

    # rglru scan
    a = jax.random.uniform(KEY, (4, 2048, 512), minval=0.9, maxval=0.999)
    b = jax.random.normal(KEY, (4, 2048, 512))
    rg = jax.jit(ref.rglru_scan_ref)
    emit("kernel/rglru_scan_ref_4x2048x512", _time(rg, a, b),
         f"bytes={a.nbytes * 2}")
