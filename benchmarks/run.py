"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Default scale is REDUCED so the
suite completes on one CPU core; ``--full`` uses paper-scale datasets.

  table2/fig5  model complexity           (paper Table 2 / Fig. 5)
  fig4/table3  M x E measurement sweep    (paper Fig. 4 / Table 3)
  table4       FedTune x 15 preferences   (paper Table 4)
  table5       FedTune x datasets         (paper Table 5)
  table6       FedTune x aggregators      (paper Table 6)
  fig8/fig9    penalty mechanism          (paper Fig. 8 / 9)
  kernels      kernel micro-benchmarks (incl. fused fed_reduce BENCH json)
  roofline     dry-run roofline table     (EXPERIMENTS.md source)
  runtime      heterogeneous runtime: batched cohorts + mode sweep
  sharded_cohort  client-exec backends (sequential|batched|sharded) at
                  M in {16, 64, 256} over the host-local device mesh
  sweep_engine vectorized T-trials-at-once vs T sequential FLServer runs
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated benchmark keys")
    args = ap.parse_args()

    from benchmarks import (async_runtime, beyond_paper,
                            fedtune_aggregators, fedtune_datasets,
                            fedtune_preferences, kernel_bench,
                            measurement_sweep, model_complexity,
                            penalty_study, roofline_report, sharded_cohort,
                            sweep_engine)
    from benchmarks.common import BenchSettings, emit

    settings = BenchSettings(full=args.full, seeds=args.seeds)
    benches = {
        "complexity": lambda: model_complexity.main(settings),
        "sweep": lambda: measurement_sweep.main(settings),
        "preferences": lambda: fedtune_preferences.main(settings),
        "datasets": lambda: fedtune_datasets.main(settings),
        "aggregators": lambda: fedtune_aggregators.main(settings),
        "penalty": lambda: penalty_study.main(settings),
        "beyond": lambda: beyond_paper.main(settings),
        "kernels": lambda: kernel_bench.main(settings),
        "roofline": lambda: roofline_report.main(settings),
        "runtime": lambda: async_runtime.main(settings),
        "sharded_cohort": lambda: sharded_cohort.main(settings),
        "sweep_engine": lambda: sweep_engine.main(settings),
    }
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    for key, fn in benches.items():
        if only and key not in only:
            continue
        t = time.perf_counter()
        try:
            fn()
            emit(f"section/{key}", (time.perf_counter() - t) * 1e6, "ok")
        except Exception as e:  # keep the suite running
            emit(f"section/{key}", (time.perf_counter() - t) * 1e6,
                 f"ERROR:{type(e).__name__}:{str(e)[:120]}")
    emit("total", (time.perf_counter() - t0) * 1e6, "")


if __name__ == "__main__":
    main()
