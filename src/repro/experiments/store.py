"""Append-only JSONL result store with resume-by-trial-key, seed
aggregation, and the paper-style table emitter.

One line per finished trial (the dict from ``TrialResult.to_record``).
Appends are line-atomic enough for the resume contract: a sweep killed
mid-write leaves at most one truncated final line, which ``load`` skips —
so re-invoking the sweep reruns exactly the unfinished trials.

The table emitter reproduces the paper's reporting convention: every
FedTune trial is normalized against its FixedTuner twin (same dataset,
aggregator, seed, M0/E0 — ``baseline_key``) through eq. (6) under the
trial's own preference vector, and the '+x%' numbers are mean +- std over
seeds.  Positive = FedTune reduced the weighted system overhead.  Stores
spanning several fleet profiles, runtime modes, or compression methods
render those as extra column suffixes (``fedavg·stragglers``,
``fedavg·int8``); records from before those axes existed tabulate under
the defaults (homogeneous/sync/uncompressed) instead of KeyError-ing, so
old stores keep resuming and tabulating.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro import obs

from repro.core.costs import SystemCost
from repro.core.preferences import Preference
from repro.experiments.grid import TrialSpec, spec_from_dict


class ResultStore:
    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        # completed-key cache: None until first asked, then maintained by
        # append/clear so admission checks are O(1) instead of a full
        # JSONL re-parse per call (the scheduler asks once per admission)
        self._completed: Optional[set] = None

    # ------------------------------------------------------------------
    def load(self) -> List[dict]:
        """Every valid record; corrupt/truncated lines (a killed writer's
        tail) are skipped, not fatal."""
        if not os.path.exists(self.path):
            return []
        out = []
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    continue
        return out

    def completed_keys(self) -> set:
        """Keys of every ``status == "done"`` record.  The file is parsed
        at most once: the set is cached and kept current by ``append``
        (add) and ``clear`` (invalidate).  Treat the returned set as
        read-only — it IS the cache."""
        if self._completed is None:
            self._completed = {r["key"] for r in self.load()
                               if r.get("status") == "done" and "key" in r}
        return self._completed

    def is_completed(self, key: str) -> bool:
        """O(1) membership against the cached completed-key set — the
        scheduler's per-admission resume check."""
        return key in self.completed_keys()

    def append(self, record: dict):
        t0 = time.perf_counter()
        with open(self.path, "a") as f:
            f.write(json.dumps(record) + "\n")
            f.flush()
            os.fsync(f.fileno())
        if (self._completed is not None
                and record.get("status") == "done" and "key" in record):
            self._completed.add(record["key"])
        if obs.enabled():
            # fsynced-append latency: the store is on every trial's
            # completion path, so a slow disk shows up here first
            obs.registry.observe("store_write_s", time.perf_counter() - t0)

    def clear(self):
        if os.path.exists(self.path):
            os.remove(self.path)
        self._completed = None


# ---------------------------------------------------------------------------
# aggregation + table emission
# ---------------------------------------------------------------------------

def _spec_of(record: dict) -> TrialSpec:
    """The record's TrialSpec, tolerant of legacy rows: fields a record
    predates (e.g. ``het`` before fleet-profile axes existed) fall back to
    the TrialSpec defaults instead of KeyError-ing — resuming or tabulating
    an old store must never crash on schema growth."""
    return spec_from_dict(record.get("spec") or {})


def improvement_pct(record: dict, baseline: dict) -> float:
    """The paper's '+x%' convention: -100 * I(fixed, tuned) under the tuned
    trial's preference (positive = FedTune reduced the weighted overhead)."""
    pref = Preference(*_spec_of(record).preference)
    tuned = SystemCost(*record["cost"])
    fixed = SystemCost(*baseline["cost"])
    return -100.0 * tuned.weighted_relative_to(fixed, pref)


def _cell_id(spec: TrialSpec) -> tuple:
    """Table cell identity: every result-bearing axis except seed (the
    aggregation dimension) and tuner (the comparison dimension).  A store
    holding e.g. both a stragglers and a homogeneous sweep must NOT mix
    them into one cell as if they were extra seeds."""
    return (spec.dataset, spec.aggregator, spec.preference, spec.m0,
            spec.e0, spec.mode, spec.rounds, spec.reduced, spec.het,
            spec.batch_size, spec.target_accuracy, spec.lr,
            spec.eval_points, spec.prox_mu, spec.compression)


def pair_with_baselines(records: Iterable[dict]) -> List[dict]:
    """Attach each fedtune record's FixedTuner twin (matched by
    ``baseline_key``) and its improvement; records without a baseline are
    dropped (a partial sweep's fedtune rows can't be normalized yet)."""
    records = list(records)
    by_key: Dict[str, dict] = {r["key"]: r for r in records
                               if r.get("status") == "done" and "key" in r}
    out = []
    for r in records:
        if r.get("status") != "done" or _spec_of(r).tuner != "fedtune":
            continue
        base = by_key.get(r.get("baseline_key"))
        if base is None:
            continue
        out.append({**r, "improvement": improvement_pct(r, base)})
    return out


def aggregate_over_seeds(paired: Iterable[dict]) -> List[dict]:
    """Group paired fedtune records by table cell (all axes except seed)
    and report mean +- std of improvement / accuracy / rounds."""
    cells: Dict[tuple, List[dict]] = {}
    for r in paired:
        spec = _spec_of(r)
        cells.setdefault(_cell_id(spec), []).append(r)
    out = []
    for cell, rs in sorted(cells.items(), key=lambda kv: repr(kv[0])):
        imps = np.array([r["improvement"] for r in rs], np.float64)
        accs = np.array([r["final_accuracy"] for r in rs], np.float64)
        rounds = np.array([r["rounds"] for r in rs], np.float64)
        out.append({
            "dataset": cell[0], "aggregator": cell[1],
            "preference": list(cell[2]), "m0": cell[3], "e0": cell[4],
            "mode": cell[5], "het": cell[8], "compression": cell[14],
            "n_seeds": len(rs),
            "improvement_mean": float(imps.mean()),
            "improvement_std": float(imps.std()),
            "accuracy_mean": float(accs.mean()),
            "rounds_mean": float(rounds.mean()),
        })
    return out


def _fmt_pref(p) -> str:
    return "(" + ",".join(f"{v:g}" for v in p) + ")"


def _column_of(row: dict, multi_het: bool, multi_mode: bool,
               multi_comp: bool = False) -> str:
    """Column identity for one aggregated cell: the aggregator, widened by
    runtime-mode, fleet-profile, and compression suffixes when the store
    spans those axes (e.g. ``fedavg·async``, ``fedavg·stragglers``,
    ``fedavg·int8``) so a mode/het/compression sweep renders as
    side-by-side columns instead of collapsing into one.  Legacy rows
    written before an axis existed default to that axis's default value
    (homogeneous / sync / no compression)."""
    col = row["aggregator"]
    if multi_mode and row.get("mode"):
        col += f"·{row['mode']}"
    if multi_het:
        col += f"·{row.get('het') or 'homogeneous'}"
    if multi_comp:
        col += f"·{row.get('compression') or 'none'}"
    return col


def paper_table(records: Iterable[dict], *,
                title: Optional[str] = None) -> str:
    """Markdown tables in the paper's layout: one section per dataset, rows
    = preference vectors, columns = aggregators, cells = mean +- std
    overhead reduction of FedTune vs the FixedTuner baseline.  When the
    store spans several fleet profiles (``SweepSpec.hets``) or runtime
    modes, the aggregator columns split per profile/mode
    (``fedavg·stragglers``, ``fedavg·async``, ...); legacy records written
    before those axes existed default to homogeneous/sync rather than
    erroring."""
    agg = aggregate_over_seeds(pair_with_baselines(records))
    if not agg:
        return "(no fedtune/baseline pairs to tabulate yet)"
    lines = []
    if title:
        lines.append(f"## {title}")
    datasets = sorted({a["dataset"] for a in agg})
    for ds in datasets:
        rows = [a for a in agg if a["dataset"] == ds]
        multi_het = len({a.get("het") or "homogeneous" for a in rows}) > 1
        multi_mode = len({a.get("mode") or "sync" for a in rows}) > 1
        multi_comp = len({a.get("compression") or "none"
                          for a in rows}) > 1
        cols = sorted({_column_of(a, multi_het, multi_mode, multi_comp)
                       for a in rows})
        prefs = []
        for a in rows:
            key = tuple(a["preference"])
            if key not in prefs:
                prefs.append(key)
        lines.append(f"\n### {ds} — FedTune overhead reduction vs "
                     "FixedTuner (+ = better)")
        lines.append("| preference (a,b,g,d) | " + " | ".join(cols) + " |")
        lines.append("|---" * (len(cols) + 1) + "|")
        for p in prefs:
            cells = []
            for col in cols:
                m = [a for a in rows
                     if tuple(a["preference"]) == p
                     and _column_of(a, multi_het, multi_mode,
                                    multi_comp) == col]
                if not m:
                    cells.append("—")
                    continue
                parts = []
                for a in m:   # one entry per remaining (M0, E0) grid point
                    v = (f"{a['improvement_mean']:+.2f}"
                         f"±{a['improvement_std']:.2f}%")
                    if len(m) > 1:
                        v += f" @({a['m0']},{a['e0']:g})"
                        if not multi_het and (
                                a.get("het") or "homogeneous") != "homogeneous":
                            v += f"/{a['het']}"
                    parts.append(v)
                cells.append("; ".join(parts))
            lines.append(f"| {_fmt_pref(p)} | " + " | ".join(cells) + " |")
    return "\n".join(lines)
