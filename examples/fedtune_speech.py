"""End-to-end driver: the paper's headline experiment at reduced scale.

Trains ResNet-10 on the speech-command-like federated dataset (2112-client
statistics at full scale; reduced here for CPU) for a few hundred rounds,
comparing fixed (M, E) against FedTune for a chosen preference.

    PYTHONPATH=src python examples/fedtune_speech.py [--full] [--rounds N]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.paper_models import ResNetConfig
from repro.core import CostModel, FedTune, FedTuneConfig, Preference
from repro.core.tuner import HyperParams
from repro.data import speech_command_like
from repro.federated import FLConfig, FLServer, get_aggregator
from repro.models import build_model
from repro.optim.optimizers import get_optimizer


def run(tuner, label, args, model, dataset, pref):
    n_params = sum(p.size for p in jax.tree.leaves(
        model.init(jax.random.PRNGKey(0))))
    server = FLServer(
        model, dataset, get_aggregator("fedavg"),
        get_optimizer("sgd", 0.05, momentum=0.9),
        CostModel(flops_per_example=model.flops_per_example,
                  param_count=n_params),
        FLConfig(m=5, e=2, batch_size=5, target_accuracy=args.target,
                 max_rounds=args.rounds, log_every=args.rounds // 10 or 1),
        tuner=tuner)
    print(f"\n=== {label} ===")
    res = server.run()
    c = res.total_cost
    print(f"{label}: rounds={res.rounds} acc={res.final_accuracy:.3f} "
          f"M={res.final_m} E={res.final_e:g}")
    print(f"  CompT={c.comp_t:.3g} TransT={c.trans_t:.3g} "
          f"CompL={c.comp_l:.3g} TransL={c.trans_l:.3g}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-scale dataset (2112 clients, 35 classes)")
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--target", type=float, default=0.5)
    args = ap.parse_args()

    dataset = speech_command_like(reduced=not args.full)
    cfg = ResNetConfig(
        name="resnet10", stage_blocks=(1, 1, 1, 1), width=8,
        n_classes=dataset.spec.n_classes,
        in_channels=dataset.spec.shape[-1],
        image_size=dataset.spec.shape[0])
    model = build_model(cfg)
    pref = Preference(0.25, 0.25, 0.25, 0.25)

    fixed = run(None, "fixed (M=5, E=2)", args, model, dataset, pref)
    tuner = FedTune(FedTuneConfig(preference=pref), HyperParams(5, 2))
    tuned = run(tuner, "FedTune", args, model, dataset, pref)

    gain = -100.0 * tuned.total_cost.weighted_relative_to(
        fixed.total_cost, pref)
    print(f"\nFedTune weighted-overhead gain vs fixed: {gain:+.2f}% "
          f"(paper reports +22.48% avg at full scale)")


if __name__ == "__main__":
    main()
