"""Wall-clock phase counters — back-compat shim over ``repro.obs``.

Historically this module held three module-global dicts; the counters now
live in the observability metrics registry
(``repro.obs.metrics.registry``) so phase timings, span traces and sweep
metrics share one store and one ``reset()``.  The public surface here is
unchanged — ``timed``/``add``/``seconds``/``calls``/``snapshot``/``reset``
keep working — because ``benchmarks/sweep_engine.py`` and the federated
layers call it on every round.

Semantics are as before: counters accumulate host wall-clock around the
timed block.  JAX dispatch is asynchronous, so a phase's device time is
attributed to the phase that eventually blocks on its results — both
training and evaluation blocks end in host conversions (``np.asarray`` /
``float``), which keeps the train/eval/other split honest at benchmark
granularity.  Not thread-safe; the sweep engines are single-threaded.

Note ``reset()`` clears the *whole* registry (phases and observability
metrics), matching the benchmark's expectation that a reset starts a
clean measurement window.
"""

from __future__ import annotations

from typing import Dict

from repro.obs.metrics import registry as _registry


def add(name: str, seconds: float):
    _registry.phase_add(name, seconds)


def timed(name: str):
    """Accumulate the block's wall-clock under ``name`` (context manager)."""
    return _registry.phase(name)


def seconds(name: str) -> float:
    return _registry.phase_seconds(name)


def calls(name: str) -> int:
    return _registry.phase_call_count(name)


def snapshot() -> Dict[str, float]:
    return _registry.phase_snapshot()


def calls_snapshot() -> Dict[str, int]:
    """Per-phase call counts — exported alongside seconds in BENCH json."""
    return _registry.phase_calls_snapshot()


def reset():
    _registry.reset()
