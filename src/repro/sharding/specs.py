"""Partition rules: logical tensor dims -> mesh axes.

Strategy (MaxText-style FSDP + TP, adapted for federated rounds):
  * "residual" (d_model-like param dims)  -> "data"  (FSDP; the round-start
    all-gather IS the FL model download)
  * "ff" / "heads" / "expert" / "vocab"   -> "model" (tensor / expert parallel)
  * "batch" activations                   -> ("pod", "data")
  * pods replicate params: each pod is an FL silo; the cross-pod weighted
    psum in fl_train_step is the FL aggregation (upload).

Parameter tensors are matched by their *name* (the last pytree dict key),
which the model zoo keeps globally consistent.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical dim names per parameter tensor name (by rank-matched tuple)
_PARAM_LOGICAL: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings / heads
    "embed": ("vocab", "residual"),
    "lm_head": ("residual", "vocab"),
    "frontend_proj": (None, "residual"),
    # attention
    "wq": ("residual", "heads", None),
    "wk": ("residual", "kv_heads", None),
    "wv": ("residual", "kv_heads", None),
    "wo": ("heads", None, "residual"),
    "bq": ("heads", None),
    "bk": ("kv_heads", None),
    "bv": ("kv_heads", None),
    # dense mlp
    "w_gate": ("residual", "ff"),
    "w_up": ("residual", "ff"),
    "w_down": ("ff", "residual"),
    # moe (rank-3 variants of the same names handled by rank dispatch below)
    "router": ("residual", "expert"),
    # rglru
    "w_in": ("residual", "ff"),
    "w_gate_branch": ("residual", "ff"),
    "conv_w": (None, "ff"),
    "w_out": ("ff", "residual"),
    # xlstm
    "w_z": ("residual", "ff"),
    "w_q": ("heads", None, None),
    "w_k": ("heads", None, None),
    "w_v": ("heads", None, None),
    "w_i": ("ff", None),
    "w_f": ("ff", None),
    "w_z_gate": ("residual", "residual_out"),
    "r_z": ("heads", None, None),
    "r_i": ("heads", None, None),
    "r_f": ("heads", None, None),
    "r_o": ("heads", None, None),
    "w_o": ("residual", "residual_out"),
    # resnet / misc
    "head_w": (None, None),
}

_MOE_LOGICAL = {  # rank-3 moe expert weights (distinct names: we_*)
    "we_gate": ("expert", "residual", "moe_inner"),
    "we_up": ("expert", "residual", "moe_inner"),
    "we_down": ("expert", "moe_inner", "residual"),
}

# logical -> mesh translation tables ---------------------------------------

def train_rules(multi_pod: bool) -> Dict[str, Any]:
    return {
        # params
        "residual": "data",
        "residual_out": None,
        "ff": "model",
        "heads": "model",
        "kv_heads": "model",
        "expert": "model",
        "vocab": "model",
        # activations
        "batch": ("pod", "data") if multi_pod else "data",
        "seq": "model",   # sequence parallelism for the residual stream
        "embed": None,
        # expert-buffer capacity / flat dispatch dims follow the batch axes
        "moe_capacity": ("pod", "data") if multi_pod else "data",
        "moe_tokens": ("pod", "data") if multi_pod else "data",
        "moe_inner": None,   # expert d_ff dim: sharded only at decode (H2b)
    }


def decode_rules(multi_pod: bool, *, shard_seq: bool = False) -> Dict[str, Any]:
    r = train_rules(multi_pod)
    # weights stay 2D-sharded ("data" x "model") at serve time as well:
    # 100B+ checkpoints exceed HBM under model-axis-only sharding.
    if shard_seq:                 # long-context: batch too small, shard cache seq
        r["batch"] = None
        r["cache_seq"] = (("pod", "data", "model") if multi_pod
                          else ("data", "model"))
    else:
        # KV cache is sequence-sharded over the model axis (kv-head counts
        # rarely divide 16; seq always does).  Attention over the sharded
        # cache becomes a partial-softmax + psum, which GSPMD derives.
        r["cache_seq"] = "model"
    r["seq"] = None               # no sequence parallelism at decode
    return r


LOGICAL_RULES = train_rules(False)


# ---------------------------------------------------------------------------
# param / cache / input specs
# ---------------------------------------------------------------------------

def _translate(logical: Tuple[Optional[str], ...], rules: Dict[str, Any]) -> P:
    used: set = set()
    axes = []
    for name in logical:
        ax = rules.get(name) if name else None
        if isinstance(ax, tuple):
            ax = tuple(a for a in ax if a not in used) or None
        elif ax in used:
            ax = None
        if ax is not None:
            used.update(ax if isinstance(ax, tuple) else (ax,))
        axes.append(ax)
    return P(*axes)


def _spec_for_param(path, leaf, rules: Dict[str, Any]) -> P:
    name = None
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            name = entry.key
            break
    rank = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
    logical = None
    stacked = False  # scan-over-layers adds a leading (n_cycles) dim
    if name in _MOE_LOGICAL and rank in (3, 4):
        logical = _MOE_LOGICAL[name]
        stacked = rank == 4
    elif name in _PARAM_LOGICAL:
        want = len(_PARAM_LOGICAL[name])
        if rank == want:
            logical = _PARAM_LOGICAL[name]
        elif rank == want + 1:
            logical = _PARAM_LOGICAL[name]
            stacked = True
    if logical is None:
        return P()  # replicate (norms, biases, small tensors)
    if stacked:
        logical = (None,) + tuple(logical)
    return _translate(logical, rules)


def fit_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes that do not evenly divide the tensor dim (explicit jit
    argument shardings require exact divisibility; replication is the safe
    fallback for small dims like 4 kv heads on a 16-way model axis)."""
    axes = []
    for d, ax in enumerate(spec):
        if ax is None:
            axes.append(None)
            continue
        ax_tuple = ax if isinstance(ax, tuple) else (ax,)
        keep = []
        prod = 1
        for a in ax_tuple:
            size = mesh.shape[a]
            if shape[d] % (prod * size) == 0:
                keep.append(a)
                prod *= size
        axes.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    # pad trailing dims
    axes += [None] * (len(shape) - len(axes))
    return P(*axes[:len(shape)])


def param_specs(params, rules: Dict[str, Any]):
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for_param(path, leaf, rules), params)


def param_shardings(params, mesh: Mesh, rules: Dict[str, Any]):
    specs = param_specs(params, rules)
    return jax.tree.map(
        lambda leaf, spec: NamedSharding(mesh, fit_spec(spec, leaf.shape, mesh)),
        params, specs)


def cache_specs(cache, rules: Dict[str, Any]):
    """Specs for a decode cache pytree (KVCache / recurrent states)."""
    batch_ax = rules.get("batch")
    seq_ax = rules.get("cache_seq")
    model_ax = rules.get("heads")

    def base_spec(field, rank):
        if field in ("k", "v") and rank == 4:      # (B, C, Kh, D)
            return P(batch_ax, seq_ax,
                     model_ax if seq_ax is None else None, None)
        if field == "slot_pos" and rank == 1:
            return P(seq_ax if seq_ax is not None else None)
        if field == "enc_out" and rank == 3:
            return P(batch_ax, None, None)
        if field == "h" and rank == 2:             # rglru (B, W)
            return P(batch_ax, model_ax)
        if field == "conv_tail" and rank == 3:
            return P(batch_ax, None, model_ax)
        if field == "C" and rank == 4:             # mlstm (B, H, hd, hd)
            return P(batch_ax, model_ax, None, None)
        if field == "n" and rank == 3:
            return P(batch_ax, model_ax, None)
        if field == "m" and rank == 2:
            return P(batch_ax, model_ax)
        if rank == 2:                              # slstm c/n/h (B, d)
            return P(batch_ax, model_ax)
        return None

    def spec(path, leaf):
        rank = leaf.ndim
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = entry.key
                break
        # KVCache fields are namedtuple attrs -> GetAttrKey
        attr = None
        for entry in reversed(path):
            if hasattr(entry, "name"):
                attr = entry.name
                break
        field = attr or name
        s = base_spec(field, rank)
        if s is not None:
            return s
        s = base_spec(field, rank - 1)  # scan-stacked (+1 leading layer dim)
        if s is not None:
            return P(*((None,) + tuple(s)))
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache)


def clients_spec(rank: int, client_dim: int, axis: str = "clients") -> P:
    """PartitionSpec placing a cohort tensor's client dim on the ``clients``
    mesh axis with everything else replicated — the layout contract for the
    (T, M, B, ...) stacked cohort arrays of runtime/sharded.py."""
    axes: list = [None] * rank
    axes[client_dim] = axis
    return P(*axes)


def input_specs_sharding(kind: str, rules: Dict[str, Any]):
    """Specs for batch inputs by input name."""
    batch_ax = rules.get("batch")

    def spec(name: str, rank: int) -> P:
        if rank == 0:
            return P()
        axes = [batch_ax] + [None] * (rank - 1)
        return P(*axes)

    return spec
