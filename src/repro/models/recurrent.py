"""RecurrentGemma / Griffin recurrent block: causal conv1d + RG-LRU.

The RG-LRU recurrence  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
is a diagonal linear recurrence, so training/prefill uses
``jax.lax.associative_scan`` (TPU-parallel, log-depth); decode carries (h,
conv tail) state.  ``kernels/rglru_scan`` is the Pallas TPU version of the
same scan; this module is also its reference.

Simplification vs. the Griffin paper (documented in DESIGN.md): the
recurrence/input gates use per-channel (diagonal) weights rather than
block-diagonal linear maps.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init
from repro.sharding.ctx import logical_constraint

_C = 8.0  # Griffin's recurrence sharpness constant


def init_rglru_params(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    w = cfg.lru_width or d
    cw = cfg.conv1d_width
    ks = jax.random.split(key, 5)
    return {
        "w_in": dense_init(ks[0], (d, w), dtype),
        "w_gate_branch": dense_init(ks[1], (d, w), dtype),
        "conv_w": dense_init(ks[2], (cw, w), dtype, fan_in=cw),
        "conv_b": jnp.zeros((w,), dtype),
        # RG-LRU gates (diagonal) + Lambda
        "a_gate_w": jnp.zeros((w,), dtype),
        "a_gate_b": jnp.zeros((w,), dtype),
        "x_gate_w": jnp.zeros((w,), dtype),
        "x_gate_b": jnp.zeros((w,), dtype),
        # Lambda init so that a = sigmoid(lambda) in [0.9, 0.999]
        "lam": jnp.asarray(
            jnp.log(jnp.expm1(jnp.linspace(0.9, 0.999, w)) /  # softplus^-1
                    (1 - jnp.linspace(0.9, 0.999, w))), dtype),
        "w_out": dense_init(ks[4], (w, d), dtype, fan_in=w),
    }


def _causal_conv(x, conv_w, conv_b):
    """x: (B,S,W); width-cw causal depthwise conv via shifted adds."""
    cw = conv_w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(cw):
        shifted = x if i == 0 else jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * conv_w[cw - 1 - i]
    return out + conv_b


def _gates(params, u):
    """u: conv output (..., W). Returns (a, beta*i*u) recurrence coeffs."""
    r = jax.nn.sigmoid(u * params["a_gate_w"] + params["a_gate_b"])
    i = jax.nn.sigmoid(u * params["x_gate_w"] + params["x_gate_b"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * u


def rglru_scan(a, b, h0=None):
    """Diagonal linear recurrence h_t = a_t h_{t-1} + b_t over axis 1.

    a, b: (B, S, W).  Uses associative_scan (log-depth, TPU-parallel)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


class RGLRUState(NamedTuple):
    h: jax.Array          # (B, W) recurrent state
    conv_tail: jax.Array  # (B, cw-1, W) last conv inputs


def init_rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> RGLRUState:
    w = cfg.lru_width or cfg.d_model
    return RGLRUState(
        h=jnp.zeros((batch, w), dtype),
        conv_tail=jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype),
    )


def rglru_block(params, x, *, use_kernel: bool = True):
    """Full-sequence Griffin recurrent block. x: (B,S,d) -> (B,S,d)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_gate_branch"]))
    u = jnp.einsum("bsd,dw->bsw", x, params["w_in"])
    u = logical_constraint(u, ("batch", None, "ff"))
    u = _causal_conv(u, params["conv_w"], params["conv_b"])
    a, b = _gates(params, u)
    h = rglru_scan(a.astype(jnp.float32), b.astype(jnp.float32))
    h = (h.astype(x.dtype) * gate)
    return jnp.einsum("bsw,wd->bsd", h, params["w_out"])


def rglru_decode_step(params, x, state: RGLRUState):
    """One-token decode. x: (B,1,d)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_gate_branch"]))
    u = jnp.einsum("bsd,dw->bsw", x, params["w_in"])            # (B,1,W)
    conv_in = jnp.concatenate([state.conv_tail, u], axis=1)     # (B,cw,W)
    cw = params["conv_w"].shape[0]
    u_c = jnp.einsum("bcw,cw->bw", conv_in[:, -cw:], params["conv_w"])
    u_c = (u_c + params["conv_b"])[:, None]                     # (B,1,W)
    a, b = _gates(params, u_c)
    h_new = a[:, 0] * state.h + b[:, 0]
    out = (h_new[:, None].astype(x.dtype) * gate)
    y = jnp.einsum("bsw,wd->bsd", out, params["w_out"])
    return y, RGLRUState(h=h_new, conv_tail=conv_in[:, 1:])
