"""Tuner API: the hook the FL server calls after every round.

A tuner observes (accuracy, per-round and cumulative SystemCost) and may
return new hyper-parameters (M, E).  ``FixedTuner`` is the paper's baseline
(constant M, E); ``FedTune`` (core/fedtune.py) is the paper's contribution.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.costs import SystemCost


@dataclass
class HyperParams:
    m: int  # participants per round
    e: float  # local training passes

    def clamped(self, m_max: int, e_max: float) -> "HyperParams":
        return HyperParams(m=int(min(max(self.m, 1), m_max)),
                           e=float(min(max(self.e, 1.0), e_max)))


class Tuner:
    """Base: never changes anything."""

    def on_round(self, round_idx: int, accuracy: float,
                 round_cost: SystemCost, total_cost: SystemCost,
                 current: HyperParams) -> HyperParams:
        return current


class FixedTuner(Tuner):
    """The paper's baseline: fixed (M, E) for the whole training."""
