#!/usr/bin/env python
"""Docs link checker: every relative markdown link in README.md and
docs/*.md must resolve to a real file (or directory) in the repo.

External links (http/https/mailto) and pure in-page anchors (#...) are
skipped — this guards the internal doc graph, not the internet.  A link
with an anchor (``path#section``) is checked on its path part.

Usage: python tools/check_docs_links.py [repo_root]
Exit status 0 when every link resolves; 1 otherwise (broken links listed
on stderr).  Run by the CI ``docs`` job and by tests/test_docs.py.
"""

from __future__ import annotations

import glob
import os
import re
import sys

# [text](target) — target captured up to the first unescaped ')'; images
# (![alt](target)) match too, which is what we want
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def doc_files(root: str):
    """The markdown files whose links we guarantee: README.md + docs/."""
    files = []
    for pattern in ("README.md", "docs/*.md", "docs/**/*.md"):
        files.extend(glob.glob(os.path.join(root, pattern), recursive=True))
    return sorted(set(files))


def links_in(path: str):
    with open(path, encoding="utf-8") as f:
        text = f.read()
    # fenced code blocks routinely contain [x](y)-shaped shell/python text
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    return _LINK_RE.findall(text)


def broken_links(root: str):
    """[(doc, link, resolved_path), ...] for every unresolvable link."""
    out = []
    for doc in doc_files(root):
        for link in links_in(doc):
            if link.startswith(_SKIP_PREFIXES):
                continue
            target = link.split("#", 1)[0]
            if not target:
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(doc), target))
            if not os.path.exists(resolved):
                out.append((os.path.relpath(doc, root), link, resolved))
    return out


def main(root: str = ".") -> int:
    docs = doc_files(root)
    if not docs:
        print(f"check_docs_links: no markdown files under {root!r}",
              file=sys.stderr)
        return 1
    broken = broken_links(root)
    n_links = sum(1 for d in docs for _l in links_in(d))
    if broken:
        for doc, link, resolved in broken:
            print(f"BROKEN {doc}: ({link}) -> {resolved}", file=sys.stderr)
        print(f"check_docs_links: {len(broken)} broken of {n_links} links "
              f"in {len(docs)} files", file=sys.stderr)
        return 1
    print(f"check_docs_links: OK — {n_links} links in {len(docs)} files "
          "all resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1] if len(sys.argv) > 1 else "."))
