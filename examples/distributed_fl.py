"""Distributed FL round on a (small) mesh: demonstrates the datacenter
execution path — the same ``fl_train_step`` the 256/512-chip dry-run lowers,
actually EXECUTED here on host devices with a reduced architecture.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/distributed_fl.py --arch gemma2-2b
"""

import argparse
import os
import sys

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.configs.shapes import InputShape
from repro.launch.steps import make_fl_train_step
from repro.models import stacked as stacked_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="gemma2-2b")
    ap.add_argument("--rounds", type=int, default=3)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), n_layers=4)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    shape = InputShape("mini_train", seq_len=64, global_batch=8, kind="train")

    jit_fn, (p_struct, m_struct, b_struct) = make_fl_train_step(
        cfg, mesh, shape, dtype=jnp.float32, lr=1e-2)

    key = jax.random.PRNGKey(0)
    with mesh:
        params = stacked_mod.init_params_stacked(cfg, key)
        momentum = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)
        print(f"arch={args.arch} (reduced)  mesh=4x2  "
              f"params={sum(x.size for x in jax.tree.leaves(params)):,}")
        for r in range(args.rounds):
            batch = {
                "tokens": jax.random.randint(
                    jax.random.fold_in(key, r), (8, 64), 0, cfg.vocab_size),
                "labels": jax.random.randint(
                    jax.random.fold_in(key, r), (8, 64), 0, cfg.vocab_size),
                # FedAvg weights: 8 participant slots with unequal n_k
                "weight": jnp.asarray([1, 2, 1, 4, 1, 2, 3, 2], jnp.float32),
            }
            if cfg.frontend is not None:
                batch["frontend"] = jax.random.normal(
                    key, (8, cfg.frontend.seq_len, cfg.frontend.feature_dim))
            params, momentum, loss, metrics = jit_fn(params, momentum, batch)
            print(f"  round {r}: weighted FL loss={float(loss):.4f} "
                  f"acc={float(metrics['acc']):.3f}")
    print("distributed FL round executed (the dry-run lowers this exact "
          "step on the 16x16 and 2x16x16 production meshes)")


if __name__ == "__main__":
    main()
