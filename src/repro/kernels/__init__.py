from repro.kernels.ops import fed_aggregate, flash_attention, rglru_scan  # noqa: F401
