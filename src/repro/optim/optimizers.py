"""Pure-pytree optimizers (no optax in this container).

Each factory returns an object with
  init(params) -> state
  update(grads, state, params) -> (updates, new_state)   # updates are ADDED
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]


def _zeros_like(params):
    return jax.tree.map(jnp.zeros_like, params)


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        return {"mu": _zeros_like(params)} if momentum else {}

    def update(grads, state, params):
        del params
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g,
                              state["mu"], grads)
            updates = jax.tree.map(lambda m: -lr * m, mu)
            return updates, {"mu": mu}
        return jax.tree.map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def adagrad(lr: float, eps: float = 1e-7) -> Optimizer:
    def init(params):
        return {"v": _zeros_like(params)}

    def update(grads, state, params):
        del params
        v = jax.tree.map(lambda v_, g: v_ + g * g, state["v"], grads)
        updates = jax.tree.map(
            lambda g, v_: -lr * g / (jnp.sqrt(v_) + eps), grads, v)
        return updates, {"v": v}

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    def init(params):
        return {"m": _zeros_like(params), "v": _zeros_like(params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        del params
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                         state["v"], grads)
        mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
        vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
        updates = jax.tree.map(
            lambda m_, v_: -lr * (m_ * mhat_scale)
            / (jnp.sqrt(v_ * vhat_scale) + eps), m, v)
        return updates, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def get_optimizer(name: str, lr: float, **kw) -> Optimizer:
    return {"sgd": sgd, "adagrad": adagrad, "adam": adam}[name](lr, **kw)
