"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels import ops as kernel_ops
from repro.kernels.fed_aggregate import fed_aggregate
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("m,n", [(1, 256), (4, 1000), (16, 8192), (50, 4097)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fed_aggregate_sweep(m, n, dtype):
    ks = jax.random.split(KEY, 3)
    w = jax.random.uniform(ks[0], (m,), jnp.float32)
    w = w / w.sum()
    d = jax.random.normal(ks[1], (m, n)).astype(dtype)
    base = jax.random.normal(ks[2], (n,)).astype(dtype)
    got = fed_aggregate(w, d, base, interpret=True)
    want = ref.fed_aggregate_ref(w, d, base)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_fed_aggregate_is_weighted_mean():
    # aggregating identical deltas with normalized weights is identity
    d = jnp.ones((5, 100)) * 3.0
    w = jnp.full((5,), 0.2)
    got = fed_aggregate(w, d, interpret=True)
    np.testing.assert_allclose(np.asarray(got), 3.0, rtol=1e-6)


@pytest.mark.parametrize("b,h,kh,s,d", [
    (1, 2, 1, 128, 32), (2, 4, 2, 256, 64), (1, 4, 4, 256, 128),
])
@pytest.mark.parametrize("window,cap", [
    (None, None), (64, None), (None, 50.0), (96, 30.0),
])
def test_flash_attention_sweep(b, h, kh, s, d, window, cap):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, kh, s, d))
    v = jax.random.normal(ks[2], (b, kh, s, d))
    got = flash_attention(q, k, v, window=window, cap=cap,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtype(dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(dtype)
    got = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,t,w", [(1, 128, 128), (2, 256, 128),
                                   (4, 128, 512), (3, 192, 384)])
def test_rglru_scan_sweep(b, t, w):
    ks = jax.random.split(KEY, 2)
    a = jax.random.uniform(ks[0], (b, t, w), minval=0.5, maxval=0.999)
    x = jax.random.normal(ks[1], (b, t, w)) * 0.1
    got = rglru_scan(a, x, block_b=1, block_w=128, chunk_t=64, interpret=True)
    want = ref.rglru_scan_ref(a, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_rglru_scan_decay_property():
    """With b=0 everywhere, h stays 0; with a=0, h_t = b_t."""
    a = jnp.full((1, 64, 128), 0.9)
    z = jnp.zeros((1, 64, 128))
    out = rglru_scan(a, z, chunk_t=32, block_b=1, block_w=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out), 0.0)
    b = jax.random.normal(KEY, (1, 64, 128))
    out2 = rglru_scan(jnp.zeros_like(b), b, chunk_t=32, block_b=1,
                      block_w=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(b), rtol=1e-6)


# ---------------------------------------------------------------------------
# fed_reduce: fused segment aggregation (normalize + int8 round trip +
# segment-sum + base), PR-10.  The contract under test is twofold:
#   * Pallas kernel == the jitted jnp reference, bit for bit (both are
#     production dispatch targets of kernels/ops.fed_reduce);
#   * packing invariance — lane t of a T-segment call equals a standalone
#     T=1 call over that lane's rows, bit for bit (what lets the sweep
#     engines fuse T trials into one dispatch while staying parity-pinned
#     against the one-trial-at-a-time FLServer).
# ---------------------------------------------------------------------------

def _reduce_case(m, n, t, seed, *, interleave=False, zero_w=0):
    rng = np.random.default_rng(seed)
    rows = jnp.asarray(rng.standard_normal((m, n)).astype(np.float32))
    w = jnp.asarray(rng.uniform(1.0, 100.0, m).astype(np.float32))
    if zero_w:
        w = w.at[jnp.asarray(rng.choice(m, zero_w, replace=False))].set(0.0)
    if interleave:
        seg = jnp.asarray(rng.integers(0, t, m).astype(np.int32))
    else:
        seg = jnp.asarray(np.sort(rng.integers(0, t, m)).astype(np.int32))
    base = jnp.asarray(rng.standard_normal((t, n)).astype(np.float32))
    return w, rows, seg, base


@pytest.mark.parametrize("m,n,t", [(1, 256, 1), (7, 300, 3), (16, 1024, 4),
                                   (33, 4097, 8)])
@pytest.mark.parametrize("mode", ["plain", "normalize", "base", "quant"])
def test_fed_reduce_pallas_matches_ref_bitwise(m, n, t, mode):
    """Interpret-mode Pallas == jitted reference, bit for bit, in every
    fusion mode — including non-pow2 row counts and column tails (the
    kernel pads N to its block and M/T to pow2 internally)."""
    w, rows, seg, base = _reduce_case(m, n, t, seed=m * 1000 + n)
    kw = {}
    if mode == "normalize":
        kw["normalize"] = True
    if mode == "base":
        kw = {"normalize": True}
    if mode == "quant":
        kw = {"normalize": True, "leaf_sizes": (n // 3, n - n // 3),
              "quant_ref": base, "quant_enabled": jnp.ones(m, bool)}
    b = base if mode in ("base", "quant") else None
    got = kernel_ops.fed_reduce(w, rows, seg, t, b,
                                force_pallas=True, interpret=True, **kw)
    want = kernel_ops.fed_reduce(w, rows, seg, t, b, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("interleave", [False, True])
@pytest.mark.parametrize("quant", [False, True])
def test_fed_reduce_packing_invariance(interleave, quant):
    """Lane t of a fused T-segment call == a standalone T=1 call over that
    lane's rows in pack order, bit for bit — even when segments are
    interleaved rather than contiguous."""
    m, n, t = 24, 513, 5
    w, rows, seg, base = _reduce_case(m, n, t, seed=42,
                                      interleave=interleave)
    kw = dict(normalize=True)
    if quant:
        kw.update(leaf_sizes=(200, n - 200), quant_ref=base,
                  quant_enabled=jnp.ones(m, bool))
    fused = kernel_ops.fed_reduce(w, rows, seg, t, base, **kw)
    segs = np.asarray(seg)
    for s in range(t):
        idx = np.nonzero(segs == s)[0]
        kw1 = dict(normalize=True)
        if quant:
            kw1.update(leaf_sizes=(200, n - 200),
                       quant_ref=base[s][None],
                       quant_enabled=jnp.ones(len(idx), bool))
        if len(idx) == 0:
            # empty segment: base passes through untouched
            np.testing.assert_array_equal(np.asarray(fused[s]),
                                          np.asarray(base[s]))
            continue
        alone = kernel_ops.fed_reduce(
            w[idx], rows[idx], jnp.zeros(len(idx), jnp.int32), 1,
            base[s][None], **kw1)
        np.testing.assert_array_equal(np.asarray(fused[s]),
                                      np.asarray(alone[0]))


def test_fed_reduce_singleton_and_empty_segments():
    """T=4 with one singleton lane, one empty lane: the singleton reduces
    to its (normalized) row + base, the empty lane passes base through."""
    n = 128
    rng = np.random.default_rng(3)
    rows = jnp.asarray(rng.standard_normal((3, n)).astype(np.float32))
    base = jnp.asarray(rng.standard_normal((4, n)).astype(np.float32))
    w = jnp.asarray([5.0, 2.0, 3.0], jnp.float32)
    seg = jnp.asarray([0, 0, 2], jnp.int32)       # lane 1 and 3 empty
    out = kernel_ops.fed_reduce(w, rows, seg, 4, base, normalize=True)
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(base[1]))
    np.testing.assert_array_equal(np.asarray(out[3]), np.asarray(base[3]))
    # singleton lane: w/tot == 1 exactly, so lane 2 is row + base
    one = kernel_ops.fed_reduce(w[2:], rows[2:],
                                jnp.zeros(1, jnp.int32), 1, base[2][None],
                                normalize=True)
    np.testing.assert_array_equal(np.asarray(out[2]), np.asarray(one[0]))


def test_fed_reduce_zero_weight_rows_are_bit_neutral():
    """Padding rows with weight 0 (what the engines append to reach pow2
    lane counts) leave every lane bit-identical — the fold adds +/-0.0."""
    m, n, t = 12, 257, 3
    w, rows, seg, base = _reduce_case(m, n, t, seed=7)
    out = kernel_ops.fed_reduce(w, rows, seg, t, base, normalize=True)
    rng = np.random.default_rng(8)
    pad = jnp.asarray(rng.standard_normal((5, n)).astype(np.float32))
    w2 = jnp.concatenate([w, jnp.zeros(5, jnp.float32)])
    rows2 = jnp.concatenate([rows, pad])
    seg2 = jnp.concatenate([seg, jnp.asarray([0, 1, 2, 0, 1], jnp.int32)])
    out2 = kernel_ops.fed_reduce(w2, rows2, seg2, t, base, normalize=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_fed_reduce_per_lane_quant_mask():
    """quant_enabled gates the round trip per ROW: disabled rows pass
    through untouched, and a mixed-mask call equals quantizing exactly the
    enabled rows up front, bit for bit."""
    m, n, t = 10, 300, 2
    w, rows, seg, base = _reduce_case(m, n, t, seed=11)
    ls = (100, n - 100)
    en = jnp.asarray(np.arange(m) % 2 == 0)
    mixed = kernel_ops.fed_reduce(w, rows, seg, t, base, normalize=True,
                                  leaf_sizes=ls, quant_ref=base,
                                  quant_enabled=en)
    pre = jax.jit(ref._quant_rows, static_argnames=("leaf_sizes",))(
        rows, seg, base, en, ls)
    want = kernel_ops.fed_reduce(w, pre, seg, t, base, normalize=True)
    np.testing.assert_array_equal(np.asarray(mixed), np.asarray(want))


def test_fed_reduce_quant_matches_tree_roundtrip():
    """The fused in-kernel round trip == the per-tree compress_delta path
    (both jitted — the production oracle pair), bit for bit through the
    weighted reduce."""
    from repro.federated.aggregation import _flatten, _unflatten
    from repro.federated.compression import _tree_roundtrip

    rng = np.random.default_rng(21)
    gtree = {"w": jnp.asarray(rng.standard_normal((8, 16)).astype(np.float32)),
             "b": jnp.asarray(rng.standard_normal(16).astype(np.float32))}
    gflat, meta = _flatten(gtree)
    leaf_sizes = tuple(meta[2])
    m = 6
    rows = jnp.stack([
        gflat + jnp.asarray(
            rng.standard_normal(gflat.size).astype(np.float32)) * 0.1
        for _ in range(m)])
    w = jnp.asarray(rng.uniform(1, 50, m).astype(np.float32))
    seg = jnp.zeros(m, jnp.int32)

    fused = kernel_ops.fed_reduce(
        w, rows, seg, 1, gflat[None], normalize=True,
        leaf_sizes=leaf_sizes, quant_ref=gflat[None],
        quant_enabled=jnp.ones(m, bool))

    rt_rows = jnp.stack([
        _flatten(_tree_roundtrip(gtree, _unflatten(rows[i], meta)))[0]
        for i in range(m)])
    want = kernel_ops.fed_reduce(w, rt_rows, seg, 1, gflat[None],
                                 normalize=True)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(want))


def test_fed_reduce_packing_invariance_property():
    """Property form of the packing-invariance contract over random
    segment layouts, weights (including zeros), and row counts."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 20), st.integers(1, 6), st.integers(0, 3),
           st.randoms(use_true_random=False))
    def prop(m, t, zero_w, rnd):
        seed = rnd.randint(0, 2**31 - 1)
        w, rows, seg, base = _reduce_case(
            m, 65, t, seed, interleave=True, zero_w=min(zero_w, m - 1))
        fused = kernel_ops.fed_reduce(w, rows, seg, t, base,
                                      normalize=True)
        segs = np.asarray(seg)
        for s in range(t):
            idx = np.nonzero(segs == s)[0]
            if len(idx) == 0:
                np.testing.assert_array_equal(np.asarray(fused[s]),
                                              np.asarray(base[s]))
                continue
            alone = kernel_ops.fed_reduce(
                w[idx], rows[idx], jnp.zeros(len(idx), jnp.int32), 1,
                base[s][None], normalize=True)
            np.testing.assert_array_equal(np.asarray(fused[s]),
                                          np.asarray(alone[0]))

    prop()
