"""Composable language model: assembles LayerSpecs into a decoder-only LM,
an encoder-decoder (audio family), or a frontend-prefixed VLM.

API (all functions close over ``ModelConfig``; params are plain pytrees):
  init_params(cfg, key, dtype)
  forward(params, cfg, tokens, ...)            # full-seq logits (train/eval)
  loss_fn(params, cfg, batch, ...)             # next-token CE + MoE aux
  init_cache(cfg, batch, max_len, ...)         # decode state pytree
  prefill(params, cfg, tokens, cache, ...)     # build cache, last logits
  decode_step(params, cfg, token, pos, cache)  # one token
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (FFN_DENSE, FFN_MOE, FFN_NONE, MIX_ATTN,
                                MIX_MLSTM, MIX_RGLRU, MIX_SLSTM, LayerSpec,
                                ModelConfig)
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import recurrent as rec_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import (dense_init, embed_init, rmsnorm, shard_bse,
                                 softcap)
from repro.sharding.ctx import logical_constraint


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), dtype)}
    if spec.mixer == MIX_ATTN:
        p["mixer"] = attn_mod.init_attention_params(ks[0], cfg, dtype=dtype)
    elif spec.mixer == MIX_RGLRU:
        p["mixer"] = rec_mod.init_rglru_params(ks[0], cfg, dtype=dtype)
    elif spec.mixer == MIX_MLSTM:
        p["mixer"] = xlstm_mod.init_mlstm_params(ks[0], cfg, dtype=dtype)
    elif spec.mixer == MIX_SLSTM:
        p["mixer"] = xlstm_mod.init_slstm_params(ks[0], cfg, dtype=dtype)
    if spec.ffn != FFN_NONE:
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
        if spec.ffn == FFN_DENSE:
            p["ffn"] = ffn_mod.init_mlp_params(ks[1], cfg.d_model, cfg.d_ff, dtype)
        else:
            p["ffn"] = ffn_mod.init_moe_params(ks[1], cfg.d_model, cfg.moe, dtype)
    if cfg.is_encoder_decoder:
        p["ln_cross"] = jnp.zeros((cfg.d_model,), dtype)
        p["cross"] = attn_mod.init_attention_params(
            ks[2], cfg, bias=False, dtype=dtype)
    return p


def _init_encoder(key, cfg: ModelConfig, dtype):
    e = cfg.encoder
    ks = jax.random.split(key, e.n_layers + 1)
    layers = []
    for i in range(e.n_layers):
        k1, k2 = jax.random.split(ks[i])
        layers.append({
            "ln1": jnp.zeros((e.d_model,), dtype),
            "mixer": attn_mod.init_attention_params(
                k1, cfg, d_in=e.d_model, n_heads=e.n_heads, n_kv=e.n_kv_heads,
                head_dim=e.head_dim, bias=False, dtype=dtype),
            "ln2": jnp.zeros((e.d_model,), dtype),
            "ffn": ffn_mod.init_mlp_params(k2, e.d_model, e.d_ff, dtype),
        })
    return {"layers": layers, "final_norm": jnp.zeros((e.d_model,), dtype)}


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    ks = jax.random.split(key, cfg.n_layers + 4)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
        "layers": [
            _init_layer(ks[1 + i], cfg, spec, dtype)
            for i, spec in enumerate(cfg.layers)
        ],
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            ks[cfg.n_layers + 1], (cfg.d_model, cfg.vocab_size), dtype)
    if cfg.frontend is not None:
        params["frontend_proj"] = dense_init(
            ks[cfg.n_layers + 2], (cfg.frontend.feature_dim, cfg.d_model), dtype)
    if cfg.is_encoder_decoder:
        params["encoder"] = _init_encoder(ks[cfg.n_layers + 3], cfg, dtype)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _block(p, cfg: ModelConfig, spec: LayerSpec, x, positions, *,
           enc_out=None, enc_pos=None, use_kernel=True):
    """One transformer block (full sequence). Returns (x, moe_aux)."""
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if spec.mixer == MIX_ATTN:
        mix = attn_mod.attention(p["mixer"], cfg, spec, h, positions,
                                 use_kernel=use_kernel)
    elif spec.mixer == MIX_RGLRU:
        mix = rec_mod.rglru_block(p["mixer"], h, use_kernel=use_kernel)
    elif spec.mixer == MIX_MLSTM:
        mix = xlstm_mod.mlstm_block(p["mixer"], h, cfg)
    else:
        mix = xlstm_mod.slstm_block(p["mixer"], h, cfg)
    x = x + mix
    if enc_out is not None:
        hc = rmsnorm(x, p["ln_cross"], cfg.norm_eps)
        cross = attn_mod.attention(
            p["cross"], cfg, spec, hc, positions, causal=False,
            kv_input=enc_out, kv_positions=enc_pos, rope=False,
            use_kernel=use_kernel)
        x = x + cross
    aux = jnp.zeros((), x.dtype)
    if spec.ffn != FFN_NONE:
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if spec.ffn == FFN_DENSE:
            out = ffn_mod.mlp(p["ffn"], h2, cfg.act)
        else:
            out, aux = ffn_mod.moe_ffn(p["ffn"], h2, cfg.moe, cfg.act)
        x = x + out
    return shard_bse(x), aux


def _encode(params, cfg: ModelConfig, frames, *, use_kernel=True):
    """Encoder over (stub) frontend frames: (B, T, F) -> (B, T, d_enc)."""
    e = cfg.encoder
    x = jnp.einsum("btf,fd->btd", frames, params["frontend_proj"])
    pos = jnp.arange(frames.shape[1])
    enc_spec = LayerSpec()
    for lp in params["encoder"]["layers"]:
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        x = x + attn_mod.attention(lp["mixer"], cfg, enc_spec, h, pos,
                                   causal=False, use_kernel=use_kernel)
        h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = x + ffn_mod.mlp(lp["ffn"], h2, cfg.act)
    return rmsnorm(x, params["encoder"]["final_norm"], cfg.norm_eps), pos


def _embed_inputs(params, cfg: ModelConfig, tokens, frontend):
    """Token embeddings, with VLM patch embeddings prefixed if present."""
    x = params["embed"][tokens] * jnp.sqrt(float(cfg.d_model)).astype(
        params["embed"].dtype)
    if cfg.frontend is not None and cfg.frontend.kind == "vision_patches":
        assert frontend is not None, "vlm needs frontend patch embeddings"
        fx = jnp.einsum("bpf,fd->bpd", frontend, params["frontend_proj"])
        x = jnp.concatenate([fx.astype(x.dtype), x], axis=1)
    return shard_bse(x)


def _unembed(params, cfg: ModelConfig, x):
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logical_constraint(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# full-sequence forward / loss
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens, *, frontend=None,
            use_kernel=True, remat=False):
    """tokens: (B, S_text). Returns logits (B, S_total, V)."""
    enc_out = enc_pos = None
    if cfg.is_encoder_decoder:
        assert frontend is not None, "encoder-decoder needs frontend frames"
        enc_out, enc_pos = _encode(params, cfg, frontend, use_kernel=use_kernel)
        x = _embed_inputs(params, cfg, tokens, None)
    else:
        x = _embed_inputs(params, cfg, tokens, frontend)
    positions = jnp.arange(x.shape[1])

    for p, spec in zip(params["layers"], cfg.layers):
        blk = functools.partial(_block, cfg=cfg, spec=spec,
                                enc_out=enc_out, enc_pos=enc_pos,
                                use_kernel=use_kernel)
        if remat:
            blk = jax.checkpoint(lambda p_, x_, pos_, blk=blk:
                                 blk(p_, x=x_, positions=pos_))
            x, _aux = blk(p, x, positions)
        else:
            x, _aux = blk(p, x=x, positions=positions)
    return _unembed(params, cfg, x)


def loss_fn(params, cfg: ModelConfig, batch, *, use_kernel=True, remat=False):
    """batch: {"tokens": (B,S), "labels": (B,S) with -1 = ignored,
    optional "frontend"}.  Returns (loss, metrics)."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    frontend = batch.get("frontend")
    enc_out = enc_pos = None
    if cfg.is_encoder_decoder:
        enc_out, enc_pos = _encode(params, cfg, frontend, use_kernel=use_kernel)
        x = _embed_inputs(params, cfg, tokens, None)
    else:
        x = _embed_inputs(params, cfg, tokens, frontend)
    positions = jnp.arange(x.shape[1])

    aux_total = jnp.zeros((), jnp.float32)
    for p, spec in zip(params["layers"], cfg.layers):
        blk = functools.partial(_block, cfg=cfg, spec=spec, enc_out=enc_out,
                                enc_pos=enc_pos, use_kernel=use_kernel)
        if remat:
            x, aux = jax.checkpoint(
                lambda p_, x_, blk=blk: blk(p_, x=x_, positions=positions)
            )(p, x)
        else:
            x, aux = blk(p, x=x, positions=positions)
        aux_total = aux_total + aux.astype(jnp.float32)

    # VLM prefix: hidden states cover frontend+text; align to text labels
    if x.shape[1] != labels.shape[1]:
        x = x[:, x.shape[1] - labels.shape[1]:]
    # Optional per-sequence weights (B,): FedAvg participant weighting
    # (n_k / n) enters the round objective here — the backward pass's
    # gradient reduction then IS the weighted FL aggregation.
    weight = batch.get("weight")
    mask = labels >= 0
    tok_w = mask.astype(jnp.float32)
    if weight is not None:
        tok_w = tok_w * weight[:, None].astype(jnp.float32)
    ce, acc = chunked_ce(params, cfg, x, labels, tok_w)
    loss = ce + aux_total
    return loss, {"ce": ce, "aux": aux_total, "acc": acc}


def chunked_ce(params, cfg: ModelConfig, x, labels, tok_w, *,
               chunk_tokens: int = 16_384):
    """Cross-entropy without materializing full (B,S,V) f32 logits: flatten
    tokens, scan over chunks, recompute logits in the backward (remat)."""
    b, s, d = x.shape
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    t = b * s
    xf = x.reshape(t, d)
    lf = labels.reshape(t)
    wf = tok_w.reshape(t)
    mf = (labels >= 0).reshape(t)
    chunk = min(chunk_tokens, t)
    pad = (-t) % chunk
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
        lf = jnp.pad(lf, (0, pad))
        wf = jnp.pad(wf, (0, pad))
        mf = jnp.pad(mf, (0, pad))
    nc = (t + pad) // chunk
    xs = xf.reshape(nc, chunk, d)
    ls = lf.reshape(nc, chunk)
    ws = wf.reshape(nc, chunk)
    ms = mf.reshape(nc, chunk)

    def chunk_stats(xc, lc, wc, mc):
        logits = jnp.einsum("td,dv->tv", xc, head)
        logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
        logits = logical_constraint(logits, (None, "vocab"))
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        safe = jnp.maximum(lc, 0)
        tgt = jnp.take_along_axis(logits, safe[:, None], axis=-1)[:, 0]
        nll = logz - tgt
        correct = jnp.where(mc, logits.argmax(-1) == safe, False)
        return (nll * wc).sum(), correct.sum(), wc.sum(), mc.sum()

    if nc == 1:
        nll_s, cor_s, w_s, m_s = chunk_stats(xs[0], ls[0], ws[0], ms[0])
    else:
        def body(carry, inp):
            out = jax.checkpoint(chunk_stats)(*inp)
            return jax.tree.map(jnp.add, carry, out), None

        init = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32),
                jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32))
        (nll_s, cor_s, w_s, m_s), _ = jax.lax.scan(
            body, init, (xs, ls, ws, ms))
    ce = nll_s / jnp.maximum(w_s, 1e-9)
    acc = cor_s / jnp.maximum(m_s, 1)
    return ce, acc


# ---------------------------------------------------------------------------
# cache / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               decode_window: Optional[int] = None, dtype=jnp.float32):
    """decode_window forces a sliding window onto full-attention layers
    (the documented long-context serving adaptation)."""
    layers = []
    for spec in cfg.layers:
        if spec.mixer == MIX_ATTN:
            layers.append(attn_mod.init_kv_cache(
                cfg, spec, batch, max_len, decode_window=decode_window,
                dtype=dtype))
        elif spec.mixer == MIX_RGLRU:
            layers.append(rec_mod.init_rglru_state(cfg, batch, dtype))
        elif spec.mixer == MIX_MLSTM:
            layers.append(xlstm_mod.init_mlstm_state(cfg, batch, dtype))
        else:
            layers.append(xlstm_mod.init_slstm_state(cfg, batch, dtype))
    cache: Dict[str, Any] = {"layers": layers}
    if cfg.is_encoder_decoder:
        e = cfg.encoder
        t = cfg.frontend.seq_len
        cache["enc_out"] = jnp.zeros((batch, t, e.d_model), dtype)
    return cache


def _prefill_block(p, cfg: ModelConfig, spec, x, positions, st, *,
                   enc_out=None, enc_pos=None, use_kernel=True):
    """One block of the prompt pass; fills this layer's cache/state."""
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if spec.mixer == MIX_ATTN:
        mix, st = attn_mod.prefill_into_cache(
            p["mixer"], cfg, spec, h, positions, st, use_kernel=use_kernel)
    elif spec.mixer == MIX_RGLRU:
        mix = rec_mod.rglru_block(p["mixer"], h, use_kernel=use_kernel)
        # state: re-derive the final hidden state (cheap second scan)
        u = jnp.einsum("bsd,dw->bsw", h, p["mixer"]["w_in"])
        a, b = rec_mod._gates(
            p["mixer"],
            rec_mod._causal_conv(u, p["mixer"]["conv_w"],
                                 p["mixer"]["conv_b"]))
        hseq = rec_mod.rglru_scan(a.astype(jnp.float32),
                                  b.astype(jnp.float32))
        st = rec_mod.RGLRUState(
            h=hseq[:, -1],
            conv_tail=u[:, -(cfg.conv1d_width - 1):].astype(
                st.conv_tail.dtype))
    elif spec.mixer == MIX_MLSTM:
        mix, st = _mlstm_prefill(p["mixer"], h, cfg)
    else:
        mix, st = _slstm_prefill(p["mixer"], h, cfg)
    x = x + mix
    if enc_out is not None:
        hc = rmsnorm(x, p["ln_cross"], cfg.norm_eps)
        x = x + attn_mod.attention(p["cross"], cfg, spec, hc, positions,
                                   causal=False, kv_input=enc_out,
                                   kv_positions=enc_pos, rope=False,
                                   use_kernel=use_kernel)
    if spec.ffn != FFN_NONE:
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if spec.ffn == FFN_DENSE:
            x = x + ffn_mod.mlp(p["ffn"], h2, cfg.act)
        else:
            # serving path: drop-free MoE (see decode_step for rationale)
            out, _ = ffn_mod.moe_ffn_dense(p["ffn"], h2, cfg.moe, cfg.act)
            x = x + out
    return shard_bse(x), st


def prefill(params, cfg: ModelConfig, tokens, cache, *, frontend=None,
            use_kernel=True):
    """Run the prompt through the model, filling the cache.
    Returns (last-position logits, cache)."""
    enc_out = enc_pos = None
    if cfg.is_encoder_decoder:
        enc_out, enc_pos = _encode(params, cfg, frontend, use_kernel=use_kernel)
        cache = dict(cache, enc_out=enc_out)
        x = _embed_inputs(params, cfg, tokens, None)
    else:
        x = _embed_inputs(params, cfg, tokens, frontend)
    positions = jnp.arange(x.shape[1])

    new_layers = []
    for p, spec, st in zip(params["layers"], cfg.layers, cache["layers"]):
        x, st = _prefill_block(p, cfg, spec, x, positions, st,
                               enc_out=enc_out, enc_pos=enc_pos,
                               use_kernel=use_kernel)
        new_layers.append(st)
    logits = _unembed(params, cfg, x[:, -1:])
    return logits[:, 0], dict(cache, layers=new_layers)


def _mlstm_prefill(p, h, cfg):
    """Full-seq chunkwise mLSTM that also returns the final state."""
    nh = cfg.n_heads
    w = int(cfg.d_model * cfg.xlstm_proj_factor)
    hd = w // nh
    q, k, v, i_pre, f_pre, z = xlstm_mod._mlstm_qkvif(p, h, nh, hd)
    b, s = h.shape[:2]
    hs, (C, n, m) = xlstm_mod.mlstm_chunkwise(
        q.transpose(0, 2, 1, 3).astype(jnp.float32),
        k.transpose(0, 2, 1, 3).astype(jnp.float32),
        v.transpose(0, 2, 1, 3).astype(jnp.float32),
        i_pre.transpose(0, 2, 1), f_pre.transpose(0, 2, 1))
    hs = hs.transpose(0, 2, 1, 3).reshape(b, s, w).astype(h.dtype)
    out = jnp.einsum("bsw,wd->bsd", hs * z, p["w_down"])
    xu = jnp.einsum("bsd,dw->bsw", h, p["w_up"])
    st = xlstm_mod.MLSTMState(C=C, n=n, m=m,
                              conv_tail=xu[:, -3:].astype(h.dtype))
    return out, st


def _slstm_prefill(p, h, cfg):
    b, s, d = h.shape
    gates = xlstm_mod._slstm_gate_inputs(p, h)
    xs = {g: gates[g].transpose(1, 0, 2) for g in gates}
    st0 = (jnp.zeros((b, d), jnp.float32), jnp.zeros((b, d), jnp.float32),
           jnp.full((b, cfg.n_heads), -jnp.inf, jnp.float32),
           jnp.zeros((b, d), jnp.float32))
    step = lambda carry, x_t: xlstm_mod._slstm_step(p, cfg.n_heads, carry, x_t)
    (c, n, m, hf), hs = jax.lax.scan(step, st0, xs)
    hs = hs.transpose(1, 0, 2).astype(h.dtype)
    out = hs * jax.nn.silu(jnp.einsum("bsd,de->bse", h, p["w_z_gate"]))
    out = jnp.einsum("bsd,de->bse", out, p["w_down"])
    return out, xlstm_mod.SLSTMState(c=c, n=n, m=m, h=hf)


def decode_step(params, cfg: ModelConfig, token, pos, cache):
    """token: (B,) int32; pos: scalar int32 (global position of this token).
    Returns (logits (B,V), new cache)."""
    x = params["embed"][token][:, None] * jnp.sqrt(
        float(cfg.d_model)).astype(params["embed"].dtype)   # (B,1,d)
    x = logical_constraint(x, ("batch", None, "embed"))
    enc_out = cache.get("enc_out")
    enc_pos = (jnp.arange(enc_out.shape[1]) if enc_out is not None else None)

    new_layers = []
    for p, spec, st in zip(params["layers"], cfg.layers, cache["layers"]):
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        if spec.mixer == MIX_ATTN:
            mix, st = attn_mod.decode_attention(p["mixer"], cfg, spec, h, pos, st)
        elif spec.mixer == MIX_RGLRU:
            mix, st = rec_mod.rglru_decode_step(p["mixer"], h, st)
        elif spec.mixer == MIX_MLSTM:
            mix, st = xlstm_mod.mlstm_decode_step(p["mixer"], h, st, cfg)
        else:
            mix, st = xlstm_mod.slstm_decode_step(p["mixer"], h, st, cfg)
        x = x + mix
        if enc_out is not None:
            hc = rmsnorm(x, p["ln_cross"], cfg.norm_eps)
            pos_q = jnp.asarray(pos, jnp.int32)[None]
            x = x + attn_mod.attention(p["cross"], cfg, spec, hc, pos_q,
                                       causal=False, kv_input=enc_out,
                                       kv_positions=enc_pos, rope=False,
                                       use_kernel=False)
        if spec.ffn != FFN_NONE:
            h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
            if spec.ffn == FFN_DENSE:
                x = x + ffn_mod.mlp(p["ffn"], h2, cfg.act)
            else:
                # Serving uses the drop-free masked-dense MoE: capacity-based
                # dispatch drops tokens as a function of BATCH composition,
                # which would make a decoded token's value depend on what
                # else is in flight.  At decode t = B tokens the dense path
                # is also cheaper than materializing (E, C, d) buffers.
                out, _ = ffn_mod.moe_ffn_dense(p["ffn"], h2, cfg.moe, cfg.act)
                x = x + out
        new_layers.append(st)
    logits = _unembed(params, cfg, x)
    return logits[:, 0], dict(cache, layers=new_layers)
