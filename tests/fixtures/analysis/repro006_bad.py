"""BAD fixture: jit-cache hazards.

A jitted callable built inside a loop retraces every iteration; an
uncached factory retraces every call; a list/dict literal at a static
position raises ``unhashable`` at runtime.  REPRO006 must fire on all
three.
"""

import jax


def train(rounds, fn, x):
    for _r in range(rounds):
        step = jax.jit(fn)      # REPRO006: constructed inside the loop
        x = step(x)
    return x


def make_step(fn):
    return jax.jit(fn)          # REPRO006: per-call, no visible cache


encode = jax.jit(lambda x, opts: x, static_argnames=("opts",))


def run(x):
    # REPRO006: dict literal at a static_argnames position
    return encode(x, opts={"lr": 0.1})
