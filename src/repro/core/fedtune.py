"""FedTune (paper Algorithm 1): online, single-trial tuning of (M, E).

Decision cycle (activated whenever test accuracy improved by >= eps since
the last decision):
  1. Normalize the overheads accumulated since the last decision by the
     accuracy gain (cost per unit of accuracy).
  2. Compare against the previous decision window via eq. (6); a positive
     I() means the last move was bad.
  3. Update slope estimates: eta_* (w.r.t. M) for the overheads that favor
     the direction of the last M move, zeta_* (w.r.t. E) likewise; on a bad
     move, multiply the *opposing* slopes by the penalty factor D.
  4. Form Delta-M (eq. 10) / Delta-E (eq. 11) with Table 3's signs:
       M: CompT +, TransT +, CompL -, TransL -
       E: CompT -, TransT +, CompL -, TransL +
  5. Step M and E by +/-1 according to the signs (or by an adaptive step —
     a beyond-paper option addressing the paper's noted limitation).

The controller is O(tens of multiplications) per decision: negligible next
to a training round, exactly as the paper claims.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from repro.core.costs import SystemCost
from repro.core.preferences import Preference
from repro.core.tuner import HyperParams, Tuner

_EPS = 1e-12

# Table 3 signs: does overhead i improve with larger M / larger E?
_M_SIGNS = (+1.0, +1.0, -1.0, -1.0)   # CompT, TransT, CompL, TransL
_E_SIGNS = (-1.0, +1.0, -1.0, +1.0)
# indices of overheads that *favor* larger M (resp. smaller M)
_M_UP_FAVORS = (0, 1)
_M_DOWN_FAVORS = (2, 3)
_E_UP_FAVORS = (1, 3)
_E_DOWN_FAVORS = (0, 2)


@dataclass
class FedTuneConfig:
    preference: Preference
    eps: float = 0.01          # min accuracy improvement to trigger a decision
    penalty: float = 10.0      # D
    m_max: int = 100
    e_max: float = 100.0
    adaptive_step: bool = False   # beyond-paper: step size from |Delta|
    adaptive_max_step: int = 4


@dataclass
class _Window:
    """Normalized overheads of one decision window."""
    values: List[float]   # [t, q, z, v] normalized by accuracy gain


class FedTune(Tuner):
    def __init__(self, config: FedTuneConfig, initial: HyperParams):
        self.cfg = config
        self.current = HyperParams(initial.m, initial.e)
        self.prev_hp: Optional[HyperParams] = None
        self._last_acc = 0.0
        self._acc_at_last_decision = 0.0
        self._window_cost = SystemCost()
        self._prv: Optional[_Window] = None
        self._prvprv: Optional[_Window] = None
        self.eta = [1.0, 1.0, 1.0, 1.0]
        self.zeta = [1.0, 1.0, 1.0, 1.0]
        self.decisions = 0
        self.trace: List[dict] = []

    # ------------------------------------------------------------------
    def on_round(self, round_idx: int, accuracy: float,
                 round_cost: SystemCost, total_cost: SystemCost,
                 current: HyperParams) -> HyperParams:
        """Accumulate this round's overheads; trigger a decision once test
        accuracy has improved by **at least** eps since the last decision
        (gain >= eps, inclusive — the paper's activation convention)."""
        self.current = current
        for name in ("comp_t", "trans_t", "comp_l", "trans_l"):
            setattr(self._window_cost, name,
                    getattr(self._window_cost, name) + getattr(round_cost, name))
        gain = accuracy - self._acc_at_last_decision
        if gain < self.cfg.eps:
            return current
        return self._decide(accuracy, gain)

    # ------------------------------------------------------------------
    def _decide(self, accuracy: float, gain: float) -> HyperParams:
        cur = _Window(values=[v / gain for v in self._window_cost.as_tuple()])
        hp = self.current
        if self._prv is not None:
            bad = self._comparison(self._prv, cur) > 0.0
            self._update_slopes(cur, bad)
            dm = self._delta(cur, self.eta, _M_SIGNS)
            de = self._delta(cur, self.zeta, _E_SIGNS)
            step_m = self._step(dm)
            step_e = self._step(de)
            nxt = HyperParams(m=hp.m + step_m, e=hp.e + step_e).clamped(
                self.cfg.m_max, self.cfg.e_max)
        else:
            # First decision: no history — probe by increasing M
            # (both CompT and TransT favor it initially).
            bad = False
            dm = de = 0.0
            nxt = HyperParams(m=hp.m + 1, e=hp.e).clamped(
                self.cfg.m_max, self.cfg.e_max)
        self.trace.append({
            "decision": self.decisions, "acc": accuracy,
            "m": hp.m, "e": hp.e, "m_next": nxt.m, "e_next": nxt.e,
            "bad": bad, "dm": dm, "de": de,
            "window": tuple(cur.values),
        })
        self.decisions += 1
        self.prev_hp = hp
        self._prvprv = self._prv
        self._prv = cur
        self._acc_at_last_decision = accuracy
        self._window_cost = SystemCost()
        return nxt

    # ------------------------------------------------------------------
    def _comparison(self, prv: _Window, cur: _Window) -> float:
        """Paper eq. (6): I(S_prv, S_cur); positive => cur is worse."""
        w = self.cfg.preference.as_tuple()
        total = 0.0
        for i in range(4):
            if w[i] == 0.0:
                continue
            total += w[i] * (cur.values[i] - prv.values[i]) / max(
                prv.values[i], _EPS)
        return total

    def _update_slopes(self, cur: _Window, bad: bool):
        """Slope estimates eta_i = |x_cur - x_prv| / |x_prv - x_prvprv| for
        the overheads that favor the direction of the last move; penalty on
        the opposing ones when the move was bad (lines 16-25)."""
        hp, prev_hp = self.current, self.prev_hp
        prv, prvprv = self._prv, self._prvprv

        def slope(i: float) -> float:
            num = abs(cur.values[i] - prv.values[i])
            if prvprv is None:
                return 1.0
            den = abs(prv.values[i] - prvprv.values[i])
            return num / max(den, _EPS)

        if prev_hp is None or hp.m != prev_hp.m:
            up = prev_hp is None or hp.m > prev_hp.m
            favored = _M_UP_FAVORS if up else _M_DOWN_FAVORS
            opposing = _M_DOWN_FAVORS if up else _M_UP_FAVORS
            for i in favored:
                self.eta[i] = slope(i)
            if bad:
                for i in opposing:
                    self.eta[i] *= self.cfg.penalty
        if prev_hp is None or hp.e != prev_hp.e:
            up = prev_hp is None or hp.e > prev_hp.e
            favored = _E_UP_FAVORS if up else _E_DOWN_FAVORS
            opposing = _E_DOWN_FAVORS if up else _E_UP_FAVORS
            for i in favored:
                self.zeta[i] = slope(i)
            if bad:
                for i in opposing:
                    self.zeta[i] *= self.cfg.penalty

    def _delta(self, cur: _Window, slopes: List[float], signs) -> float:
        """Eqs. (10)/(11)."""
        w = self.cfg.preference.as_tuple()
        prv = self._prv
        total = 0.0
        for i in range(4):
            if w[i] == 0.0:
                continue
            diff = abs(cur.values[i] - prv.values[i])
            total += signs[i] * w[i] * slopes[i] * diff / max(
                cur.values[i], _EPS)
        return total

    def _step(self, delta: float) -> int:
        """Step direction from Delta (eqs. 10/11).  Delta == 0 — every
        weighted term cancelled, or no active preference weight saw any
        change — is no evidence in either direction, so the hyper-parameter
        HOLDS (step 0) rather than taking a spurious down-step."""
        if delta == 0.0:
            return 0
        base = 1 if delta > 0 else -1
        if not self.cfg.adaptive_step:
            return base
        # beyond-paper: scale the step with the relative magnitude of Delta
        mag = min(self.cfg.adaptive_max_step, max(1, int(abs(delta) * 10)))
        return base * mag
