"""Client-side local training: E passes of mini-batch SGD on local data.

The jit'd step is shape-stable (fixed batch_size via pad+mask), so changing
E or M at round boundaries — what FedTune does — never retraces.
Supports the FedProx proximal term (mu/2 ||theta - theta_global||^2).
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.loader import client_batches
from repro.federated.aggregation import ClientUpdate
from repro.models.registry import Model
from repro.optim.optimizers import Optimizer

_step_cache = {}


def _make_step(model: Model, optimizer: Optimizer, prox_mu: float):
    key = (id(model), id(optimizer), prox_mu)
    if key in _step_cache:
        return _step_cache[key]

    def loss(params, batch, global_params):
        l, metrics = model.loss_fn(params, batch)
        if prox_mu > 0.0:
            sq = sum(jnp.sum((a - b) ** 2) for a, b in zip(
                jax.tree.leaves(params), jax.tree.leaves(global_params)))
            l = l + 0.5 * prox_mu * sq
        return l, metrics

    @jax.jit
    def step(params, opt_state, batch, global_params):
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
            params, batch, global_params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        return params, opt_state, l, metrics

    _step_cache[key] = step
    return step


def local_train(model: Model, global_params, x: np.ndarray, y: np.ndarray,
                *, passes: float, batch_size: int, optimizer: Optimizer,
                rng: np.random.Generator, prox_mu: float = 0.0
                ) -> ClientUpdate:
    """Run ``passes`` epochs over (x, y) starting from the global model."""
    step = _make_step(model, optimizer, prox_mu)
    params = global_params
    opt_state = optimizer.init(params)
    n_steps = 0
    last_loss = 0.0
    for bx, by, mask in client_batches(x, y, batch_size, passes, rng):
        batch = {"x": jnp.asarray(bx), "y": jnp.asarray(by),
                 "mask": jnp.asarray(mask)}
        params, opt_state, l, _ = step(params, opt_state, batch,
                                       global_params)
        last_loss = float(l)
        n_steps += 1
    return ClientUpdate(params=params, n_examples=len(y), n_steps=n_steps,
                        last_loss=last_loss)
