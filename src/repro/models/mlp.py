"""The paper's EMNIST model: an MLP with one hidden layer (200 ReLU units)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.paper_models import MLPConfig


def init_params(cfg: MLPConfig, key, dtype=jnp.float32):
    dims = (cfg.in_dim,) + cfg.hidden + (cfg.n_classes,)
    ks = jax.random.split(key, len(dims) - 1)
    return {
        "layers": [
            {"w": (jax.random.normal(ks[i], (dims[i], dims[i + 1]))
                   * jnp.sqrt(2.0 / dims[i])).astype(dtype),
             "b": jnp.zeros((dims[i + 1],), dtype)}
            for i in range(len(dims) - 1)
        ]
    }


def forward(params, cfg: MLPConfig, x):
    """x: (B, in_dim) or (B, H, W[, C]) flattened."""
    x = x.reshape(x.shape[0], -1)
    layers = params["layers"]
    for p in layers[:-1]:
        x = jax.nn.relu(x @ p["w"] + p["b"])
    p = layers[-1]
    return x @ p["w"] + p["b"]


def flops_per_example(cfg: MLPConfig) -> float:
    dims = (cfg.in_dim,) + cfg.hidden + (cfg.n_classes,)
    return float(sum(2 * dims[i] * dims[i + 1] for i in range(len(dims) - 1)))
