"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain 512 host placeholder devices.

  single pod : (16, 16)        axes ("data", "model")   = 256 chips (v5e pod)
  multi-pod  : (2, 16, 16)     axes ("pod", "data", "model") = 512 chips

FL semantics on these meshes: the "data" axis carries the participant
cohort (one participant slot per data slice); the "pod" axis carries
disjoint sub-cohorts (silos) whose weighted psum IS the FL aggregation.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """A tiny mesh over whatever devices exist (tests on 1-8 CPU devices)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def make_clients_mesh(n_devices: int | None = None):
    """A 1-D mesh whose single ``clients`` axis carries the FL cohort: each
    device owns M/D participant slots of the sharded client-execution path
    (runtime/sharded.py).  Uses every addressable device by default; on a
    CPU host, ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set
    before any jax import) provides an N-device mesh."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n,), ("clients",))
