"""Multi-trial sweep execution: T independent FL trainings as ONE workload.

The unit of progress for FL hyper-parameter research is the *trial* — one
(preference, aggregator, dataset, seed, M0/E0) cell of the paper's tables —
and trials are embarrassingly parallel: they share no state, only hardware.
The sequential engine here runs them one ``FLServer.run()`` at a time; the
vectorized engine adds a **trials axis** on top of the cohort machinery from
PR 1-2 and runs all of them per virtual round:

  1. PLAN   — every live trial plans its sync round through the engine's own
              ``plan_sync_round`` (selection, availability, deadline cut),
              consuming its private server/system rngs exactly as a
              standalone run would.
  2. PACK   — every trial's included clients are materialized
              (``materialize_streams``, same rng contract as the
              sequential/batched paths) and packed into one flat cohort:
              grouped by model, size-bucketed by pow2 step count
              (``bucket_by_steps``), the client axis padded to a pow2 so the
              set of compiled (T, M) shapes stays small as FedTune moves
              each trial's M.  One ``cohort_scan`` per bucket trains clients
              of MANY trials side by side — each vmap lane carries its own
              trial's global params (``global_in_axis=0``).
  3. REDUCE — aggregation.  Every FedAvg trial's weighted mean runs as ONE
              fused ``fed_reduce`` dispatch per model group over the packed
              flat cohort (segment ids = trial slots, raw example counts
              normalized in-kernel, the int8 upload round trip of
              compressed trials fused in against each trial's dispatch-time
              globals) — bit-identical per lane to a standalone run because
              the kernel folds each segment's rows left-to-right in pack
              order (see kernels/ref.py).  Non-FedAvg trials hand their
              per-client pytrees to their own aggregator, which itself
              reduces through a T=1 ``fed_reduce``.  The ``sharded``
              packing lays the flat cohort over the ``clients`` mesh axis
              (runtime/sharded.py's mesh) and runs the same fused segment
              sum per device slice, completed by a psum — so per-client
              params never reach the host.
  4. STEP   — every due trial's evaluation runs as ONE stacked dispatch
              per (model, dataset) group (federated/evaluation.py's
              ``StackedEvaluator``), then each trial's own FedTune
              controller sees its round cost and accuracy and steps its
              (M, E) independently; finished trials drop out of the pack.

  Upload-compressed trials are packed like any others: FedAvg lanes defer
  the quantize->dequantize round trip into the fused reduce (one dispatch
  covers roundtrip + weighting + segment sum), other aggregators' lanes
  run it as a per-lane transform on the packed rows
  (``compress_delta_lanes``, masked per lane by each trial's
  ``TrialSpec.compression``) — both bit-identical to the sequential
  path's per-client ``compress_delta``.

Async/buffered trials vectorize through a second path (``run_vectorized_
events``) built on ONE merged virtual-clock event queue spanning all live
trials (events tagged with trial id, ties ordered (time, trial_key,
per-trial push seq) — see runtime/events.py).  Each macro-step advances
every live trial to its next pending client completion (dropouts handled
inline), packs those arrivals into one flat cohort — each vmap lane
training from ITS trial's dispatch-snapshot params via ``global_in_axis=0``
— then routes each trained lane back to its trial's FedAsync mixer or
FedBuff buffer on the host, exactly as the standalone event loop would
(the loop's plan/apply/account/finish phases are the engine's own
``plan_event``/``apply_event``/``finish_event_round`` methods).

Parity contract (pinned in tests/test_experiments.py): a T-trial vectorized
sweep — sync, async, or buffered — produces per-trial round records
(accuracies, costs, FedTune (M, E) trajectories, dispatch/staleness logs)
identical to T independent ``FLServer.run()`` calls with matching seeds.
Lanes of a vmapped cohort are computed independently, so packing MORE
clients around a trial does not change its floats.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs, perf
from repro.configs.paper_models import MLPConfig
from repro.core import CostModel, FedTune, FedTuneConfig, Preference
from repro.core.tuner import FixedTuner, HyperParams
from repro.data import cifar100_like, emnist_like, speech_command_like
from repro.experiments.grid import TrialSpec
from repro.federated import FLConfig, FLServer, get_aggregator
from repro.federated.aggregation import ClientUpdate, _flatten, _unflatten
from repro.federated.compression import compress_delta_lanes, lane_mask
from repro.federated.evaluation import eval_due, evaluate_stacked
from repro.federated.server import FLResult, RoundRecord
from repro.models import build_model
from repro.optim.optimizers import get_optimizer
from repro.runtime.batched import (_pow2, _stack_streams, bucket_by_steps,
                                   cohort_scan, make_client_step,
                                   materialize_streams, note_pack_metrics)
from repro.runtime.engine import EventDrivenRuntime, RuntimeConfig
from repro.runtime.events import FAILURE, MergedEventQueue, TrialQueueView
from repro.runtime.profiles import ChurnSchedule, sample_fleet

ENGINES = ("vectorized", "sequential")
PACKS = ("batched", "sharded")

_DATASET_FNS = {"speech_command": speech_command_like, "emnist": emnist_like,
                "cifar100": cifar100_like}
_dataset_cache: Dict[tuple, Any] = {}
_model_cache: Dict[tuple, Any] = {}
_optimizer_cache: Dict[tuple, Any] = {}
_multi_cohort_cache: Dict[tuple, Any] = {}
_sharded_multi_cache: Dict[tuple, Any] = {}


# ---------------------------------------------------------------------------
# trial construction (shared caches so T trials over one dataset family share
# one Model/Optimizer object — and therefore one set of compiled cohort fns)
# ---------------------------------------------------------------------------

def _dataset_for(spec: TrialSpec):
    key = (spec.dataset, spec.reduced, spec.seed)
    if key not in _dataset_cache:
        _dataset_cache[key] = _DATASET_FNS[spec.dataset](
            reduced=spec.reduced, seed=spec.seed)
    return _dataset_cache[key]


def _model_for(spec: TrialSpec):
    ds = _dataset_for(spec)
    key = (spec.dataset, spec.reduced)
    if key not in _model_cache:
        in_dim = int(np.prod(ds.spec.shape))
        _model_cache[key] = build_model(MLPConfig(
            name=f"mlp_{spec.dataset}{'_r' if spec.reduced else ''}",
            in_dim=in_dim, hidden=(48,), n_classes=ds.spec.n_classes))
    return _model_cache[key]


def _optimizer_for(spec: TrialSpec):
    key = ("sgd", spec.lr, 0.9)
    if key not in _optimizer_cache:
        _optimizer_cache[key] = get_optimizer("sgd", spec.lr, momentum=0.9)
    return _optimizer_cache[key]


def build_server(spec: TrialSpec) -> FLServer:
    """A fresh FLServer for one trial (fresh aggregator/tuner/selector/rng
    state; model, optimizer, and dataset shared through the caches)."""
    ds = _dataset_for(spec)
    model = _model_for(spec)
    n_params = sum(p.size for p in jax.tree.leaves(
        model.init(jax.random.PRNGKey(0))))
    flops = model.flops_per_example or 2 * n_params
    tuner = (FedTune(FedTuneConfig(preference=Preference(*spec.preference)),
                     HyperParams(spec.m0, spec.e0))
             if spec.tuner == "fedtune" else FixedTuner())
    # a fleet exists iff the trial has any system heterogeneity OR a
    # failure/churn model to hang onto it — a plain homogeneous trial keeps
    # fleet=None so its selector/est_times behavior (and thus bit-parity
    # with every earlier PR) is untouched
    needs_fleet = (spec.het != "homogeneous" or spec.failure_rate > 0.0
                   or spec.churn is not None)
    fleet = (sample_fleet(spec.het, ds.n_clients, seed=spec.seed)
             if needs_fleet else None)
    if fleet is not None and spec.failure_rate > 0.0:
        fleet.failure = np.full(ds.n_clients, spec.failure_rate)
        fleet.failure_seed = spec.seed
    if fleet is not None and spec.churn is not None:
        fleet.churn = ChurnSchedule.from_string(spec.churn, seed=spec.seed)
    return FLServer(
        model, ds, get_aggregator(spec.aggregator), _optimizer_for(spec),
        CostModel(flops_per_example=flops, param_count=n_params),
        FLConfig(m=spec.m0, e=spec.e0, batch_size=spec.batch_size,
                 target_accuracy=spec.target_accuracy,
                 max_rounds=spec.rounds, eval_points=spec.eval_points,
                 prox_mu=spec.prox_mu, seed=spec.seed,
                 compression=spec.compression),
        tuner=tuner, fleet=fleet,
        runtime_config=RuntimeConfig(mode=spec.mode,
                                     client_exec=spec.client_exec))


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------

@dataclass
class TrialResult:
    """One finished trial, flattened for the JSONL store.

    ``history_*`` are the per-round trajectories the parity tests compare;
    ``dispatch_log``/``staleness_log`` (async/buffered runtime modes only,
    empty otherwise) record every dispatch as (virtual time, client id,
    model version) and the staleness of every applied arrival — they are
    compared in the event-engine parity tests but deliberately NOT
    serialized by ``to_record`` (the store schema stays stable and small).
    ``engine`` names the execution path that produced the result
    (``sequential``, ``vectorized/<pack>``, ``vectorized-events/<pack>``);
    it is informational — engines are result-parity-equal."""
    spec: TrialSpec
    reached: bool
    rounds: int
    final_accuracy: float
    final_m: int
    final_e: float
    cost: Tuple[float, float, float, float]
    sim_time: float
    wall: float
    engine: str
    history_m: List[int]
    history_e: List[float]
    history_acc: List[float]
    dispatch_log: List[tuple] = field(default_factory=list)
    staleness_log: List[int] = field(default_factory=list)

    @classmethod
    def from_flresult(cls, spec: TrialSpec, res: FLResult, wall: float,
                      engine: str) -> "TrialResult":
        return cls(
            spec=spec, reached=res.reached_target, rounds=res.rounds,
            final_accuracy=float(res.final_accuracy), final_m=res.final_m,
            final_e=float(res.final_e), cost=res.total_cost.as_tuple(),
            sim_time=float(res.sim_time), wall=wall, engine=engine,
            history_m=[r.m for r in res.history],
            history_e=[float(r.e) for r in res.history],
            history_acc=[float(r.accuracy) for r in res.history],
            dispatch_log=list(res.dispatch_log or []),
            staleness_log=list(res.staleness_log or []))

    def to_record(self) -> dict:
        return {
            "key": self.spec.key(), "status": "done",
            "baseline_key": self.spec.baseline_key(),
            "spec": self.spec.to_dict(),
            "reached": self.reached, "rounds": self.rounds,
            "final_accuracy": self.final_accuracy,
            "final_m": self.final_m, "final_e": self.final_e,
            "cost": list(self.cost), "sim_time": self.sim_time,
            "wall": self.wall, "engine": self.engine,
            "history_m": self.history_m, "history_e": self.history_e,
            "history_acc": self.history_acc,
        }


def run_trial(spec: TrialSpec) -> TrialResult:
    """One trial, the single-process way: a full ``FLServer.run()``."""
    srv = build_server(spec)
    t0 = time.perf_counter()  # noqa: REPRO004 -- TrialResult.wall is informational; parity compares params/history only
    res = srv.run()
    return TrialResult.from_flresult(spec, res,
                                     time.perf_counter() - t0, "sequential")  # noqa: REPRO004 -- TrialResult.wall is informational


# ---------------------------------------------------------------------------
# the vectorized multi-trial engine
# ---------------------------------------------------------------------------

def _tree_stack(trees: Sequence[Any]):
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *trees)


def _multi_cohort_fn(model, optimizer, prox_mu: float):
    """The packed-cohort step: the shared scan/vmap body with PER-CLIENT
    reference params (``global_in_axis=0``), each lane starting local
    training from its own trial's global model."""
    key = (id(model), id(optimizer), prox_mu)
    if key in _multi_cohort_cache:
        return _multi_cohort_cache[key]
    one_client = make_client_step(model, optimizer, prox_mu)

    @jax.jit
    def run(global_b, xs, ys, masks, active):
        opt_b = jax.vmap(optimizer.init)(global_b)
        return cohort_scan(one_client, global_b, opt_b, xs, ys, masks,
                           active, global_b, global_in_axis=0)

    _multi_cohort_cache[key] = run
    return run


def _flatten_cohort(params_b):
    leaves = jax.tree.leaves(params_b)
    m = leaves[0].shape[0]
    return jnp.concatenate([l.reshape(m, -1) for l in leaves], axis=1)


def _sharded_multi_fn(model, optimizer, prox_mu: float, mesh, n_seg: int,
                      leaf_sizes: tuple, compressed: bool = False):
    """Packed cohort over the ``clients`` mesh axis with per-trial FedAvg
    fused on device: each device trains its slice of the flat cohort,
    then ONE ``fed_reduce`` call per slice fuses the int8 upload round
    trip of compressed lanes (against ``qref[seg]``, the lane's trial
    globals) with the (T, N) segment partial sum, and a psum across the
    axis completes every trial's weighted mean at once.  Per-client
    params never reach the host."""
    from repro.kernels import ops as kernel_ops
    from repro.sharding.specs import clients_spec
    key = (id(model), id(optimizer), prox_mu, id(mesh), n_seg, compressed)
    if key in _sharded_multi_cache:
        return _sharded_multi_cache[key]
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    one_client = make_client_step(model, optimizer, prox_mu)
    axis = mesh.axis_names[0]

    def shard_body(global_b, xs, ys, masks, active, weights, seg, qref,
                   enabled):
        opt_b = jax.vmap(optimizer.init)(global_b)
        params_b, last_loss = cohort_scan(
            one_client, global_b, opt_b, xs, ys, masks, active, global_b,
            global_in_axis=0)
        flat = _flatten_cohort(params_b)                  # (M_loc, N)
        partial = kernel_ops.fed_reduce(                  # (T, N) segment sum
            weights, flat, seg, n_seg,
            leaf_sizes=leaf_sizes if compressed else None,
            quant_ref=qref if compressed else None,
            quant_enabled=enabled if compressed else None)
        return jax.lax.psum(partial, axis), last_loss

    @jax.jit
    def run(global_b, xs, ys, masks, active, weights, seg, qref, enabled):
        in_specs = (jax.tree.map(lambda l: clients_spec(l.ndim, 0, axis),
                                 global_b),
                    clients_spec(xs.ndim, 1, axis),
                    clients_spec(ys.ndim, 1, axis),
                    clients_spec(masks.ndim, 1, axis),
                    clients_spec(active.ndim, 1, axis),
                    clients_spec(1, 0, axis),
                    clients_spec(1, 0, axis),
                    P(),                                  # qref replicated
                    clients_spec(1, 0, axis))
        return shard_map(shard_body, mesh=mesh, in_specs=in_specs,
                         out_specs=(P(), clients_spec(1, 0, axis)))(
                             global_b, xs, ys, masks, active, weights,
                             seg, qref, enabled)

    _sharded_multi_cache[key] = run
    return run


@dataclass
class _Cohort:
    cids: List[int]
    streams: List[list]
    n_steps: List[int]
    sizes: List[int]
    trained: List[Any] = field(default_factory=list)   # per-client pytrees
    flat_rows: List[Any] = field(default_factory=list)  # per-client (N,) rows
    losses: List[float] = field(default_factory=list)
    agg_params: Any = None    # set when aggregation was fused on device


@dataclass(eq=False)     # identity semantics: trials are packed by object
class _LiveTrial:
    spec: TrialSpec
    srv: FLServer
    eng: EventDrivenRuntime
    hp: HyperParams
    params: Any
    round_idx: int = 0
    accuracy: float = 0.0
    reached: bool = False
    done: bool = False
    wall: float = 0.0
    history: List[RoundRecord] = field(default_factory=list)
    plan: Any = None
    cohort: Optional[_Cohort] = None
    round_cost: Any = None     # set by _reduce_round, consumed by _finish_round
    _meta: Any = None          # cached _flatten meta (model-constant)


def _make_live(spec: TrialSpec) -> _LiveTrial:
    srv = build_server(spec)
    eng = EventDrivenRuntime(srv, fleet=srv.fleet,
                             config=srv.runtime_config or RuntimeConfig())
    eng.trace_label = spec.key()
    params = srv.model.init(jax.random.PRNGKey(srv.config.seed))
    return _LiveTrial(spec=spec, srv=srv, eng=eng,
                      hp=HyperParams(m=spec.m0, e=spec.e0), params=params)


def _group_key(tr: _LiveTrial) -> tuple:
    return (id(tr.srv.model), id(tr.srv.optimizer), tr.srv.config.prox_mu,
            tr.srv.config.batch_size)


_note_pack = note_pack_metrics      # pack-shape metrics, see batched.py


def _run_group_batched(ents: List[Tuple[_LiveTrial, int]]):
    """Train one model-group's packed entries; results land back in each
    trial's cohort.  FedAvg trials keep their clients as rows of the
    bucket's flat (M, N) matrix (their aggregation runs as one fused
    ``fed_reduce`` over those rows in ``_fused_sync_reduce``); other
    aggregators get per-client pytree slices.  Each trial's global params
    enter the pack through ONE per-round stack + an on-device gather per
    bucket, so host-side tree work stays O(trials), not O(clients).
    Upload-compressed lanes of non-FedAvg trials go through the
    quantize->dequantize round trip against their trial's global params
    (``compress_delta_lanes``) before unpacking — bit-identical per lane
    to the sequential path's ``compress_delta``, and masked off for
    uncompressed lanes so mixed grids pack together.  Compressed FedAvg
    lanes are masked off too: their round trip is fused into the segment
    reduce (same bits, one fewer dispatch)."""
    tr0 = ents[0][0]
    model, opt = tr0.srv.model, tr0.srv.optimizer
    bs = tr0.srv.config.batch_size
    run = _multi_cohort_fn(model, opt, tr0.srv.config.prox_mu)

    trials: List[_LiveTrial] = []
    slot: Dict[int, int] = {}
    for tr, _ in ents:
        if id(tr) not in slot:
            slot[id(tr)] = len(trials)
            trials.append(tr)
    stacked = _tree_stack([tr.params for tr in trials])

    n_steps = [tr.cohort.n_steps[j] for tr, j in ents]
    for t_pad, idx in sorted(bucket_by_steps(n_steps).items()):
        sel = [ents[i] for i in idx]
        m_pad = _pow2(len(sel))    # bound the compiled (T, M) shape set
        if obs.enabled():
            _note_pack(t_pad, m_pad, len(sel),
                       sum(n_steps[i] for i in idx))
        streams = [tr.cohort.streams[j] for tr, j in sel]
        xs, ys, masks, active = _stack_streams(
            streams + [[]] * (m_pad - len(sel)), bs, t_pad)
        slots = np.array([slot[id(tr)] for tr, _ in sel]
                         + [0] * (m_pad - len(sel)), np.int32)
        global_b = jax.tree.map(lambda s: s[slots], stacked)
        params_b, last_loss = run(global_b, jnp.asarray(xs), jnp.asarray(ys),
                                  jnp.asarray(masks), jnp.asarray(active))
        mask = lane_mask([tr.srv.config.compression
                          if tr.srv.aggregator.name != "fedavg" else None
                          for tr, _ in sel]
                         + [None] * (m_pad - len(sel)))
        if mask is not None:
            params_b = compress_delta_lanes(global_b, params_b, mask)
        flat = _flatten_cohort(params_b)
        ll = np.asarray(last_loss)
        for k, (tr, j) in enumerate(sel):
            if tr.srv.aggregator.name == "fedavg":
                tr.cohort.flat_rows[j] = flat[k]
            else:
                tr.cohort.trained[j] = jax.tree.map(
                    lambda p, k=k: p[k], params_b)
            tr.cohort.losses[j] = float(ll[k])


def _run_group_sharded(ents: List[Tuple[_LiveTrial, int]], mesh):
    """Train one all-FedAvg model-group's packed entries over the
    ``clients`` mesh axis; every trial's FedAvg aggregate comes back from
    the device directly (segment sum + psum)."""
    tr0 = ents[0][0]
    model, opt = tr0.srv.model, tr0.srv.optimizer
    bs = tr0.srv.config.batch_size
    n_dev = int(np.prod(mesh.devices.shape))
    compressed = any(tr.srv.config.compression not in (None, "none")
                     for tr, _ in ents)

    trials: List[_LiveTrial] = []
    slot: Dict[int, int] = {}
    for tr, _ in ents:
        if id(tr) not in slot:
            slot[id(tr)] = len(trials)
            trials.append(tr)
    n_t = len(trials)
    # FedAvg weights within each trial: n_j / n_total
    totals = [float(sum(tr.cohort.sizes)) for tr in trials]

    flat0, meta = _flatten(trials[0].params)
    t_seg = _pow2(n_t)     # segment count padded pow2: bounded shape set
    run = _sharded_multi_fn(model, opt, tr0.srv.config.prox_mu, mesh,
                            t_seg, tuple(meta[2]), compressed)
    # each lane's quant reference = its trial's dispatch-time globals
    qref = jnp.stack([_flatten(tr.params)[0] for tr in trials]
                     + [jnp.zeros_like(flat0)] * (t_seg - n_t))
    agg = jnp.zeros((n_t, flat0.shape[0]), flat0.dtype)
    n_steps = [tr.cohort.n_steps[j] for tr, j in ents]
    for t_pad, idx in sorted(bucket_by_steps(n_steps).items()):
        sel = [ents[i] for i in idx]
        m_pad = _pow2(len(sel))
        m_pad = int(np.ceil(m_pad / n_dev) * n_dev)   # shard-divisible
        if obs.enabled():
            _note_pack(t_pad, m_pad, len(sel),
                       sum(n_steps[i] for i in idx))
        pad = m_pad - len(sel)
        xs, ys, masks, active = _stack_streams(
            [tr.cohort.streams[j] for tr, j in sel] + [[]] * pad, bs, t_pad)
        global_b = _tree_stack([tr.params for tr, _ in sel]
                               + [sel[0][0].params] * pad)
        w = np.zeros(m_pad, np.float32)
        seg = np.zeros(m_pad, np.int32)    # pad lanes: seg 0, weight 0
        enabled = np.zeros(m_pad, bool)
        for k, (tr, j) in enumerate(sel):
            s = slot[id(tr)]
            w[k] = tr.cohort.sizes[j] / totals[s]
            seg[k] = s
            enabled[k] = tr.srv.config.compression not in (None, "none")
        if obs.enabled():
            obs.registry.inc("reduce_fused_dispatches")
            obs.registry.sample("reduce_rows", m_pad)
            obs.registry.sample("reduce_lanes", n_t)
        partial, last_loss = run(global_b, jnp.asarray(xs), jnp.asarray(ys),
                                 jnp.asarray(masks), jnp.asarray(active),
                                 jnp.asarray(w), jnp.asarray(seg), qref,
                                 jnp.asarray(enabled))
        agg = agg + partial[:n_t]
        ll = np.asarray(last_loss)
        for k, (tr, j) in enumerate(sel):
            tr.cohort.losses[j] = float(ll[k])
    # zero-step clients never trained: their weight enters at the trial's
    # own global params, as in every other execution path
    for tr, j in ents:
        if tr.cohort.n_steps[j] == 0:
            s = slot[id(tr)]
            zw = tr.cohort.sizes[j] / totals[s]
            agg = agg.at[s].add(zw * _flatten(tr.params)[0])  # noqa: REPRO001 -- mirrors the sequential engines' eager zero-step contribution op-for-op; jitting would change FMA contraction vs the pinned parity
    for tr in trials:
        tr.cohort.agg_params = _unflatten(agg[slot[id(tr)]], meta)


def _fedavg_from_rows(tr: _LiveTrial) -> Any:
    """FedAvg straight from the packed cohort's flat rows, as a T=1
    ``fed_reduce`` (raw counts normalized in-kernel, the int8 round trip
    fused when the trial compresses uploads) — the single-trial fallback
    with the exact bits of one lane of ``_fused_sync_reduce``."""
    from repro.kernels import ops as kernel_ops
    co = tr.cohort
    gflat, meta = _flatten(tr.params)
    if tr._meta is None:
        tr._meta = meta
    rows = [r if r is not None else gflat
            for r in co.flat_rows]     # zero-step clients stay at global
    w = jnp.asarray(np.asarray(co.sizes, np.float32))
    seg = jnp.zeros(len(rows), jnp.int32)
    comp = tr.srv.config.compression not in (None, "none")
    out = kernel_ops.fed_reduce(
        w, jnp.stack(rows), seg, 1, normalize=True,
        leaf_sizes=tuple(meta[2]) if comp else None,
        quant_ref=gflat[None, :] if comp else None,
        quant_enabled=jnp.ones(len(rows), bool) if comp else None)
    return _unflatten(out[0], tr._meta)


def _fused_sync_reduce(live: List[_LiveTrial]):
    """ONE ``fed_reduce`` dispatch per model group covering every FedAvg
    trial's aggregation: each trial is a segment (lane) of the packed
    (M, N) row matrix, raw example counts are normalized per segment
    in-kernel, and compressed trials' int8 upload round trips run against
    their own stacked global params inside the same dispatch.  Fills
    ``cohort.agg_params``; ``_reduce_round`` consumes it.  Bit-identical
    per trial to the standalone ``FedAvg.__call__`` path because the
    kernel's per-segment fold only ever sees that trial's rows, in the
    same client order (kernels/ref.py's packing-invariance contract)."""
    from repro.kernels import ops as kernel_ops
    todo = [tr for tr in live
            if tr.cohort is not None and tr.cohort.cids
            and tr.cohort.agg_params is None
            and tr.srv.aggregator.name == "fedavg"]
    groups: Dict[int, List[_LiveTrial]] = {}
    for tr in todo:
        groups.setdefault(id(tr.srv.model), []).append(tr)
    for grp in groups.values():
        t_pad = _pow2(len(grp))
        rows, w, seg, en, qrefs = [], [], [], [], []
        meta = None
        for s, tr in enumerate(grp):
            co = tr.cohort
            gflat, meta = _flatten(tr.params)
            if tr._meta is None:
                tr._meta = meta
            qrefs.append(gflat)
            comp = tr.srv.config.compression not in (None, "none")
            for j in range(len(co.cids)):
                r = co.flat_rows[j]
                rows.append(r if r is not None else gflat)
                w.append(co.sizes[j])
                seg.append(s)
                en.append(comp)
        m_pad = _pow2(len(rows))
        n = rows[0].shape[0]
        rows += [jnp.zeros(n, rows[0].dtype)] * (m_pad - len(rows))
        pad = m_pad - len(w)
        w += [0.0] * pad                  # zero-weight rows are bit-neutral
        seg += [0] * pad
        en += [False] * pad
        quant = any(en)
        if quant:
            qrefs += [jnp.zeros(n, qrefs[0].dtype)] * (t_pad - len(qrefs))
        if obs.enabled():
            obs.registry.inc("reduce_fused_dispatches")
            obs.registry.sample("reduce_rows", m_pad)
            obs.registry.sample("reduce_lanes", len(grp))
        with obs.span("REDUCE", phase="apply", n_lanes=len(grp),
                      n_rows=m_pad):
            out = kernel_ops.fed_reduce(
                jnp.asarray(np.asarray(w, np.float32)), jnp.stack(rows),
                jnp.asarray(np.asarray(seg, np.int32)), t_pad,
                normalize=True,
                leaf_sizes=tuple(meta[2]) if quant else None,
                quant_ref=jnp.stack(qrefs) if quant else None,
                quant_enabled=jnp.asarray(np.asarray(en)) if quant else None)
        for s, tr in enumerate(grp):
            tr.cohort.agg_params = _unflatten(out[s], tr._meta)


def _reduce_round(tr: _LiveTrial):
    """Per-trial selector updates, aggregation, and cost accounting — the
    pre-evaluation half of the engine's sync round sequence.  Evaluation
    is deliberately NOT here: the sweep loop batches every due trial's
    eval into one stacked dispatch between reduce and finish."""
    srv = tr.srv
    if tr.cohort is not None and tr.cohort.cids:
        co = tr.cohort
        for j, cid in enumerate(co.cids):
            srv.selector.update(int(cid), co.losses[j], co.sizes[j])
        if co.agg_params is not None:   # fused reduce (or sharded pack)
            tr.params = co.agg_params
        elif srv.aggregator.name == "fedavg":
            tr.params = _fedavg_from_rows(tr)
        else:
            updates = [
                ClientUpdate(
                    params=(co.trained[j] if co.trained[j] is not None
                            else tr.params),
                    n_examples=co.sizes[j], n_steps=co.n_steps[j],
                    last_loss=co.losses[j], client_id=int(cid))
                for j, cid in enumerate(co.cids)]
            tr.params = srv.aggregator(tr.params, updates)
    tr.round_cost = tr.eng.account_sync_round(tr.plan, tr.hp)


def _finish_round(tr: _LiveTrial, wall: float,
                  accuracy: Optional[float] = None):
    """Record the round and step the trial's own controller — the
    post-evaluation half of the engine's sync round sequence.
    ``accuracy`` is the trial's lane of the stacked evaluation (None when
    this round is not on the eval schedule: the last measured accuracy
    carries forward, as in the standalone loop)."""
    srv, cfg = tr.srv, tr.srv.config
    round_cost = tr.round_cost
    r = tr.round_idx
    if accuracy is not None:
        tr.accuracy = accuracy
    tr.history.append(RoundRecord(
        r, tr.hp.m, tr.hp.e, tr.accuracy, round_cost, wall,
        sim_time=tr.eng.clock.now, n_updates=len(tr.plan.included)))
    tr.round_idx += 1
    tr.cohort = None
    tr.plan = None
    tr.round_cost = None
    if tr.accuracy >= cfg.target_accuracy:
        tr.reached = True
        tr.done = True
        return
    tr.hp = srv.tuner.on_round(r, tr.accuracy, round_cost,
                               srv.cost_model.total, tr.hp)
    tr.hp = tr.hp.clamped(srv.dataset.n_clients, 100.0)
    if tr.round_idx >= cfg.max_rounds:
        tr.done = True


def _to_result(tr: _LiveTrial, engine: str) -> TrialResult:
    res = FLResult(
        reached_target=tr.reached, rounds=len(tr.history),
        final_accuracy=tr.accuracy,
        total_cost=tr.srv.cost_model.total.copy(), history=tr.history,
        final_m=tr.hp.m, final_e=tr.hp.e, params=tr.params,
        sim_time=tr.eng.clock.now)
    return TrialResult.from_flresult(tr.spec, res, tr.wall, engine)


def _resolve_sync_pack(pack: str):
    """Resolve the requested pack against the host topology: the sharded
    pack needs a real multi-device mesh, single-device hosts fall back to
    batched.  Returns ``(pack, mesh)``."""
    mesh = None
    if pack == "sharded":
        if jax.device_count() == 1:
            print("experiments: sharded packing needs a multi-device mesh "
                  "(jax.device_count() == 1); falling back to batched "
                  "packing", flush=True)
            pack = "batched"
        else:
            from repro.runtime.sharded import default_clients_mesh
            mesh = default_clients_mesh()
    return pack, mesh


def _sync_round_step(live: List[_LiveTrial], *, pack: str = "batched",
                     mesh=None, step_idx: int = 0) -> int:
    """Advance the given live sync trials by ONE packed virtual round
    (plan -> pack -> train -> apply -> eval -> finish, as described in the
    module docstring).  The live set is whatever the caller says it is —
    the fixed-set sweep passes every unfinished trial, the continuous-
    batching scheduler (experiments/scheduler.py) passes the pool's
    currently-admitted lanes — and every pack/eval shape is keyed off that
    live set, never off an initial trial count.  Trials that end this
    round come back with ``done`` set; retiring them (result emission,
    lane release) is the caller's job.  Returns the number of packed
    client entries."""
    t0 = time.perf_counter()  # noqa: REPRO004 -- per-macro-step wall share for TrialResult.wall; round accounting uses virtual clocks
    if obs.enabled():
        obs.registry.sample("lanes_live", len(live), step=step_idx,
                            engine="sync")
    # 1. plan every live trial's round (per-trial rng streams)
    with obs.span("PLAN", phase="plan", n_trials=len(live)):
        for tr in live:
            v0 = tr.eng.clock.now
            tr.plan = tr.eng.plan_sync_round(tr.hp)
            tr.eng.clock.advance_to(tr.eng.clock.now
                                    + tr.plan.round_time)
            if obs.enabled():
                obs.record("round", phase="round", trial=tr.spec.key(),
                           round_idx=tr.round_idx,
                           virtual=(v0, tr.eng.clock.now),
                           n_included=len(tr.plan.included),
                           n_active=len(tr.plan.active))
    # 2. materialize batch streams (the rng contract) and pack
    entries: List[Tuple[_LiveTrial, int]] = []
    with obs.span("PACK", phase="pack", n_trials=len(live)):
        for tr in live:
            cids = tr.plan.train_cids
            if not cids:
                tr.cohort = None
                continue
            data = [tr.srv.dataset.client_data(c) for c in cids]
            streams, n_steps = materialize_streams(
                data, tr.srv.config.batch_size, tr.hp.e, tr.srv.rng)
            sizes = [len(y) for _, y in data]
            tr.cohort = _Cohort(cids=cids, streams=streams,
                                n_steps=n_steps, sizes=sizes,
                                trained=[None] * len(cids),
                                flat_rows=[None] * len(cids),
                                losses=[0.0] * len(cids))
            entries.extend((tr, j) for j in range(len(cids)))
    # 3. group by model and train each group's packed cohort
    groups: Dict[tuple, List[Tuple[_LiveTrial, int]]] = {}
    for ent in entries:
        groups.setdefault(_group_key(ent[0]), []).append(ent)
    with perf.timed("train"), obs.span("TRAIN", phase="train",
                                       n_entries=len(entries),
                                       n_groups=len(groups)):
        for ents in groups.values():
            fused = (pack == "sharded"
                     and all(tr.srv.aggregator.name == "fedavg"
                             for tr, _ in ents))
            if fused:
                _run_group_sharded(ents, mesh)
            else:
                _run_group_batched(ents)
    # 4. per-trial aggregation + accounting, then ONE stacked eval of
    #    every due trial (grouped by model/dataset), then per-trial
    #    record + controller step
    with obs.span("APPLY", phase="apply", n_trials=len(live)):
        _fused_sync_reduce(live)       # one dispatch per model group
        for tr in live:
            _reduce_round(tr)
    due = [tr for tr in live
           if eval_due(tr.round_idx, tr.srv.config.eval_every,
                       tr.srv.config.max_rounds)]
    with obs.span("EVAL", phase="eval", n_due=len(due)):
        # pad_pow2: stacked eval shapes keyed off the live due count's
        # pow2, so lane churn (drain or continuous admission) does not
        # recompile per distinct count — parity-safe, lanes are independent
        accs = evaluate_stacked(
            [(tr.srv.model, tr.srv.dataset, tr.srv.config.eval_points,
              tr.params) for tr in due], mesh=mesh, pad_pow2=True)
    acc_of = {id(tr): a for tr, a in zip(due, accs)}
    wall = time.perf_counter() - t0  # noqa: REPRO004 -- wall shares are informational; parity compares params/history only
    if obs.enabled():
        obs.counter("t_sim", max(tr.eng.clock.now for tr in live))
    for tr in live:
        tr.wall += wall / len(live)
        _finish_round(tr, wall / len(live), acc_of.get(id(tr)))
    return len(entries)


def _run_vectorized_sync(specs: Sequence[TrialSpec], *,
                         pack: str = "batched",
                         on_result: Optional[Callable] = None,
                         verbose: bool = False) -> List[TrialResult]:
    """Run every sync-mode trial concurrently, one packed cohort per
    virtual round (``_sync_round_step``) over the set of unfinished
    trials until all are done."""
    pack, mesh = _resolve_sync_pack(pack)
    trials = [_make_live(s) for s in specs]
    results: List[TrialResult] = [None] * len(trials)
    engine = f"vectorized/{pack}"
    n_rounds = 0
    while True:
        live = [tr for tr in trials if not tr.done]
        if not live:
            break
        n_entries = _sync_round_step(live, pack=pack, mesh=mesh,
                                     step_idx=n_rounds)
        for tr in live:
            if tr.done:
                res = _to_result(tr, engine)
                results[trials.index(tr)] = res
                if on_result is not None:
                    on_result(res)
        n_rounds += 1
        if verbose and n_rounds % 10 == 0:
            done = sum(tr.done for tr in trials)
            print(f"  sweep round {n_rounds}: {done}/{len(trials)} trials "
                  f"done, {n_entries} clients packed", flush=True)
    return results


# ---------------------------------------------------------------------------
# the merged-queue event engine (async / buffered trials)
# ---------------------------------------------------------------------------

@dataclass(eq=False)     # identity semantics: trials are packed by object
class _EventTrial:
    """One live async/buffered trial of a merged-queue sweep: its server,
    runtime engine, event-loop state, and the facade binding it onto the
    sweep's merged event queue."""
    spec: TrialSpec
    srv: FLServer
    eng: EventDrivenRuntime
    view: TrialQueueView
    st: Any = None             # repro.runtime.engine.EventLoopState
    done: bool = False
    wall: float = 0.0


@dataclass
class _Lane:
    """One packed arrival: trial + its in-flight record + the batch stream
    materialized at the standalone loop's exact rng point.  ``params`` and
    ``loss`` are filled by the cohort training."""
    tr: _EventTrial
    fl: Any                    # repro.runtime.engine._InFlight
    stream: list
    n_steps: int
    params: Any = None
    loss: float = 0.0


def _make_event_live(spec: TrialSpec, merged: MergedEventQueue,
                     trial_ord: int) -> _EventTrial:
    srv = build_server(spec)
    eng = EventDrivenRuntime(srv, fleet=srv.fleet,
                             config=srv.runtime_config or RuntimeConfig())
    eng.trace_label = spec.key()
    view = TrialQueueView(merged, trial_ord)
    tr = _EventTrial(spec=spec, srv=srv, eng=eng, view=view)
    params = srv.model.init(jax.random.PRNGKey(srv.config.seed))
    # initial concurrency dispatches straight into the merged queue
    tr.st = eng.init_event_state(params, queue=view)
    return tr


def _coalesce_buckets(buckets: Dict[int, List[int]],
                      min_lanes: int = 4) -> Dict[int, List[int]]:
    """Merge under-filled step buckets upward into the next-larger one.

    The event pack holds at most one lane per trial, so strict
    ``bucket_by_steps`` grouping would often produce singleton buckets —
    one compiled dispatch per lane, which is exactly the overhead packing
    exists to amortize.  Promoting a small bucket's lanes into a larger
    t_pad only adds masked (frozen-state) steps, so results are unchanged;
    for big packs the original waste bound still applies because full
    buckets are left alone."""
    out: Dict[int, List[int]] = {}
    pending: List[int] = []
    for t_pad in sorted(buckets):
        pending.extend(buckets[t_pad])
        if len(pending) >= min_lanes or t_pad == max(buckets):
            out[t_pad] = pending     # the max bucket absorbs any tail
            pending = []
    return out


def _run_event_group(lanes: List[_Lane], min_lanes: int = 4):
    """Train one model-group's packed arrivals: one vmap lane per trial,
    each lane starting local training from ITS trial's dispatch-snapshot
    params (``global_in_axis=0`` also anchors the FedProx term there, as
    ``local_train`` does).  Buckets by pow2 step count (small buckets
    coalesced upward — see ``_coalesce_buckets``; the caller keys
    ``min_lanes`` off the LIVE lane count, not the sweep's initial T, so
    a draining or continuously-batched pool coalesces against what is
    actually resident) and pads the lane axis to a pow2 so compiled
    (T, M) shapes repeat across macro-steps — and are SHARED with the
    sync sweep path (same ``_multi_cohort_fn``)."""
    tr0 = lanes[0].tr
    model, opt = tr0.srv.model, tr0.srv.optimizer
    bs = tr0.srv.config.batch_size
    run = _multi_cohort_fn(model, opt, tr0.srv.config.prox_mu)
    buckets = _coalesce_buckets(
        bucket_by_steps([ln.n_steps for ln in lanes]), min_lanes=min_lanes)
    for t_pad, idx in sorted(buckets.items()):
        sel = [lanes[i] for i in idx]
        m_pad = _pow2(len(sel))    # bound the compiled (T, M) shape set
        if obs.enabled():
            _note_pack(t_pad, m_pad, len(sel),
                       sum(ln.n_steps for ln in sel))
        xs, ys, masks, active = _stack_streams(
            [ln.stream for ln in sel] + [[]] * (m_pad - len(sel)),
            bs, t_pad)
        global_b = _tree_stack([ln.fl.params for ln in sel]
                               + [sel[0].fl.params] * (m_pad - len(sel)))
        params_b, last_loss = run(global_b, jnp.asarray(xs), jnp.asarray(ys),
                                  jnp.asarray(masks), jnp.asarray(active))
        # upload-compressed lanes: quantize->dequantize against the lane's
        # dispatch snapshot, exactly what _client_update does per arrival
        mask = lane_mask([ln.tr.srv.config.compression for ln in sel]
                         + [None] * (m_pad - len(sel)))
        if mask is not None:
            params_b = compress_delta_lanes(global_b, params_b, mask)
        ll = np.asarray(last_loss)
        # one host transfer per leaf, then free numpy views per lane — much
        # cheaper than a device-slice dispatch per (lane, leaf)
        leaves, treedef = jax.tree.flatten(params_b)
        np_leaves = [np.asarray(l) for l in leaves]
        for k, ln in enumerate(sel):
            ln.params = jax.tree.unflatten(treedef, [l[k] for l in np_leaves])
            ln.loss = float(ll[k])


class _EventEngine:
    """Merged-queue engine state shared by the fixed-set wrapper
    (``run_vectorized_events``) and the continuous-batching scheduler
    (experiments/scheduler.py): ONE merged virtual-clock event queue
    spanning every live trial, with trial ordinals handed out at
    admission.  Admission order IS the merged queue's cross-trial tie
    order — the fixed-set wrapper admits in sorted-key order (so its tie
    order stays independent of caller spec order), the scheduler admits
    in queue order (so a drain is deterministic given the submission
    sequence).  Either way a trial's own event sequence — and therefore
    its floats — depends only on its private rngs and clock, never on
    which other trials share the queue."""

    def __init__(self):
        self.merged = MergedEventQueue()
        self.by_ord: Dict[int, _EventTrial] = {}
        self.n_steps = 0
        # ordinals are handed out monotonically and never reused — a
        # snapshot restore repopulates by_ord with only the live ordinals,
        # so len(by_ord) would hand a recycled ordinal to the next admit
        self.next_ord = 0

    def admit(self, spec: TrialSpec) -> _EventTrial:
        """Bring one async/buffered trial live on the merged queue (its
        initial concurrency dispatches push events immediately)."""
        if spec.mode not in ("async", "buffered"):
            raise ValueError(
                f"trial {spec.key()!r} is not an event-driven trial "
                "(the merged-queue engine covers the async/buffered modes; "
                "sync trials pack per round via run_vectorized)")
        trial_ord = self.next_ord
        self.next_ord += 1
        tr = _make_event_live(spec, self.merged, trial_ord)
        self.by_ord[trial_ord] = tr
        return tr

    def end_trial(self, tr: _EventTrial) -> None:
        """Retire one trial: account its tail window, mark it done, and
        drop its pending events so the merged queue never carries a
        retired trial's traffic into later macro-steps."""
        tr.eng.account_event_tail(tr.st)
        tr.done = True
        self.merged.drop_trial(tr.view.trial_ord)

    def macro_step(self, live: List[_EventTrial],
             on_done: Callable[[_EventTrial], None]) -> int:
        """One COLLECT/PACK/APPLY macro-step over the given live trials.

        (1) COLLECT — pop the merged queue in deterministic (time,
        admission ordinal, seq) order, advancing every live trial to its
        next pending arrival; dropouts are handled inline (loads charged,
        concurrency refilled), and events of trials that already
        contributed an arrival are deferred untouched (an arrival must be
        trained and applied before its trial's later events may be
        processed — FedAsync/FedBuff state is sequential per trial).
        Each collected arrival's batch stream is materialized at the
        exact point the standalone loop would consume the trial's server
        rng.  (2) PACK — all collected arrivals train as one flat cohort
        (one vmap lane per trial, each from its own dispatch snapshot),
        with bucket coalescing keyed off the live-lane count.  (3) APPLY
        — per trial on the host: selector update, FedAsync mixing /
        FedBuff buffering, accounting, evaluation, FedTune step, and
        concurrency refill, via the engine's own event-loop methods.

        ``on_done(tr)`` fires for every trial that ends during the step
        (after its tail accounting + event drop); the caller emits the
        result and releases the lane.  Returns the number of packed
        arrivals."""
        step_idx = self.n_steps
        self.n_steps += 1
        merged, by_ord = self.merged, self.by_ord

        def end(tr: _EventTrial):
            self.end_trial(tr)
            on_done(tr)

        t0 = time.perf_counter()  # noqa: REPRO004 -- per-macro-step wall share for TrialResult.wall; event order uses the merged virtual queue
        if obs.enabled():
            obs.registry.sample("lanes_live", len(live), step=step_idx,
                                engine="events")
        # 1. COLLECT one pending arrival per live trial
        lanes: List[_Lane] = []
        packed = set()
        stash = []
        with obs.span("COLLECT", phase="collect", n_live=len(live)) as _sp:
            while merged and len(packed) < len(live):
                ev = merged.pop()
                tr = by_ord[ev.trial_ord]
                if tr.done:
                    continue           # stale event of a finished trial
                if id(tr) in packed:
                    stash.append(ev)   # defer: this trial already packed
                    continue
                tr.eng.clock.advance_to(ev.time)
                if ev.kind == FAILURE:  # hard failure: retry inline, refill
                    tr.eng.handle_failure(tr.st, ev, queue=tr.view)
                    tr.eng.fill_event_concurrency(tr.st, tr.eng.clock.now,
                                                  queue=tr.view)
                    continue
                fl = tr.eng.plan_event(tr.st, ev)
                if fl is None:         # dropout: refill and keep collecting
                    tr.eng.fill_event_concurrency(tr.st, tr.eng.clock.now,
                                                  queue=tr.view)
                    continue
                data = [tr.srv.dataset.client_data(fl.client_id)]
                streams, n_steps = materialize_streams(
                    data, tr.srv.config.batch_size, fl.e, tr.srv.rng)
                lanes.append(_Lane(tr=tr, fl=fl, stream=streams[0],
                                   n_steps=n_steps[0]))
                packed.add(id(tr))
            for ev in stash:
                merged.requeue(ev)
            _sp.set(n_lanes=len(lanes), n_deferred=len(stash))
        # a live trial with nothing queued ends exactly as the standalone
        # loop does on an empty queue (the dispatch deadlock guard makes
        # this unreachable in practice, but the semantics must match)
        for tr in live:
            if not tr.done and id(tr) not in packed and not tr.view:
                end(tr)
        # 2. PACK: train all collected arrivals as one cohort per model group
        groups: Dict[tuple, List[_Lane]] = {}
        for ln in lanes:
            if ln.n_steps == 0:        # zero-step client: stays at snapshot
                ln.params, ln.loss = ln.fl.params, 0.0
                continue
            groups.setdefault(_group_key(ln.tr), []).append(ln)
        with perf.timed("train"), obs.span("PACK", phase="train",
                                           n_lanes=len(lanes),
                                           n_groups=len(groups)):
            for group in groups.values():
                _run_event_group(group, min_lanes=min(4, len(live)))
        # 3. APPLY per trial, in collect (= merged pop) order: first fold
        #    every lane into its trial's global model, then evaluate every
        #    aggregating-and-due trial in ONE stacked dispatch (grouped by
        #    model/dataset), then finish/refill per trial.  Evaluation
        #    consumes no rng and each trial's clock is private, so hoisting
        #    the evals between apply and finish preserves the standalone
        #    loop's per-trial operation order exactly.
        wall = time.perf_counter() - t0  # noqa: REPRO004 -- wall shares are informational; parity compares params/history only
        share = wall / max(len(lanes), 1)
        applied = []
        with obs.span("APPLY", phase="apply", n_lanes=len(lanes)):
            for ln in lanes:
                tr, fl = ln.tr, ln.fl
                tr.wall += share
                tr.srv.selector.update(int(fl.client_id), ln.loss,
                                       fl.n_examples)
                aggregated, staleness = tr.eng.apply_event(tr.st, fl,
                                                           ln.params)
                applied.append((ln, aggregated, staleness))
        due = [ln.tr for ln, aggregated, _s in applied
               if aggregated and eval_due(len(ln.tr.st.history),
                                          ln.tr.srv.config.eval_every,
                                          ln.tr.srv.config.max_rounds)]
        with obs.span("EVAL", phase="eval", n_due=len(due)):
            accs = evaluate_stacked(
                [(tr.srv.model, tr.srv.dataset, tr.srv.config.eval_points,
                  tr.st.params) for tr in due], pad_pow2=True)
        acc_of = {id(tr): a for tr, a in zip(due, accs)}
        for ln, aggregated, staleness in applied:
            tr = ln.tr
            if aggregated:
                tr.eng.finish_event_round(tr.st, staleness, share,
                                          accuracy=acc_of.get(id(tr)))
                if tr.st.reached:
                    end(tr)
                    continue
            tr.eng.fill_event_concurrency(tr.st, tr.eng.clock.now,
                                          queue=tr.view)
            if len(tr.st.history) >= tr.srv.config.max_rounds:
                end(tr)
        if obs.enabled() and live:
            obs.counter("t_sim", max(tr.eng.clock.now for tr in live))
        return len(lanes)


def run_vectorized_events(specs: Sequence[TrialSpec], *,
                          pack: str = "batched",
                          on_result: Optional[Callable] = None,
                          verbose: bool = False) -> List[TrialResult]:
    """Run T async/buffered trials concurrently off ONE merged event queue
    (``_EventEngine`` macro-steps over the set of unfinished trials).

    Parity: bit-identical to each trial's standalone ``FLServer.run()``
    (accuracies, costs, dispatch/staleness logs, (M, E) trajectories)."""
    for s in specs:
        if s.mode not in ("async", "buffered"):
            raise ValueError(
                f"trial {s.key()!r} is not an event-driven trial "
                "(run_vectorized_events covers the async/buffered modes; "
                "sync trials pack per round via run_vectorized)")
    if pack == "sharded":
        # event packs are one-arrival-per-trial wide and FedAsync/FedBuff
        # mixing is per-trial host state — there is no cross-client
        # aggregation to fuse on device, so the mesh layout buys nothing
        print("experiments: sharded packing does not apply to event-driven "
              "(async/buffered) trials — per-trial mixing is host-side; "
              "using the batched pack", flush=True)
        pack = "batched"

    ev = _EventEngine()
    # trial ordinals from sorted keys: the merged queue's cross-trial tie
    # order is then independent of the caller's spec order
    order = sorted(range(len(specs)), key=lambda i: specs[i].key())
    trials: List[_EventTrial] = [None] * len(specs)
    for i in order:
        trials[i] = ev.admit(specs[i])
    results: List[TrialResult] = [None] * len(specs)
    engine = f"vectorized-events/{pack}"

    def on_done(tr: _EventTrial):
        res = TrialResult.from_flresult(tr.spec, tr.eng.event_result(tr.st),
                                        tr.wall, engine)
        results[trials.index(tr)] = res
        if on_result is not None:
            on_result(res)

    while True:
        live = [tr for tr in trials if not tr.done]
        if not live:
            break
        n_lanes = ev.macro_step(live, on_done)
        if verbose and ev.n_steps % 20 == 0:
            done = sum(tr.done for tr in trials)
            print(f"  event sweep step {ev.n_steps}: {done}/{len(trials)}"
                  f" trials done, {n_lanes} arrivals packed", flush=True)
    return results


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def run_vectorized(specs: Sequence[TrialSpec], *, pack: str = "batched",
                   on_result: Optional[Callable[[TrialResult], None]] = None,
                   verbose: bool = False) -> List[TrialResult]:
    """Run every trial concurrently: sync trials through the round-packed
    engine (one cohort per virtual round), async/buffered trials through
    the merged-event-queue engine (one cohort per macro-step).  Both reuse
    the same compiled ``_multi_cohort_fn`` shapes.  Results come back in
    input-spec order; ``on_result`` fires per trial as it finishes.

    Upload-compressed trials vectorize like any others: the quantize->
    dequantize round trip is a per-lane transform inside the cohort
    packers (``compress_delta_lanes``), masked off for uncompressed lanes,
    so mixed grids pack into one cohort."""
    if pack not in PACKS:
        raise ValueError(f"unknown pack {pack!r}; valid packs: "
                         + ", ".join(PACKS))
    sync_specs = [s for s in specs if s.mode == "sync"]
    event_specs = [s for s in specs if s.mode != "sync"]
    out: Dict[str, TrialResult] = {}

    def keep(res: TrialResult):
        out[res.spec.key()] = res
        if on_result is not None:
            on_result(res)

    if sync_specs:
        _run_vectorized_sync(sync_specs, pack=pack, on_result=keep,
                             verbose=verbose)
    if event_specs:
        run_vectorized_events(event_specs, pack=pack, on_result=keep,
                              verbose=verbose)
    return [out[s.key()] for s in specs]


def run_sweep(specs: Sequence[TrialSpec], *, store=None,
              engine: str = "vectorized", pack: str = "batched",
              verbose: bool = False) -> List[TrialResult]:
    """Run a list of trials and (optionally) append each finished trial to
    ``store`` as it completes — the unit of resume is the trial, so a killed
    sweep restarts exactly at the first unfinished key.

    ``engine='vectorized'`` packs EVERY trial (sync trials per virtual
    round, async/buffered trials off the merged event queue; compressed
    trials quantize per lane inside the pack — nothing falls back).
    ``engine='sequential'`` runs everything one ``FLServer.run()`` at a
    time — engines are result-parity-equal, so stores can mix them."""
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; valid engines: "
                         + ", ".join(ENGINES))
    results: List[TrialResult] = []

    def emit(res: TrialResult):
        results.append(res)
        if store is not None:
            store.append(res.to_record())

    if engine == "sequential":
        for spec in specs:
            emit(run_trial(spec))
        return results

    if specs:
        run_vectorized(specs, pack=pack, on_result=emit, verbose=verbose)
    return results
