"""gemma2-2b — 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
Alternating local(4096)/global attention, attention + final logit soft-caps.
[arXiv:2408.00118]"""

from repro.configs.base import LayerSpec, ModelConfig, cycled_layers

_PATTERN = (
    LayerSpec(window=4096),   # local sliding-window layer
    LayerSpec(window=None),   # global layer
)

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    layers=cycled_layers(26, _PATTERN),
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
    source="arXiv:2408.00118",
)
