"""Metrics registry: phase timers, counters, gauges, histograms, series.

Two tiers with different always-on guarantees:

* **Phase timers** (``phase``/``phase_add``/``phase_seconds``/
  ``phase_call_count``) are always on — they are the backing store for
  the ``repro.perf`` shim, whose ``timed("train")``/``timed("eval")``
  split the benchmark suite has asserted on since PR 3.  Overhead is one
  ``perf_counter`` pair and two dict updates per phase, same as the old
  module-global implementation.
* **Observability metrics** (``inc``/``gauge``/``observe``/``sample``)
  are recorded unconditionally by this module but every call site gates
  on ``obs.enabled()`` first, so with tracing off no metric call is even
  reached — that is the zero-cost contract, pinned in tests/test_obs.py.

``sample`` feeds the metrics JSONL stream (``obs.export``): a bounded
list of ``{"name", "value", "step", ...tags}`` rows for time-series like
live-lane occupancy and per-bucket pack widths.  ``observe`` feeds
histograms (staleness, store write latency) summarized at export time.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

# Safety valve so a pathological run cannot grow the series list without
# bound; 1M rows is far beyond any smoke/bench sweep (which emit ~1e3).
SERIES_LIMIT = 1_000_000


def _percentile(sorted_vals: List[float], q: float) -> float:
    """Nearest-rank percentile on an already-sorted list."""
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, max(0, int(round(q * (len(sorted_vals) - 1)))))
    return sorted_vals[idx]


class MetricsRegistry:
    """Process-wide metric store (singleton at :data:`registry`)."""

    def __init__(self):
        self._phase_s: Dict[str, float] = {}
        self._phase_calls: Dict[str, int] = {}
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, List[float]] = {}
        self._series: List[Dict[str, Any]] = []

    # ---- phase timers (always on; repro.perf delegates here) ----------

    def phase_add(self, name: str, seconds: float):
        self._phase_s[name] = self._phase_s.get(name, 0.0) + seconds
        self._phase_calls[name] = self._phase_calls.get(name, 0) + 1

    @contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.phase_add(name, time.perf_counter() - t0)

    def phase_seconds(self, name: str) -> float:
        return self._phase_s.get(name, 0.0)

    def phase_call_count(self, name: str) -> int:
        return self._phase_calls.get(name, 0)

    def phase_snapshot(self) -> Dict[str, float]:
        return dict(self._phase_s)

    def phase_calls_snapshot(self) -> Dict[str, int]:
        return dict(self._phase_calls)

    # ---- observability metrics (call sites gate on obs.enabled()) -----

    def inc(self, name: str, value: float = 1.0):
        self._counters[name] = self._counters.get(name, 0.0) + value

    def gauge(self, name: str, value: float):
        self._gauges[name] = float(value)

    def observe(self, name: str, value: float):
        self._hists.setdefault(name, []).append(float(value))

    def sample(self, name: str, value: float, step: Optional[int] = None,
               **tags):
        if len(self._series) >= SERIES_LIMIT:
            return
        row: Dict[str, Any] = {"name": name, "value": float(value)}
        if step is not None:
            row["step"] = int(step)
        if tags:
            row.update(tags)
        self._series.append(row)

    # ---- accessors ----------------------------------------------------

    def counter_value(self, name: str) -> float:
        return self._counters.get(name, 0.0)

    def counters(self) -> Dict[str, float]:
        return dict(self._counters)

    def gauges(self) -> Dict[str, float]:
        return dict(self._gauges)

    def series(self, name: Optional[str] = None) -> List[Dict[str, Any]]:
        if name is None:
            return list(self._series)
        return [r for r in self._series if r["name"] == name]

    def histogram_summary(self, name: str) -> Dict[str, float]:
        vals = sorted(self._hists.get(name, []))
        if not vals:
            return {"count": 0}
        return {
            "count": len(vals),
            "min": vals[0],
            "max": vals[-1],
            "mean": sum(vals) / len(vals),
            "p50": _percentile(vals, 0.50),
            "p90": _percentile(vals, 0.90),
            "p99": _percentile(vals, 0.99),
        }

    def histograms(self) -> Dict[str, Dict[str, float]]:
        return {n: self.histogram_summary(n) for n in sorted(self._hists)}

    def snapshot(self) -> Dict[str, Any]:
        """Everything at once — what the benchmark and exporters read."""
        return {
            "phases": self.phase_snapshot(),
            "phase_calls": self.phase_calls_snapshot(),
            "counters": self.counters(),
            "gauges": self.gauges(),
            "histograms": self.histograms(),
            "n_series": len(self._series),
        }

    def reset(self):
        self._phase_s.clear()
        self._phase_calls.clear()
        self._counters.clear()
        self._gauges.clear()
        self._hists.clear()
        self._series.clear()


registry = MetricsRegistry()
