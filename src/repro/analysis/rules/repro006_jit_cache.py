"""REPRO006 — jit-cache hazards: per-iteration construction and
unhashable static arguments.

``jax.jit`` caches compilations on the *callable object*: build a fresh
jitted callable inside a per-round or per-event loop and every
iteration retraces and recompiles, silently turning a microsecond
dispatch into a multi-second stall.  The repo's sanctioned pattern is a
factory guarded by an explicit cache (``_step_cache``,
``_batched_step_cache``, ``EvalFnCache``) — the rule recognizes those
by a cache-flavored name in the enclosing function/class (or an
``lru_cache`` decorator) and stays quiet.  Separately, a call to a
jitted callable that passes a list/dict/set literal at a
``static_argnums``/``static_argnames`` position raises
``ValueError: unhashable`` at runtime; the rule resolves same-file
``name = jax.jit(f, static_...)`` bindings and checks call sites.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from ..core import FileContext, Rule, register
from ..scopes import FuncNode, dotted_parts, final_name

UNHASHABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
              ast.SetComp)


def _is_jit_call(node: ast.Call) -> bool:
    name = final_name(node.func)
    if name == "jit":
        return True
    if name == "partial" and node.args:
        return final_name(node.args[0]) == "jit"
    return False


def _jit_decorated(func) -> bool:
    for dec in func.decorator_list:
        if final_name(dec) == "jit":
            return True
        if isinstance(dec, ast.Call) and (
                final_name(dec.func) == "jit" or (
                    final_name(dec.func) == "partial" and dec.args
                    and final_name(dec.args[0]) == "jit")):
            return True
    return False


def _cache_marker(ctx: FileContext, node: ast.AST) -> bool:
    """True when the construction site is visibly cache-guarded: a
    'cache'-flavored name in the enclosing function, a Cache-named
    enclosing class, or an lru_cache/cache decorator."""
    fn = ctx.enclosing_function(node)
    if fn is not None:
        for dec in fn.decorator_list:
            if final_name(dec) in {"lru_cache", "cache"} or (
                    isinstance(dec, ast.Call)
                    and final_name(dec.func) in {"lru_cache", "cache"}):
                return True
        for sub in ast.walk(fn):
            if isinstance(sub, (ast.Name, ast.Attribute)):
                if any("cache" in p.lower() for p in dotted_parts(sub)):
                    return True
    for anc in ctx.ancestors(node):
        if isinstance(anc, ast.ClassDef) and "cache" in anc.name.lower():
            return True
    return False


def _static_spec(call: ast.Call) -> Tuple[Set[int], Set[str]]:
    nums: Set[int] = set()
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg not in {"static_argnums", "static_argnames"}:
            continue
        values: List[ast.AST] = []
        if isinstance(kw.value, (ast.Tuple, ast.List)):
            values = list(kw.value.elts)
        elif isinstance(kw.value, ast.Constant):
            values = [kw.value]
        for v in values:
            if isinstance(v, ast.Constant):
                if kw.arg == "static_argnums" and isinstance(v.value, int):
                    nums.add(v.value)
                elif kw.arg == "static_argnames" \
                        and isinstance(v.value, str):
                    names.add(v.value)
    return nums, names


@register
class JitCacheHazards(Rule):
    id = "REPRO006"
    name = "jit-cache-hazard"

    def check_file(self, ctx: FileContext):
        # name -> (static_argnums, static_argnames) for same-file
        # `f = jax.jit(g, static_...)` bindings with static args
        static_bound: Dict[str, Tuple[Set[int], Set[str]]] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) and _is_jit_call(node):
                self._check_construction(ctx, node)
                self._record_binding(ctx, node, static_bound)
            elif isinstance(node, FuncNode) and _jit_decorated(node):
                self._check_decorated(ctx, node)
        if static_bound:
            self._check_static_call_sites(ctx, static_bound)

    def _check_construction(self, ctx: FileContext, node: ast.Call):
        # a decorator IS the def site — _check_decorated owns that case
        parent = ctx.parent(node)
        if isinstance(parent, FuncNode + (ast.ClassDef,)) \
                and node in parent.decorator_list:
            return
        if ctx.enclosing_loop(node) is not None:
            ctx.add(node, self.id,
                    "jitted callable constructed inside a loop — every "
                    "iteration retraces and recompiles; hoist it out or "
                    "memoize the wrapper")
        elif ctx.enclosing_function(node) is not None \
                and not _cache_marker(ctx, node):
            ctx.add(node, self.id,
                    "jitted callable constructed per call with no visible "
                    "cache — memoize it (see _step_cache/_batched_step_"
                    "cache/EvalFnCache for the house pattern)")

    def _check_decorated(self, ctx: FileContext, func):
        if ctx.enclosing_loop(func) is not None:
            ctx.add(func, self.id,
                    f"@jit function `{func.name}` defined inside a loop — "
                    "every iteration creates a fresh callable and "
                    "retraces; hoist the definition")
        elif ctx.enclosing_function(func) is not None \
                and not _cache_marker(ctx, func):
            ctx.add(func, self.id,
                    f"@jit function `{func.name}` defined per call of its "
                    "enclosing function with no visible cache — memoize "
                    "the factory")

    def _record_binding(self, ctx: FileContext, call: ast.Call,
                        static_bound: Dict):
        nums, names = _static_spec(call)
        if not nums and not names:
            return
        parent = ctx.parent(call)
        if isinstance(parent, ast.Assign):
            for tgt in parent.targets:
                if isinstance(tgt, ast.Name):
                    static_bound[tgt.id] = (nums, names)

    def _check_static_call_sites(self, ctx: FileContext,
                                 static_bound: Dict):
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = node.func.id if isinstance(node.func, ast.Name) else None
            if name not in static_bound:
                continue
            nums, names = static_bound[name]
            for i, arg in enumerate(node.args):
                if i in nums and isinstance(arg, UNHASHABLE):
                    ctx.add(node, self.id,
                            f"unhashable literal at static_argnums "
                            f"position {i} of jitted `{name}` — static "
                            "args must be hashable (use a tuple or a "
                            "frozen config)")
            for kw in node.keywords:
                if kw.arg in names and isinstance(kw.value, UNHASHABLE):
                    ctx.add(node, self.id,
                            f"unhashable literal for static_argnames "
                            f"'{kw.arg}' of jitted `{name}` — static "
                            "args must be hashable")
