"""Paper Table 5: FedTune across datasets (FedAvg aggregation)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import (BenchSettings, emit, fedtune_for, improvement,
                               run_fl)
from repro.core.preferences import PAPER_PREFERENCES


def main(settings: BenchSettings, prefs=None):
    prefs = prefs or PAPER_PREFERENCES[:6]  # subset keeps CPU time sane
    targets = {"speech_command": 0.5, "emnist": 0.5, "cifar100": 0.3}
    for dataset, target in targets.items():
        base = run_fl(dataset, settings, aggregator="fedavg", target=target)
        gains = []
        for pref in prefs:
            tuner = fedtune_for(pref, settings.m0, settings.e0)
            res = run_fl(dataset, settings, tuner=tuner,
                         aggregator="fedavg", target=target)
            gains.append(improvement(pref, base.total_cost, res.total_cost))
        emit(f"table5/{dataset}", base.wall * 1e6,
             f"mean_gain={np.mean(gains):+.2f}%;std={np.std(gains):.2f};"
             f"base_acc={base.final_accuracy:.3f}")
