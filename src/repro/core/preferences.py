"""Application training preferences (paper §4): weights over the four
system overheads CompT, TransT, CompL, TransL."""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Preference:
    alpha: float   # CompT (computation time)
    beta: float    # TransT (transmission time)
    gamma: float   # CompL (computation load, FLOPs)
    delta: float   # TransL (transmission load, bytes)

    def __post_init__(self):
        s = self.alpha + self.beta + self.gamma + self.delta
        assert abs(s - 1.0) < 1e-6, f"preferences must sum to 1, got {s}"
        assert min(self.alpha, self.beta, self.gamma, self.delta) >= 0

    def as_tuple(self):
        return (self.alpha, self.beta, self.gamma, self.delta)

    def __str__(self):
        return (f"({self.alpha:g},{self.beta:g},{self.gamma:g},{self.delta:g})")


# The paper's 15 evaluation combinations (Table 4, first column).
PAPER_PREFERENCES = [
    Preference(1.0, 0.0, 0.0, 0.0),
    Preference(0.0, 1.0, 0.0, 0.0),
    Preference(0.0, 0.0, 1.0, 0.0),
    Preference(0.0, 0.0, 0.0, 1.0),
    Preference(0.5, 0.5, 0.0, 0.0),
    Preference(0.5, 0.0, 0.5, 0.0),
    Preference(0.5, 0.0, 0.0, 0.5),
    Preference(0.0, 0.5, 0.5, 0.0),
    Preference(0.0, 0.5, 0.0, 0.5),
    Preference(0.0, 0.0, 0.5, 0.5),
    Preference(1 / 3, 1 / 3, 1 / 3, 0.0),
    Preference(1 / 3, 1 / 3, 0.0, 1 / 3),
    Preference(1 / 3, 0.0, 1 / 3, 1 / 3),
    Preference(0.0, 1 / 3, 1 / 3, 1 / 3),
    Preference(0.25, 0.25, 0.25, 0.25),
]
