"""dbrx-132b — 40L d_model=6144 48H (GQA kv=8) d_ff=10752/expert vocab=100352,
fine-grained MoE: 16 experts top-4.  [hf:databricks/dbrx-base]"""

from repro.configs.base import FFN_MOE, ModelConfig, MoEConfig, uniform_layers

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10_752,
    vocab_size=100_352,
    layers=uniform_layers(40, ffn=FFN_MOE),
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10_752),
    rope_theta=500_000.0,
    tie_embeddings=False,
    source="hf:databricks/dbrx-base",
)
