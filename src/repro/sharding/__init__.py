from repro.sharding.ctx import (activation_rules, logical_constraint,
                                current_mesh, param_sharding_rules)
from repro.sharding.specs import (param_specs, input_specs_sharding,
                                  LOGICAL_RULES)

__all__ = [
    "activation_rules", "logical_constraint", "current_mesh",
    "param_sharding_rules", "param_specs", "input_specs_sharding",
    "LOGICAL_RULES",
]
