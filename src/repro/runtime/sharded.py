"""Clients-as-mesh-axis sharded cohort execution.

The batched path (batched.py) scales the cohort with ONE device's FLOPs:
scan-over-steps, vmap-over-clients, the whole cohort resident on a single
chip — beyond M ~ 64 that chip is the bottleneck.  Here the same
size-bucketed cohort is laid out along a 1-D ``clients`` mesh axis
(launch/mesh.py: ``make_clients_mesh``) under ``shard_map``: every device
holds M/D client slots, runs the identical ``cohort_scan`` body (shared
with batched.py) on its slice, reduces its slots' trained params to a
weighted partial sum through the fused ``fed_reduce`` kernel path (the
int8 upload round trip of compressed cohorts runs inside the same
dispatch), and a ``lax.psum`` over the ``clients`` axis completes the
FedAvg weighted mean ON DEVICE.  The host only ever receives the aggregated (N,) parameter
vector plus per-client scalar losses — a round never materializes (M, N)
per-client params off-device, so cohort size scales with device count.

Parity contract (pinned in tests/test_sharded.py the same way
tests/test_runtime.py pins batched-vs-sequential): batch streams are
materialized in client order from the same rng as the sequential/batched
paths, bucketing is shared with batched.py, and the on-device weighted
mean equals FedAvg over the batched path's per-client results up to float
reassociation.

Each bucket's cohort is padded up to a multiple of the axis size with
zero-weight client slots (all-False step masks freeze them at the global
params, zero aggregation weight erases them), so every shard is
shape-identical; padding waste per bucket is under one device row.
"""

from __future__ import annotations

from typing import Any, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.federated.aggregation import _flatten, _unflatten
from repro.kernels import ops as kernel_ops
from repro.launch.mesh import make_clients_mesh
from repro.optim.optimizers import Optimizer
from repro.models.registry import Model
from repro.runtime.batched import (_stack_streams, bucket_by_steps,
                                   cohort_scan, make_client_step,
                                   materialize_streams)
from repro.sharding.specs import clients_spec

_sharded_fn_cache = {}
_default_mesh_cache = None


def default_clients_mesh():
    """The process-wide ``clients`` mesh over every addressable device.
    Cached so repeated rounds reuse one mesh object (and therefore one
    compiled cohort program per (T, M) shape)."""
    global _default_mesh_cache
    if _default_mesh_cache is None:
        _default_mesh_cache = make_clients_mesh()
    return _default_mesh_cache


class ShardedRound(NamedTuple):
    """Result of one sharded cohort round (input client order)."""
    params: Any                # FedAvg weighted mean over the cohort
    last_losses: np.ndarray    # per-client final local loss
    n_steps: List[int]         # local steps actually taken per client
    n_examples: List[int]      # client dataset sizes (the FedAvg weights)


def _flatten_cohort(params_b):
    """A (M, ...) stacked params pytree -> (M, N) row matrix, leaf order
    matching ``aggregation._flatten`` so flat vectors interconvert."""
    leaves = jax.tree.leaves(params_b)
    m = leaves[0].shape[0]
    return jnp.concatenate([l.reshape(m, -1) for l in leaves], axis=1)


def _make_sharded_cohort_fn(model: Model, optimizer: Optimizer,
                            prox_mu: float, mesh,
                            compression: Optional[str] = None):
    key = (id(model), id(optimizer), prox_mu, id(mesh),
           compression if compression not in (None, "none") else None)
    if key in _sharded_fn_cache:
        return _sharded_fn_cache[key]

    one_client = make_client_step(model, optimizer, prox_mu)
    axis = mesh.axis_names[0]
    compressed = compression not in (None, "none")

    def shard_body(xs, ys, masks, active, weights, global_params):
        """Runs on one device with its slice of the cohort: the shared
        scan/vmap body over the local client slots, then ONE ``fed_reduce``
        call fusing the upload round trip when compression is on (the
        aggregate must be formed from what the server would reconstruct)
        with the local weighted partial sum, completed by a psum across
        the clients axis."""
        m_loc = active.shape[1]
        global_b = jax.tree.map(
            lambda p: jnp.broadcast_to(p, (m_loc,) + p.shape), global_params)
        opt_b = jax.vmap(optimizer.init)(global_b)
        params_b, last_loss = cohort_scan(
            one_client, global_b, opt_b, xs, ys, masks, active,
            global_params)
        flat = _flatten_cohort(params_b)                   # (M_loc, N)
        seg = jnp.zeros(m_loc, jnp.int32)
        # static at trace time: per-leaf widths for the fused quant scales
        leaf_sizes = tuple(int(np.prod(p.shape))
                           for p in jax.tree.leaves(global_params))
        qref = _flatten_cohort(jax.tree.map(
            lambda p: p[None], global_params))             # (1, N)
        partial = kernel_ops.fed_reduce(                   # (1, N)
            weights, flat, seg, 1,
            leaf_sizes=leaf_sizes if compressed else None,
            quant_ref=qref if compressed else None)
        return jax.lax.psum(partial[0], axis), last_loss

    @jax.jit
    def run(xs, ys, masks, active, weights, global_params):
        in_specs = (clients_spec(xs.ndim, 1, axis),
                    clients_spec(ys.ndim, 1, axis),
                    clients_spec(masks.ndim, 1, axis),
                    clients_spec(active.ndim, 1, axis),
                    clients_spec(1, 0, axis),
                    jax.tree.map(lambda _: P(), global_params))
        return shard_map(shard_body, mesh=mesh, in_specs=in_specs,
                         out_specs=(P(), clients_spec(1, 0, axis)))(
                             xs, ys, masks, active, weights, global_params)

    _sharded_fn_cache[key] = run
    return run


def sharded_fedavg_train(model: Model, global_params,
                         data: Sequence[Tuple[np.ndarray, np.ndarray]], *,
                         passes: float, batch_size: int,
                         optimizer: Optimizer, rng: np.random.Generator,
                         prox_mu: float = 0.0,
                         client_ids: Optional[Sequence[int]] = None,
                         mesh=None,
                         compression: Optional[str] = None) -> ShardedRound:
    """Train the whole cohort sharded over the ``clients`` mesh axis and
    return the FedAvg aggregate directly (weights n_k / n_total), without
    materializing per-client params on the host.  ``client_ids`` is
    accepted for signature symmetry with ``batched_local_train``; results
    come back in input order regardless.  ``compression`` applies the
    upload round trip per lane on device, before the fused aggregation."""
    del client_ids
    mesh = mesh if mesh is not None else default_clients_mesh()
    n_dev = int(np.prod(mesh.devices.shape))
    run = _make_sharded_cohort_fn(model, optimizer, prox_mu, mesh,
                                  compression)
    streams, n_steps = materialize_streams(data, batch_size, passes, rng)
    assert max(n_steps) > 0, "cohort with zero local steps"
    sizes = [len(y) for _, y in data]
    w = np.asarray(sizes, np.float64) / float(sum(sizes))  # FedAvg weights

    global_flat, meta = _flatten(global_params)
    agg = jnp.zeros_like(global_flat)
    losses = np.zeros(len(data), np.float64)
    for t_pad, idx in sorted(bucket_by_steps(n_steps).items()):
        pad_m = (-len(idx)) % n_dev
        xs, ys, masks, active = _stack_streams(
            [streams[i] for i in idx] + [[]] * pad_m, batch_size, t_pad)
        wb = np.zeros(len(idx) + pad_m, np.float32)
        wb[:len(idx)] = w[idx]
        part, last_loss = run(jnp.asarray(xs), jnp.asarray(ys),
                              jnp.asarray(masks), jnp.asarray(active),
                              jnp.asarray(wb), global_params)
        agg = agg + part
        losses[idx] = np.asarray(last_loss)[:len(idx)]

    # 0-step clients never trained: they enter the FedAvg mean at the
    # global params, exactly as the batched/sequential paths include them
    zero_w = float(sum(w[i] for i, t in enumerate(n_steps) if t == 0))
    if zero_w > 0.0:
        agg = agg + zero_w * global_flat
    return ShardedRound(params=_unflatten(agg, meta), last_losses=losses,
                        n_steps=n_steps, n_examples=sizes)
