"""Sweep launcher: run whole grids of FedTune trials as one workload.

Expands a product grid (datasets x aggregators x preferences x seeds x
(M0,E0) x tuners x runtime modes x fleet profiles), skips every trial
already present in the JSONL result store (resume-by-trial-key — kill the
process and re-invoke to continue), and runs the rest through the
vectorized trials-as-an-axis engine (repro.experiments.runner) or
one-at-a-time.  Sync trials pack per virtual round; async/buffered trials
pack off a merged multi-trial event queue — both bit-identical to
independent runs, so engines can be mixed freely against one store.

Usage:
  PYTHONPATH=src python -m repro.launch.sweep \
      --datasets emnist --aggregators fedavg,fedadam \
      --preferences 0,4,14 --seeds 2 --rounds 20 \
      --out runs/sweep.jsonl --table

  # the paper's 15 preference vectors on one dataset
  PYTHONPATH=src python -m repro.launch.sweep --preferences all --rounds 30

  # runtime regimes and fleet profiles as grid axes (columns in --table)
  PYTHONPATH=src python -m repro.launch.sweep --mode sync,async,buffered \
      --het homogeneous,stragglers --rounds 10 --table

  # upload compression as a grid axis: int8 trials vectorize like any
  # others (per-lane quantization inside the packed cohorts)
  PYTHONPATH=src python -m repro.launch.sweep --compression none,int8 \
      --mode sync,async --rounds 10 --table

  # CI smoke: a fixed 24-trial reduced grid; --limit N runs only the first
  # N pending trials (the second invocation resumes the remainder)
  PYTHONPATH=src python -m repro.launch.sweep --preset smoke --limit 8
  PYTHONPATH=src python -m repro.launch.sweep --preset smoke --table

``--preferences`` takes 'all', indices into the paper's Table-4 list
('0,4,14'), or literal quads separated by ';'.  ``--init`` carries the
(M0, E0) axis as colon pairs: '5:2.0;10:1.0'.  ``--mode`` and ``--het``
take comma lists and become grid axes.  ``--pack sharded`` lays the packed
sync cohort over the ``clients`` mesh axis (multi-device; on CPU set
XLA_FLAGS=--xla_force_host_platform_device_count=8); event-driven trials
always use the batched pack (their per-trial FedAsync/FedBuff mixing is
host-side, so there is nothing to fuse on-device).
"""

from __future__ import annotations

import argparse
import time


def smoke_grid():
    """The CI smoke grid: 24 tiny reduced-dataset trials (18 fedtune +
    6 shared fixed baselines)."""
    from repro.experiments import SweepSpec, TrialSpec, parse_preferences
    return SweepSpec(
        datasets=("emnist",),
        aggregators=("fedavg", "fednova", "fedadam"),
        preferences=parse_preferences("0,3,14"),
        seeds=(0, 1),
        inits=((4, 1.0),),
        base=TrialSpec(rounds=3, target_accuracy=0.99, batch_size=5,
                       eval_points=128),
    )


def smoke_async_grid():
    """The CI event-runtime smoke grid: 8 tiny trials spanning the async
    and buffered runtime modes (fedtune + fixed baselines per mode), all
    vectorized off the merged event queue."""
    from repro.experiments import SweepSpec, TrialSpec, parse_preferences
    return SweepSpec(
        datasets=("emnist",),
        aggregators=("fedavg",),
        preferences=parse_preferences("14"),
        seeds=(0, 1),
        inits=((4, 1.0),),
        modes=("async", "buffered"),
        base=TrialSpec(rounds=2, target_accuracy=0.99, batch_size=5,
                       eval_points=128),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", default="emnist",
                    help="comma list: speech_command,emnist,cifar100")
    ap.add_argument("--aggregators", default="fedavg",
                    help="comma list, e.g. fedavg,fednova,fedadam")
    ap.add_argument("--preferences", default="14",
                    help="'all', paper indices '0,4,14', or quads "
                         "'1,0,0,0;0.25,0.25,0.25,0.25'")
    ap.add_argument("--seeds", type=int, default=1,
                    help="number of seeds (0..N-1)")
    ap.add_argument("--seed-base", type=int, default=0)
    ap.add_argument("--tuners", default="fedtune,fixed")
    ap.add_argument("--init", default="5:2.0",
                    help="(M0,E0) axis as colon pairs: '5:2.0;10:1.0'")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--target", type=float, default=0.5)
    ap.add_argument("--batch-size", type=int, default=10)
    ap.add_argument("--mode", default="sync",
                    help="comma list of runtime modes (grid axis): "
                         "sync,async,buffered")
    ap.add_argument("--het", default="homogeneous",
                    help="comma list of fleet profiles (grid axis): "
                         "homogeneous,mild,stragglers,mobile")
    ap.add_argument("--compression", default="none",
                    help="comma list of upload-compression methods (grid "
                         "axis): none,int8 — compressed trials vectorize "
                         "like any others (lane-wise quantization)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale datasets (default: reduced)")
    ap.add_argument("--engine", default="vectorized",
                    choices=("vectorized", "sequential"))
    ap.add_argument("--pack", default="batched",
                    choices=("batched", "sharded"),
                    help="vectorized cohort packing: one device (batched) "
                         "or the clients mesh axis (sharded; sync trials "
                         "only — event-driven trials pack batched)")
    ap.add_argument("--out", default="runs/sweep.jsonl",
                    help="JSONL result store (resume key source)")
    ap.add_argument("--no-resume", action="store_true",
                    help="truncate the store instead of skipping "
                         "completed trial keys")
    ap.add_argument("--limit", type=int, default=0,
                    help="run at most N pending trials (0 = all)")
    ap.add_argument("--table", action="store_true",
                    help="emit the paper-style overhead-reduction table")
    ap.add_argument("--preset", default=None,
                    choices=("smoke", "smoke-async"),
                    help="named grid (smoke = the 24-trial CI grid; "
                         "smoke-async = the 8-trial async/buffered "
                         "event-runtime grid)")
    ap.add_argument("--trace", nargs="?", const="auto", default=None,
                    metavar="PATH",
                    help="record a dual-clock trace of the sweep: Chrome "
                         "trace-event JSON (open in Perfetto) plus a "
                         "metrics JSONL next to it.  Default paths derive "
                         "from --out (<out>.trace.json / <out>"
                         ".metrics.jsonl); tracing is bit-parity-neutral")
    ap.add_argument("--trace-jax", action="store_true",
                    help="with --trace: also open jax.profiler trace "
                         "annotations per span so device profiles line up")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    from repro.experiments import (ResultStore, SweepSpec, TrialSpec,
                                   paper_table, parse_preferences, run_sweep)

    if args.preset == "smoke":
        sweep = smoke_grid()
    elif args.preset == "smoke-async":
        sweep = smoke_async_grid()
    else:
        inits = []
        for pair in args.init.split(";"):
            m0, e0 = pair.split(":")
            inits.append((int(m0), float(e0)))
        sweep = SweepSpec(
            datasets=tuple(args.datasets.split(",")),
            aggregators=tuple(args.aggregators.split(",")),
            preferences=parse_preferences(args.preferences),
            seeds=tuple(range(args.seed_base, args.seed_base + args.seeds)),
            tuners=tuple(args.tuners.split(",")),
            inits=tuple(inits),
            modes=tuple(args.mode.split(",")),
            hets=tuple(args.het.split(",")),
            compressions=tuple(
                None if c in ("", "none") else c
                for c in args.compression.split(",")),
            base=TrialSpec(rounds=args.rounds, target_accuracy=args.target,
                           batch_size=args.batch_size,
                           reduced=not args.full),
        )
    specs = sweep.expand()     # validates every axis value eagerly

    store = ResultStore(args.out)
    if args.no_resume:
        store.clear()
    done = store.completed_keys()
    pending = [s for s in specs if s.key() not in done]
    skipped = len(specs) - len(pending)
    print(f"sweep: {len(specs)} trials in grid; resume: skipping {skipped} "
          f"completed, {len(pending)} pending", flush=True)
    if args.limit > 0:
        pending = pending[:args.limit]
        print(f"sweep: --limit {args.limit} -> running {len(pending)} "
              "trial(s) this invocation", flush=True)

    if args.trace is not None:
        from repro import obs
        obs.enable(jax_annotations=args.trace_jax)

    t0 = time.perf_counter()
    results = run_sweep(pending, store=store, engine=args.engine,
                        pack=args.pack, verbose=args.verbose)
    wall = time.perf_counter() - t0
    for res in results:
        print(f"  done {res.spec.key()}  acc={res.final_accuracy:.3f} "
              f"rounds={res.rounds} M={res.final_m} E={res.final_e:g}",
              flush=True)
    print(f"sweep: ran {len(results)} trial(s) in {wall:.1f}s "
          f"({args.engine} engine); store={args.out}", flush=True)

    if args.trace is not None:
        from repro import obs
        from repro.obs.export import (trace_paths_for, write_chrome_trace,
                                      write_metrics_jsonl)
        obs.disable()
        trace_path, metrics_path = trace_paths_for(
            args.out, None if args.trace == "auto" else args.trace)
        write_chrome_trace(trace_path)
        n_rows = write_metrics_jsonl(metrics_path)
        print(f"sweep: trace -> {trace_path} ({len(obs.tracer.spans)} "
              f"spans); metrics -> {metrics_path} ({n_rows} rows) — open "
              "the trace at https://ui.perfetto.dev", flush=True)

    if args.table:
        print()
        print(paper_table(store.load(),
                          title="FedTune sweep (reduced-scale reproduction)"))


if __name__ == "__main__":
    main()
