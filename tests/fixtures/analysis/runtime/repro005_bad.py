"""BAD fixture: engine stages without spans, metric outside the catalog.

``plan_round``/``apply_update`` match the engine's stage-method shape
but carry no ``@obs.traced``/``obs.span``; the metric name is absent
from ``trace_schema.json``.  REPRO005 must fire three times.
"""

from repro import obs


class MiniEngine:
    def plan_round(self, st):            # REPRO005: stage without a span
        return st

    def apply_update(self, st):          # REPRO005: stage without a span
        obs.registry.inc("bogus_metric_name")   # REPRO005: not in catalog
        return st
