"""Per-client batching with fixed shapes (pad + mask) so jit never retraces
when FedTune changes E or clients have different amounts of data."""

from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def client_batches(x: np.ndarray, y: np.ndarray, batch_size: int,
                   passes: float, rng: np.random.Generator
                   ) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Yield (x, y, mask) batches covering ``passes`` epochs of the client's
    data.  ``passes`` may be fractional (paper's E=0.5: half the data).
    Batches are padded to ``batch_size`` with mask=0 rows."""
    n = len(y)
    total = int(round(passes * n))
    if total <= 0:
        return
    order = np.concatenate([
        rng.permutation(n) for _ in range(int(np.ceil(total / n)))
    ])[:total]
    for start in range(0, total, batch_size):
        idx = order[start:start + batch_size]
        bx, by = x[idx], y[idx]
        mask = np.ones(len(idx), np.bool_)
        pad = batch_size - len(idx)
        if pad:
            bx = np.concatenate([bx, np.zeros((pad,) + bx.shape[1:], bx.dtype)])
            by = np.concatenate([by, np.zeros((pad,), by.dtype)])
            mask = np.concatenate([mask, np.zeros(pad, np.bool_)])
        yield bx, by, mask


def num_local_steps(n_examples: int, batch_size: int, passes: float) -> int:
    total = int(round(passes * n_examples))
    return int(np.ceil(total / batch_size)) if total > 0 else 0
