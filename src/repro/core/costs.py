"""System-overhead accounting (paper §3.1, eqs. 2-5).

Clients are homogeneous in hardware/network (paper assumption), so

  CompT  = C1 * E * sum_r max_k b_{k,r} n_k      (slowest participant)
  TransT = C2 * R
  CompL  = C3 * E * sum_r sum_k b_{k,r} n_k
  TransL = C4 * R * M

Paper convention for the constants: C1 = C3 = model FLOPs per input,
C2 = C4 = model parameter count.  ``CostModel.add_round`` accumulates the
four overheads from per-round telemetry (participant example counts and the
passes actually run), which also supports heterogeneous E (FedNova-style
extensions) because it sums what each participant actually did.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

from repro.core.preferences import Preference

_EPS = 1e-12   # zero-baseline clamp, same convention as FedTune._comparison


@dataclass
class SystemCost:
    comp_t: float = 0.0
    trans_t: float = 0.0
    comp_l: float = 0.0
    trans_l: float = 0.0

    def as_tuple(self):
        return (self.comp_t, self.trans_t, self.comp_l, self.trans_l)

    def copy(self) -> "SystemCost":
        return SystemCost(*self.as_tuple())

    def __sub__(self, other: "SystemCost") -> "SystemCost":
        return SystemCost(self.comp_t - other.comp_t,
                          self.trans_t - other.trans_t,
                          self.comp_l - other.comp_l,
                          self.trans_l - other.trans_l)

    def weighted_relative_to(self, baseline: "SystemCost",
                             pref: Preference) -> float:
        """Paper eq. (6): I(baseline, self). Negative => self is better.

        A zero baseline overhead is legitimate (e.g. a compressed-upload
        run whose window accrues no transmission), so it is clamped to
        ``_EPS`` — the same convention as ``FedTune._comparison`` — rather
        than asserted away."""
        terms = []
        for w, a, b in zip(pref.as_tuple(), self.as_tuple(),
                           baseline.as_tuple()):
            if w == 0.0:
                continue
            terms.append(w * (a - b) / max(b, _EPS))
        return float(sum(terms))


@dataclass
class CostModel:
    """Accumulates eqs. (2)-(5) round by round."""

    flops_per_example: float      # C1 = C3
    param_count: float            # C2 = C4
    backward_multiplier: float = 3.0  # fwd+bwd ~= 3x fwd FLOPs
    total: SystemCost = field(default_factory=SystemCost)
    rounds: int = 0

    @property
    def train_flops_per_example(self) -> float:
        """C1 (= C3): forward+backward FLOPs per training example."""
        return self.flops_per_example * self.backward_multiplier

    def traffic_halves(self, upload_factor: float = 1.0):
        """(download, upload) units per client round under the paper's
        convention that a full round moves ``param_count`` total, split
        half down / half up, with only the upload compressible.  Single
        source of truth for the runtime clock AND the deadline selector's
        ranking signal — they must not drift apart."""
        return self.param_count * 0.5, self.param_count * upload_factor * 0.5

    def add_round(self, participant_examples: Sequence[float],
                  passes: float, *, upload_factor: float = 1.0) -> SystemCost:
        """participant_examples: examples per selected client this round
        (already scaled by the fraction of data a pass covers);
        passes: E; upload_factor < 1 models compressed uploads (the
        download half of the round stays full precision).
        Returns this round's cost."""
        m = len(participant_examples)
        assert m >= 1
        c1 = c3 = self.flops_per_example * self.backward_multiplier
        c2 = c4 = self.param_count
        r = SystemCost(
            comp_t=c1 * passes * max(participant_examples),
            trans_t=c2 * (1.0 + upload_factor) / 2.0,
            comp_l=c3 * passes * sum(participant_examples),
            trans_l=c4 * m * (1.0 + upload_factor) / 2.0,
        )
        self._accumulate(r)
        return r

    def add_timed_round(self, *, comp_time: float, trans_time: float,
                        comp_load: float, trans_load: float) -> SystemCost:
        """Heterogeneous-runtime accounting: the *time* overheads come from
        per-client simulated wall-clock (critical path over the round's
        participants, or virtual-clock deltas in async modes) instead of the
        homogeneous ``C1 * E * max_k n_k`` proxy; the *load* overheads stay
        exact work sums.  Over a homogeneous unit-rate fleet the critical
        path degenerates to eqs. (2)-(5), so this strictly generalizes
        ``add_round``."""
        r = SystemCost(comp_t=comp_time, trans_t=trans_time,
                       comp_l=comp_load, trans_l=trans_load)
        self._accumulate(r)
        return r

    def _accumulate(self, r: SystemCost):
        self.total.comp_t += r.comp_t
        self.total.trans_t += r.trans_t
        self.total.comp_l += r.comp_l
        self.total.trans_l += r.trans_l
        self.rounds += 1
