"""Pallas TPU kernel: flash attention with GQA, causal/sliding-window masks
and logit soft-cap (covers gemma2-style archs and long-context serving).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the last axis is
sequential on TPU, so the online-softmax state (m, l, acc) lives in VMEM
scratch across kv iterations.  GQA is handled in the BlockSpec index maps
(kv head = q head // group), so grouped K/V are never materialized.  Fully
masked kv blocks (beyond the causal frontier or outside the sliding window)
are skipped with ``pl.when`` — unlike the pure-jnp fallback, no masked FLOPs
are spent.

VMEM per grid step: q (BQ, D) + k/v (BK, D) + acc (BQ, D) f32 + scores
(BQ, BK) f32 ~= 1.3 MB at BQ=BK=512, D=128 — comfortably inside the ~16 MB
v5e VMEM with double buffering.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            cap: Optional[float], bq: int, bk: int, nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk
    # static-shape positions; masks built per block
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # Is any entry of this (q_blk, k_blk) tile unmasked?
    live = True
    if causal:
        live = jnp.asarray(k_start <= q_start + bq - 1)
    if window is not None:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1)[:, None]          # (BQ, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)[:, None]
        acc_scr[...] = (acc_scr[...] * alpha
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "cap", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, cap: Optional[float] = None,
                    block_q: int = DEFAULT_BQ, block_k: int = DEFAULT_BK,
                    interpret: bool = False):
    """q: (B, H, S, D); k, v: (B, Kh, T, D), H % Kh == 0 -> (B, H, S, D).

    Assumes self-attention alignment: query i attends keys <= i + (T - S)."""
    b, h, s, d = q.shape
    kh, t = k.shape[1], k.shape[2]
    assert h % kh == 0, (h, kh)
    g = h // kh
    bq = min(block_q, s)
    bk = min(block_k, t)
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)
    nq, nk = s // bq, t // bk
    assert s == t or not causal or s == 1, (
        "causal kernel expects aligned self-attention")

    kernel = functools.partial(
        _kernel, scale=d ** -0.5, causal=causal, window=window, cap=cap,
        bq=bq, bk=bk, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
