"""Quickstart: federated training with FedTune in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

Trains a small MLP on the synthetic EMNIST-like federated dataset with
FedAvg, letting FedTune adjust (M, E) for a computation-load-sensitive
application (gamma = 1).
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.paper_models import MLPConfig
from repro.core import CostModel, FedTune, FedTuneConfig, Preference
from repro.core.tuner import HyperParams
from repro.data import emnist_like
from repro.federated import FLConfig, FLServer, get_aggregator
from repro.models import build_model
from repro.optim.optimizers import get_optimizer


def main():
    dataset = emnist_like(reduced=True)
    model = build_model(MLPConfig(name="mlp", in_dim=784, hidden=(48,),
                                  n_classes=16))
    n_params = sum(p.size for p in jax.tree.leaves(
        model.init(jax.random.PRNGKey(0))))

    preference = Preference(0.0, 0.0, 1.0, 0.0)   # CompL-sensitive app
    tuner = FedTune(FedTuneConfig(preference=preference),
                    HyperParams(m=5, e=2))
    server = FLServer(
        model, dataset,
        aggregator=get_aggregator("fedavg"),
        optimizer=get_optimizer("sgd", 0.03, momentum=0.9),
        cost_model=CostModel(flops_per_example=2 * n_params,
                             param_count=n_params),
        config=FLConfig(m=5, e=2, batch_size=10, target_accuracy=0.5,
                        max_rounds=80, log_every=10),
        tuner=tuner)
    result = server.run()

    c = result.total_cost
    print(f"\nreached={result.reached_target} rounds={result.rounds} "
          f"acc={result.final_accuracy:.3f}")
    print(f"final hyper-parameters: M={result.final_m} E={result.final_e:g} "
          f"({tuner.decisions} FedTune decisions)")
    print(f"CompT={c.comp_t:.3g}  TransT={c.trans_t:.3g}  "
          f"CompL={c.comp_l:.3g}  TransL={c.trans_l:.3g}")


if __name__ == "__main__":
    main()
