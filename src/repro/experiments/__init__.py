"""Sweep orchestration: whole populations of FL trials as one workload.

``grid``   — TrialSpec/SweepSpec product grids with eager validation
             (axes: preference x aggregator x dataset x seed x (M0,E0)
             x tuner x runtime mode x fleet profile).
``runner`` — sequential and vectorized (trials-as-an-axis) execution:
             sync trials pack per virtual round, async/buffered trials
             pack off a merged multi-trial event queue; both bit-identical
             to standalone runs.
``store``  — append-only JSONL results, resume keys, paper-style tables
             (per-mode/per-profile columns, legacy-row tolerant).
``scheduler`` — continuous-batching trial serving: a ``LanePool`` page
             table over the stacked trial axis, a persistent
             ``TrialQueue`` (grid- or watched-JSONL-fed), and a
             ``TrialScheduler`` that retires lanes the moment a trial
             reaches target and admits queued trials mid-flight.
"""

from repro.experiments.grid import (CANONICAL_PREFERENCE,  # noqa: F401
                                    SweepSpec, TrialSpec, parse_preferences,
                                    spec_from_dict)
from repro.experiments.runner import (TrialResult, build_server,  # noqa: F401
                                      run_sweep, run_trial, run_vectorized,
                                      run_vectorized_events)
from repro.experiments.scheduler import (LanePool, ServeStats,  # noqa: F401
                                         TrialQueue, TrialScheduler, serve)
from repro.experiments.store import (ResultStore,  # noqa: F401
                                     aggregate_over_seeds, improvement_pct,
                                     pair_with_baselines, paper_table)
