"""Virtual-clock event queue for the heterogeneous FL runtime.

A tiny discrete-event core: events carry a virtual timestamp and are popped
in time order with a monotonically increasing sequence number breaking ties,
so two events at the same instant always replay in push order — the whole
simulation is a pure function of its seeds.  The clock never goes backwards;
popping an event advances it.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import List

# event kinds
ARRIVAL = "arrival"          # a client's update reaches the server
DROPOUT = "dropout"          # a client died mid-round; its work is lost


@dataclass(order=True)
class Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    client_id: int = field(compare=False, default=-1)


class VirtualClock:
    """Monotonic simulated time."""

    def __init__(self):
        self._now = 0.0

    @property
    def now(self) -> float:
        return self._now

    def advance_to(self, t: float):
        assert t >= self._now - 1e-12, f"clock went backwards: {t} < {self._now}"
        self._now = max(self._now, t)


class EventQueue:
    def __init__(self):
        self._heap: List[Event] = []
        self._seq = 0

    def push(self, time: float, kind: str, client_id: int = -1) -> Event:
        ev = Event(time=float(time), seq=self._seq, kind=kind,
                   client_id=client_id)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
