"""BAD fixture: tracer-unsafe host ops inside a jitted function.

``x`` and ``lr`` are tracers inside ``step``: Python branching, host
casts, ``.item()`` and ``np.*`` on them all fail (or silently bake in a
branch) under jit.  REPRO003 must fire on each marked line.
"""

import jax
import numpy as np


@jax.jit
def step(x, lr):
    if x > 0:                 # REPRO003: Python branch on a tracer
        x = x - lr
    y = float(x)              # REPRO003: host cast of a tracer
    z = np.asarray(x)         # REPRO003: numpy on a tracer
    return x.item() + y + z   # REPRO003: .item() on a tracer
