"""REPRO003 — tracer-unsafe operations inside jitted scopes.

Inside a traced function the arguments are tracers: Python ``if``/
``while`` on them raises ``TracerBoolConversionError`` at best and
silently bakes in one branch at worst; ``float()``/``int()``/``.item()``
force a host sync that kills async dispatch (and fails outright under
jit); ``np.*`` on a tracer materializes it.  The rule flags those
patterns when (and only when) they touch a *parameter* of the innermost
traced function — closure variables like ``prox_mu`` are Python-level
constants at trace time and stay exempt, as do shape/dtype attribute
reads and ``is None`` dispatch.
"""

from __future__ import annotations

import ast

from ..core import FileContext, Rule, register
from ..scopes import FuncNode, dotted_parts, final_name

STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}
HOST_CASTS = {"float", "int", "bool"}
# parameters that carry static Python config, not arrays: model/layer
# configs, meshes and optimizers are hashable trace-time constants (jit
# marks them static or closes over them), so branching on them is fine
STATIC_PARAMS = {"cfg", "config", "spec", "specs", "mesh", "model",
                 "optimizer", "hp", "opts", "rules", "dtype", "cls"}


def _param_names(func) -> set:
    a = func.args
    names = {p.arg for p in a.args + a.posonlyargs + a.kwonlyargs}
    if a.vararg:
        names.add(a.vararg.arg)
    if a.kwarg:
        names.add(a.kwarg.arg)
    names.discard("self")
    return names - STATIC_PARAMS


def _mentions_param(expr: ast.AST, params: set):
    """Name of a mentioned traced parameter, skipping static attribute
    chains like ``x.shape[0]`` and ``isinstance``/``is None`` guards."""
    # bare truthiness of a subscript (`if params_st["stacked"]:`) tests
    # pytree *structure* — which container slots exist — not leaf values
    if isinstance(expr, ast.Subscript):
        return None
    for node in ast.walk(expr):
        if isinstance(node, ast.Compare) and any(
                isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return None  # `x is None` dispatch is host-side and fine
        if isinstance(node, ast.Call) \
                and final_name(node.func) in {"isinstance", "len"}:
            return None
    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
            continue
        if isinstance(node, ast.Name) and node.id in params:
            parent_attr = None
            # x.shape is static even though `x` is a tracer: look one up
            # via a cheap re-walk of the expression for `<name>.<static>`
            for sub in ast.walk(expr):
                if isinstance(sub, ast.Attribute) \
                        and sub.attr in STATIC_ATTRS \
                        and isinstance(sub.value, ast.Name) \
                        and sub.value.id == node.id:
                    parent_attr = sub
            if parent_attr is None:
                return node.id
    return None


@register
class TracerUnsafe(Rule):
    id = "REPRO003"
    name = "tracer-unsafe-op-in-jit"

    def check_file(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            fn = ctx.enclosing_function(node)
            if fn is None or not ctx.scopes.is_traced(fn):
                continue
            params = _param_names(fn)
            if isinstance(node, (ast.If, ast.While)):
                hit = _mentions_param(node.test, params)
                if hit:
                    kind = "if" if isinstance(node, ast.If) else "while"
                    ctx.add(node, self.id,
                            f"Python `{kind}` on traced value '{hit}' "
                            "inside a jitted scope — use jnp.where/"
                            "lax.cond or hoist the branch out of jit")
            elif isinstance(node, ast.IfExp):
                hit = _mentions_param(node.test, params)
                if hit:
                    ctx.add(node, self.id,
                            f"Python conditional expression on traced "
                            f"value '{hit}' inside a jitted scope — use "
                            "jnp.where or lax.cond")
            elif isinstance(node, ast.Call):
                self._check_call(ctx, node, params)

    def _check_call(self, ctx: FileContext, node: ast.Call, params: set):
        name = final_name(node.func)
        if name in HOST_CASTS and node.args:
            hit = _mentions_param(node.args[0], params)
            if hit:
                ctx.add(node, self.id,
                        f"host cast `{name}()` of traced value '{hit}' "
                        "inside a jitted scope — forces a sync and fails "
                        "under jit")
            return
        if name == "item" and isinstance(node.func, ast.Attribute):
            hit = _mentions_param(node.func.value, params)
            if hit:
                ctx.add(node, self.id,
                        f"`.item()` on traced value '{hit}' inside a "
                        "jitted scope — forces a sync and fails under jit")
            return
        parts = dotted_parts(node.func)
        if parts and parts[0] in {"np", "numpy"} and parts[1:2] != ["random"]:
            for arg in node.args:
                hit = _mentions_param(arg, params)
                if hit:
                    ctx.add(node, self.id,
                            f"numpy call `{'.'.join(parts)}` on traced "
                            f"value '{hit}' inside a jitted scope — "
                            "materializes the tracer; use jnp")
                    return
