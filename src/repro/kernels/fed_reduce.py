"""Pallas TPU kernel: fused segment aggregation over a packed cohort.

    out[t] = base[t] + sum_{m : seg[m] == t} w_m * roundtrip(row_m)

This is the server-side hot path of the multi-trial sweep engines: every
lane (trial slot) of the packed flat cohort reduces to its own (N,)
parameter vector in ONE dispatch, where the pre-fusion code issued a
jitted call per lane (per-trial ``fed_aggregate``) plus separate jitted
weight-normalization and int8-dequant round trips.

Layout mirrors ``fed_aggregate``: the parameter axis is cut into
lane-aligned VMEM column blocks; each grid step loads the (M, BLOCK_N)
row tile, the (M, 1) weight/segment columns and the (T, BLOCK_N) base
tile, and folds the M rows into a (T, BLOCK_N) accumulator in VREGs.
Arithmetic intensity is ~1 FLOP / 2 bytes — HBM-bandwidth-bound, so the
kernel's one job is to stream the rows exactly once (see
``roofline/kernels.py`` for the analytic byte model the benchmark checks
against).

Bit-exactness: the in-kernel fold adds rows one at a time in pack order
(``jnp.where`` lane select over a precomputed ``w * x``), the exact op
sequence of ``ref.fed_reduce_ref``'s scan — so Pallas output matches the
reference bitwise, and lane t of a fused call matches a standalone T=1
call.  The quantization round trip and weight normalization are shared
jnp pre-passes from ``kernels/ref.py`` inside the same jit: per-leaf
quant scales are a full-row reduction, which cannot be formed inside a
column-blocked grid step, so they are computed once up front and the
whole program still lowers to a single XLA dispatch around the
pallas_call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref

BLOCK_N = 2048  # lane-aligned (16 x 128) f32 tile per cohort row


def _kernel(seg_ref, w_ref, base_ref, x_ref, o_ref):
    # seg: (M, 1) i32, w: (M, 1) f32 (normalized), base: (T, BLOCK_N),
    # x: (M, BLOCK_N), o: (T, BLOCK_N)
    x = x_ref[...].astype(jnp.float32)
    wx = w_ref[...].astype(jnp.float32) * x          # before the fold: no
    seg = seg_ref[...]                               # mul+add to contract
    t, block = o_ref.shape
    lanes = jax.lax.broadcasted_iota(jnp.int32, (t, 1), 0)

    def fold(m, acc):
        row = jax.lax.dynamic_slice_in_dim(wx, m, 1, 0)      # (1, BLOCK_N)
        s = jax.lax.dynamic_slice_in_dim(seg, m, 1, 0)[0, 0]
        return jnp.where(lanes == s, acc + row, acc)

    acc = jax.lax.fori_loop(0, x.shape[0], fold,
                            jnp.zeros((t, block), jnp.float32))
    o_ref[...] = (acc + base_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "num_segments", "normalize", "leaf_sizes", "block_n", "interpret"))
def fed_reduce(weights, rows, segments, num_segments, base=None, *,
               normalize: bool = False, leaf_sizes=None, quant_ref=None,
               quant_enabled=None, block_n: int = BLOCK_N,
               interpret: bool = False):
    """weights: (M,); rows: (M, N); segments: (M,) -> (num_segments, N).
    Same contract as ``ref.fed_reduce_ref`` (its bit-matching oracle)."""
    m, n = rows.shape
    t = num_segments
    seg = segments.astype(jnp.int32)
    x = rows.astype(jnp.float32)
    if quant_ref is not None:
        x = _ref._quant_rows(x, seg, quant_ref, quant_enabled, leaf_sizes)
    w = _ref._norm_weights(weights, seg, t, normalize)
    if base is None:
        base = jnp.zeros((t, n), rows.dtype)
    pad = (-n) % block_n
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        base = jnp.pad(base, ((0, 0), (0, pad)))
    n_pad = n + pad

    out = pl.pallas_call(
        _kernel,
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
            pl.BlockSpec((t, block_n), lambda i: (0, i)),
            pl.BlockSpec((m, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((t, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((t, n_pad), rows.dtype),
        interpret=interpret,
    )(seg.reshape(m, 1), w.reshape(m, 1), base, x)
    return out[:, :n]
