"""repro.analysis: rule fixtures, noqa semantics, determinism, and the
self-check ratchet over the real tree.

The fixture table pins each rule's hits AND misses (the good fixtures
encode the exemptions — closure constants, sorted() wrappers, cached jit
factories — that keep the analyzer quiet on the real tree).  The
self-check test makes tier-1 itself the ratchet: any new unsuppressed
finding in src/repro fails the suite, not just CI's lint job.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import analyze_paths, load_baseline, new_findings
from repro.analysis.baseline import DEFAULT_BASELINE
from repro.analysis.core import parse_noqa

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "fixtures" / "analysis"
SRC_REPRO = REPO / "src" / "repro"
TOOLS_BASELINE = REPO / "tools" / "analysis_baseline.json"


def rule_hits(path: Path, rule: str):
    res = analyze_paths([path])
    assert not res.errors, res.errors
    return [f for f in res.findings if f.rule == rule]


# ---------------------------------------------------------------------------
# table-driven fixture corpus: (fixture, rule, expected finding count)
# ---------------------------------------------------------------------------

CASES = [
    ("runtime/repro001_bad.py", "REPRO001", 2),
    ("runtime/repro001_good.py", "REPRO001", 0),
    ("repro002_bad.py", "REPRO002", 2),
    ("repro002_good.py", "REPRO002", 0),
    ("repro003_bad.py", "REPRO003", 4),
    ("repro003_good.py", "REPRO003", 0),
    ("runtime/repro004_bad.py", "REPRO004", 4),
    ("runtime/repro004_good.py", "REPRO004", 0),
    ("obs/repro004_allowlisted.py", "REPRO004", 0),
    ("runtime/repro005_bad.py", "REPRO005", 3),
    ("runtime/repro005_good.py", "REPRO005", 0),
    ("repro006_bad.py", "REPRO006", 3),
    ("repro006_good.py", "REPRO006", 0),
    ("repro007_bad.py", "REPRO007", 2),
    ("repro007_good.py", "REPRO007", 0),
]


@pytest.mark.parametrize("fixture,rule,expected", CASES,
                         ids=[c[0] for c in CASES])
def test_rule_fixture(fixture, rule, expected):
    hits = rule_hits(FIXTURES / fixture, rule)
    assert len(hits) == expected, \
        f"{fixture}: expected {expected} {rule} finding(s), got " \
        f"{[(f.line, f.message) for f in hits]}"


def test_bad_fixtures_flag_only_their_own_rule():
    """Each bad fixture trips its rule and nothing else — cross-rule
    noise in the corpus would mean a rule is overreaching."""
    for fixture, rule, expected in CASES:
        if not expected:
            continue
        res = analyze_paths([FIXTURES / fixture])
        other = [f for f in res.findings if f.rule != rule]
        assert not other, f"{fixture}: unexpected {other}"


def test_fma_incident_pattern_in_a_scratch_file(tmp_path):
    """Acceptance pin: re-introducing the PR 5 eager-FMA pattern in a
    fresh scratch file under a runtime/ path is flagged as REPRO001."""
    scratch = tmp_path / "runtime" / "scratch.py"
    scratch.parent.mkdir()
    scratch.write_text(
        "import jax.numpy as jnp\n"
        "SCALE = 127.0\n"
        "def roundtrip_leaf(delta):\n"
        "    q = jnp.round(delta * SCALE)\n"
        "    return q / SCALE\n",
        encoding="utf-8")
    res = analyze_paths([tmp_path])
    assert any(f.rule == "REPRO001" for f in res.findings), res.findings


# ---------------------------------------------------------------------------
# noqa semantics
# ---------------------------------------------------------------------------

def test_justified_noqa_suppresses():
    res = analyze_paths([FIXTURES / "noqa_justified.py"])
    assert not res.findings
    assert len(res.suppressed) == 1
    sup = res.suppressed[0]
    assert sup.finding.rule == "REPRO007"
    assert "feature absent" in sup.justification


def test_unjustified_noqa_does_not_suppress():
    res = analyze_paths([FIXTURES / "noqa_unjustified.py"])
    assert not res.suppressed
    assert len(res.findings) == 1
    assert res.findings[0].rule == "REPRO007"
    assert "not suppressed" in res.findings[0].message


def test_noqa_inside_string_literal_is_ignored():
    src = 'MSG = "# noqa: REPRO007 -- not a comment"\n'
    assert parse_noqa(src) == {}


def test_noqa_requires_matching_rule_code():
    src = "x = 1  # noqa: REPRO001 -- only suppresses REPRO001\n"
    assert parse_noqa(src) == {1: {"REPRO001": "only suppresses REPRO001"}}


# ---------------------------------------------------------------------------
# determinism: two CLI runs over src/ are byte-identical
# ---------------------------------------------------------------------------

def _run_cli(*args):
    env = dict(os.environ,
               PYTHONPATH=str(REPO / "src"),
               PYTHONHASHSEED="random")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=REPO, env=env, capture_output=True, text=True)


def test_json_output_is_byte_identical_across_runs():
    # two separate processes with random hash seeds: any reliance on
    # set/dict hash order in the analyzer would show up as a diff
    runs = [_run_cli("src/repro", "--format", "json",
                     "--baseline", str(TOOLS_BASELINE)) for _ in range(2)]
    for r in runs:
        assert r.returncode == 0, r.stdout + r.stderr
    assert runs[0].stdout == runs[1].stdout
    doc = json.loads(runs[0].stdout)
    assert doc["findings"] == [] and doc["new_findings"] == []
    assert doc["errors"] == []


def test_cli_exit_codes(tmp_path):
    # new findings -> 1
    bad = _run_cli(str(FIXTURES / "repro007_bad.py"))
    assert bad.returncode == 1
    # clean tree -> 0 (also: the packaged default baseline is used)
    good = _run_cli(str(FIXTURES / "repro007_good.py"))
    assert good.returncode == 0
    # missing path -> 2
    assert _run_cli(str(tmp_path / "nope")).returncode == 2
    # unparsable source -> 2
    broken = tmp_path / "broken.py"
    broken.write_text("def (:\n", encoding="utf-8")
    assert _run_cli(str(broken)).returncode == 2


# ---------------------------------------------------------------------------
# self-check: the real tree is clean against the checked-in baseline
# ---------------------------------------------------------------------------

def test_src_repro_has_zero_unsuppressed_findings():
    res = analyze_paths([SRC_REPRO])
    assert not res.errors, res.errors
    baseline = load_baseline(TOOLS_BASELINE)
    fresh = new_findings(res, baseline)
    assert not fresh, "\n".join(
        f"{f.path}:{f.line}: {f.rule} {f.message}" for f in fresh)
    # the tree carries real suppressions and each one is justified by
    # construction (unjustified noqa would surface as a finding above)
    assert res.suppressed, "expected justified suppressions in src/repro"


def test_checked_in_baselines_are_identical_and_empty():
    tools_doc = json.loads(TOOLS_BASELINE.read_text(encoding="utf-8"))
    packaged_doc = json.loads(DEFAULT_BASELINE.read_text(encoding="utf-8"))
    assert tools_doc == packaged_doc
    assert tools_doc["findings"] == [], \
        "the baseline only ratchets down — fix or justify-suppress instead"
