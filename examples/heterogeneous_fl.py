"""FedTune under stragglers: tuning (M, E) in all three runtime modes.

The paper tunes (M, E) against the four system overheads assuming
homogeneous, fully synchronous clients.  This demo runs the same FedTune
controller on a *straggler* fleet (15% of devices are 10x slower, 5%
drop out mid-round) in each execution mode of the event-driven runtime:

  sync      — classic deadline rounds; stragglers above the 0.7 completion
              quantile are cut.
  async     — FedAsync: staleness-discounted immediate application.
  buffered  — FedBuff: K staleness-weighted deltas per aggregation through
              the fed_aggregate kernel.

For each mode it reports the accuracy reached, the virtual wall-clock, the
four overheads, and where FedTune drove (M, E) — on heterogeneous fleets
the CompT-sensitive preferences push M/E differently than the homogeneous
cost model would, which is exactly the regime the runtime exists to study.

Usage: PYTHONPATH=src python examples/heterogeneous_fl.py [--rounds N]
"""

from __future__ import annotations

import argparse

import jax

from repro.configs.paper_models import MLPConfig
from repro.core import CostModel, FedTune, FedTuneConfig, Preference
from repro.core.tuner import HyperParams
from repro.data import emnist_like
from repro.federated import FLConfig, FLServer, get_aggregator
from repro.models import build_model
from repro.optim.optimizers import get_optimizer
from repro.runtime import RuntimeConfig, sample_fleet


def run_mode(name: str, rt: RuntimeConfig, *, rounds: int, m0: int,
             e0: float, pref: Preference, het: str = "stragglers"):
    dataset = emnist_like(reduced=True)
    model = build_model(MLPConfig(name="mlp", in_dim=28 * 28, hidden=(48,),
                                  n_classes=dataset.spec.n_classes))
    n_params = sum(p.size for p in jax.tree.leaves(
        model.init(jax.random.PRNGKey(0))))
    fleet = sample_fleet(het, dataset.n_clients, seed=0)
    tuner = FedTune(FedTuneConfig(preference=pref), HyperParams(m0, e0))
    server = FLServer(
        model, dataset, get_aggregator("fedavg"),
        get_optimizer("sgd", 0.03, momentum=0.9),
        CostModel(flops_per_example=2 * n_params, param_count=n_params),
        FLConfig(m=m0, e=e0, batch_size=10, target_accuracy=0.6,
                 max_rounds=rounds, eval_points=512),
        tuner=tuner, fleet=fleet, runtime_config=rt)
    res = server.run()
    c = res.total_cost
    arrived = [h.n_updates for h in res.history[:5]]
    print(f"{name:10s} acc={res.final_accuracy:.3f} aggs={res.rounds:3d} "
          f"t_sim={res.sim_time:9.3g}  M:{m0}->{res.final_m} "
          f"E:{e0:g}->{res.final_e:g}")
    print(f"{'':10s} CompT={c.comp_t:.3g} TransT={c.trans_t:.3g} "
          f"CompL={c.comp_l:.3g} TransL={c.trans_l:.3g} "
          f"first-rounds arrivals={arrived}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--e", type=float, default=1.0)
    ap.add_argument("--het", default="stragglers")
    ap.add_argument("--preference", default="0.5,0.0,0.5,0.0",
                    help="alpha,beta,gamma,delta (CompT+CompL default: "
                         "straggler-sensitive)")
    ap.add_argument("--client-exec", default="sequential",
                    choices=("sequential", "batched", "sharded"),
                    help="sync-mode client execution backend (sharded "
                         "needs a multi-device mesh)")
    args = ap.parse_args()
    pref = Preference(*(float(x) for x in args.preference.split(",")))

    print(f"FedTune over a '{args.het}' fleet, preference "
          f"{tuple(pref.as_tuple())}\n")
    kw = dict(rounds=args.rounds, m0=args.m, e0=args.e, pref=pref,
              het=args.het)
    run_mode("sync", RuntimeConfig(mode="sync", deadline_quantile=0.7,
                                   client_exec=args.client_exec), **kw)
    run_mode("async", RuntimeConfig(mode="async"), **kw)
    run_mode("buffered", RuntimeConfig(mode="buffered",
                                       buffer_k=max(args.m // 2, 1)), **kw)


if __name__ == "__main__":
    main()
