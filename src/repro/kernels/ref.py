"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def fed_aggregate_ref(weights, deltas, base=None):
    """weights: (M,), deltas: (M, N) -> (N,). Optionally adds ``base``."""
    out = jnp.einsum("m,mn->n", weights.astype(jnp.float32),
                     deltas.astype(jnp.float32))
    if base is not None:
        out = out + base.astype(jnp.float32)
    return out.astype(deltas.dtype)


# ---------------------------------------------------------------------------
# fed_reduce: fused segment aggregation over a packed multi-trial cohort
# ---------------------------------------------------------------------------
#
# The bit-exactness contract of fed_reduce is *packing invariance*: lane t
# of a T-segment call must equal the same rows reduced through a T=1 call,
# bit for bit, because the sweep engines aggregate T trials in one dispatch
# while the standalone `FLServer.run()` they are parity-pinned against
# reduces one trial at a time.  Three design rules make that hold by
# construction rather than by compiler luck:
#
#   * per-segment results are a strict left-to-right fold over that
#     segment's rows in pack order (a lax.scan of one-row scatter-adds, not
#     an einsum/segment_sum whose tree reduction re-associates when other
#     segments' rows are interleaved);
#   * the weight multiply is materialized BEFORE the fold (``wx = w * x``),
#     so the fold body is a pure f32 add of precomputed values and XLA has
#     no mul+add pair to contract into an FMA differently per shape;
#   * the quantization round trip and weight normalization are row/segment
#     elementwise (and max is order-insensitive and exact), so they cannot
#     see what else is packed.

def _quant_rows(rows, segments, quant_ref, quant_enabled, leaf_sizes):
    """The int8 upload round trip on flat (M, N) rows, bit-identical to
    ``compression._roundtrip_leaf`` applied per (row, leaf).

    quant_ref: (T, N) per-segment reference vectors (each trial's global
    params, flattened); row m is quantized against ``quant_ref[seg[m]]``.
    leaf_sizes: static tuple of per-leaf column widths (the scale is
    per-leaf, exactly like the tree round trip).  quant_enabled: optional
    (M,) bool — disabled rows pass through untouched."""
    x = rows.astype(jnp.float32)
    g = quant_ref.astype(jnp.float32)[segments]          # (M, N) gather
    d = x - g
    scales = []
    off = 0
    for size in leaf_sizes:
        leaf_max = jnp.max(jnp.abs(d[:, off:off + size]), axis=1)
        scales.append(jnp.maximum(leaf_max / 127.0, 1e-12))
        off += size
    col_scale = jnp.concatenate(
        [jnp.broadcast_to(s[:, None], (rows.shape[0], size))
         for s, size in zip(scales, leaf_sizes)], axis=1)   # (M, N)
    q = jnp.clip(jnp.round(d / col_scale), -127, 127).astype(jnp.int8)
    rec = g + q.astype(jnp.float32) * col_scale
    if quant_enabled is None:
        return rec
    return jnp.where(quant_enabled[:, None], rec, x)


def _seg_fold(values, segments, num_segments):
    """Left-to-right fold of rows into per-segment f32 accumulators.
    values: (M,) or (M, N); returns (num_segments,) or (num_segments, N).
    Each accumulator element only ever sees its own segment's rows, in
    their pack order — which is what makes the result invariant to what
    ELSE is packed alongside them."""
    acc0 = jnp.zeros((num_segments,) + values.shape[1:], jnp.float32)

    def step(acc, xs):
        row, s = xs
        return acc.at[s].add(row), None

    acc, _ = jax.lax.scan(step, acc0, (values.astype(jnp.float32),
                                       segments))
    return acc


def _norm_weights(weights, segments, num_segments, normalize):
    """f32 weights, divided by their per-segment totals when asked.  The
    totals are the same sequential fold, so a lane's normalizer equals the
    standalone run's regardless of packing.  Empty (padding) segments
    divide by 1 instead of 0 — their rows carry weight 0 anyway."""
    w = weights.astype(jnp.float32)
    if not normalize:  # noqa: REPRO003 -- static_argnames kwarg of every jit of this path: a Python bool at trace time
        return w
    tot = _seg_fold(w, segments, num_segments)
    tot = jnp.where(tot > 0, tot, 1.0)
    return w / tot[segments]


def fed_reduce_ref(weights, rows, segments, num_segments, base=None, *,
                   normalize=False, leaf_sizes=None, quant_ref=None,
                   quant_enabled=None):
    """Fused segment aggregation over a packed multi-trial flat cohort.

    weights: (M,), rows: (M, N), segments: (M,) int32 trial slots ->
    (num_segments, N).  Optionally fuses weight normalization (divide by
    per-segment weight totals), the int8 upload round trip against
    ``quant_ref`` (see ``_quant_rows``), and a per-segment ``base`` add
    ((num_segments, N)).  ``num_segments`` and ``leaf_sizes`` are static.
    """
    seg = segments.astype(jnp.int32)
    x = rows.astype(jnp.float32)
    if quant_ref is not None:
        x = _quant_rows(x, seg, quant_ref, quant_enabled, leaf_sizes)
    w = _norm_weights(weights, seg, num_segments, normalize)
    wx = w[:, None] * x
    out = _seg_fold(wx, seg, num_segments)
    if base is not None:
        out = out + base.astype(jnp.float32)
    return out.astype(rows.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window: Optional[int] = None,
                        cap: Optional[float] = None):
    """q: (B, H, S, D); k, v: (B, Kh, T, D) with H % Kh == 0 -> (B, H, S, D)."""
    b, h, s, d = q.shape
    kh, t = k.shape[1], k.shape[2]
    g = h // kh
    qr = q.reshape(b, kh, g, s, d).astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qr, k.astype(jnp.float32))
    scores = scores * (d ** -0.5)
    if cap is not None:
        scores = cap * jnp.tanh(scores / cap)
    q_pos = jnp.arange(s)
    k_pos = jnp.arange(t)
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None] + (t - s)
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] + (t - s) - window
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(q.dtype)


def rglru_scan_ref(a, b, h0=None):
    """h_t = a_t * h_{t-1} + b_t over axis 1. a, b: (B, T, W)."""
    bsz, t, w = a.shape
    h = jnp.zeros((bsz, w), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    _, hs = jax.lax.scan(step, h, (a.astype(jnp.float32).transpose(1, 0, 2),
                                   b.astype(jnp.float32).transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2).astype(a.dtype)
