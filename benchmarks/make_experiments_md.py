"""Generate the §Dry-run and §Roofline sections of EXPERIMENTS.md from the
dry-run artifacts + the analytic model.

    PYTHONPATH=src:. python benchmarks/make_experiments_md.py
"""

from __future__ import annotations

import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from repro.configs import ARCH_NAMES, get_config
from repro.configs.shapes import SHAPES, get_shape
from repro.roofline.analytic import analyze

ROOT = pathlib.Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "experiments" / "dryrun"


def load():
    recs = {}
    for f in sorted(DRYRUN.glob("*.json")):
        r = json.loads(f.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def dryrun_section(recs) -> str:
    out = ["## §Dry-run",
           "",
           "Every (architecture x input shape) lowered AND compiled on the "
           "single-pod `(16,16)` mesh and the multi-pod `(2,16,16)` mesh "
           "(512 host placeholder devices).  `peak` = per-device "
           "`memory_analysis()` peak (args + temp + out − aliased); "
           "`coll ops` = collective kinds found in the compiled HLO.",
           "",
           "| arch | shape | mesh | status | peak/dev | HLO collectives | compile |",
           "|---|---|---|---|---|---|---|"]
    n_ok = n_fail = 0
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                r = recs.get((arch, shape, mesh))
                if r is None:
                    continue
                if r["status"] != "ok":
                    n_fail += 1
                    out.append(f"| {arch} | {shape} | {mesh} | FAIL | - | "
                               f"{r.get('error', '')[:60]} | - |")
                    continue
                n_ok += 1
                counts = (r.get("coll_breakdown") or {}).get("counts", {})
                kinds = ",".join(f"{k.split('-')[0]}-{k.split('-')[1][:3]}"
                                 f"x{v}" for k, v in counts.items() if v)
                peak = r.get("peak_memory_bytes", 0) / 2**30
                out.append(
                    f"| {arch} | {shape} | {mesh} | OK | {peak:.1f} GiB | "
                    f"{kinds or '-'} | {r.get('t_compile_s', 0):.0f}s |")
    out.insert(3, f"**{n_ok} OK / {n_fail} FAIL** across "
                  f"{len(ARCH_NAMES)}x{len(SHAPES)}x2 combinations.")
    return "\n".join(out)


def roofline_section(recs) -> str:
    out = ["## §Roofline",
           "",
           "Per (arch x shape) on the single-pod mesh (256 chips, v5e: "
           "197 TF/s bf16, 819 GB/s HBM, 2x50 GB/s ICI).  Terms are "
           "per-device seconds from the ANALYTIC model (XLA *CPU* "
           "`cost_analysis` counts while-loop bodies once — see the "
           "validation row; HLO-parsed collective bytes are reported "
           "alongside as the structural cross-check).  `useful` = "
           "MODEL_FLOPS(6·N_active·D) / lowered FLOPs — it exposes the "
           "deliberate overcompute (remat ~25%, dense-MoE E/k, unskipped "
           "masked attention chunks).",
           "",
           "| arch | shape | compute | memory | collective | bottleneck | "
           "useful | HLO coll bytes/dev | peak/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape_name in SHAPES:
            shape = get_shape(shape_name)
            a = analyze(cfg, shape)
            t = a.terms()
            r = recs.get((arch, shape_name, "16x16"), {})
            coll_hlo = r.get("coll_bytes", 0.0)
            peak = r.get("peak_memory_bytes", 0) / 2**30
            useful = a.flops_ideal / max(a.flops, 1e-9)
            note = ""
            if (not cfg.subquadratic) and shape.seq_len > 65536 \
                    and shape.kind == "decode":
                note = " [SW]"
            out.append(
                f"| {arch}{note} | {shape_name} | {t['compute']*1e3:.2f} ms | "
                f"{t['memory']*1e3:.2f} ms | {t['collective']*1e3:.2f} ms | "
                f"**{a.bottleneck()}** | {useful:.0%} | "
                f"{coll_hlo/2**20:.0f} MiB | {peak:.1f} GiB |")
    # one-line "what would move the bottleneck" notes
    out += ["",
            "Per-family bottleneck notes (what would move the dominant term):",
            "- **MoE train/prefill (dbrx, granite)**: compute-bound with low "
            "useful fraction — the masked dense-expert lowering costs E/k x; "
            "a shard_map all-to-all dispatch recovers it (§Perf H1).",
            "- **dense train (qwen2, command-r, minitron, gemma2)**: compute "
            "~ collective; the FSDP all-gathers + f32 grad reduce-scatter "
            "dominate collectives — quantized aggregation shrinks them "
            "(§Perf H3, the paper's TransL knob at the gradient level).",
            "- **decode (all)**: collective/memory-bound on weight gathers; "
            "int8 serving weights halve both terms (§Perf H2).",
            "- **recurrent/ssm (recurrentgemma, xlstm)**: already "
            "sub-quadratic; long_500k decode runs in O(state), 0.2-10 GiB/dev.",
            ]
    return "\n".join(out)


def main():
    recs = load()
    print(dryrun_section(recs))
    print()
    print(roofline_section(recs))


if __name__ == "__main__":
    main()
