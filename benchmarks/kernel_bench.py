"""Kernel micro-benchmarks: wall time of the jnp reference paths (the CPU
executable analogues; the Pallas kernels themselves target TPU and are
validated in interpret mode by tests).

The fed_reduce section is the PR-10 contract check: the fused
normalize+quantize+segment-sum+base dispatch vs the pre-fusion
separate-call sequence (per-trial int8 round trip, per-trial T=1 reduce)
over the same packed cohort, verified bit-identical, timed, and compared
against the ``roofline.kernels`` analytic byte model — at a measured host
stream bandwidth and analytically for TPU_V5E.  It also quotes the cost
model's CompT/TransT for an M=1,000,000 cohort drawn from a K=10,000,000
``VirtualFleet`` (no (K,) array ever exists — the point of client-state
virtualization).  Emits one ``BENCH {json}`` line (sweep_engine.py's
convention) that CI asserts on and uploads.

Run standalone:  PYTHONPATH=src:. python benchmarks/kernel_bench.py
                 [--json kernel_bench.json]
"""

from __future__ import annotations

import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchSettings, emit
from repro.kernels import ops as kernel_ops
from repro.kernels import ref
from repro.roofline.hardware import TPU_V5E
from repro.roofline.kernels import fed_reduce_traffic

KEY = jax.random.PRNGKey(0)


def _time(fn, *args, n=5):
    jax.block_until_ready(fn(*args))          # one warmup, all leaves
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


@functools.partial(jax.jit, static_argnames=("leaf_sizes",))
def _roundtrip_rows(rows, gref, leaf_sizes):
    """The pre-fusion standalone quantize round trip over one trial's
    rows (what ``compress_delta_lanes`` dispatched per lane group)."""
    seg = jnp.zeros(rows.shape[0], jnp.int32)
    return ref._quant_rows(rows, seg, gref[None, :], None, leaf_sizes)


def _measure_stream_gbs() -> float:
    """Effective host stream bandwidth: read+write of a 64MB f32 array."""
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        16 * 1024 * 1024).astype(np.float32))
    add = jax.jit(lambda v: v + 1.0)
    us = _time(add, x, n=10)
    return (2 * x.nbytes) / (us * 1e-6) / 1e9


def bench_fed_reduce(t: int = 8, per: int = 16, n: int = 4096,
                     json_path=None) -> dict:
    """Fused vs separate-call sequence at T lanes x per rows/lane.

    The default N matches the production regime (flattened model params
    are a few thousand floats), where the 2T-dispatch separate sequence
    pays per-call overhead the single fused dispatch amortizes.  At very
    large N the comparison inverts on CPU hosts — the separate path's
    per-trial slices fit in cache while the fused working set streams
    from RAM — which is a host-cache artifact, not the TPU roofline
    story (``roofline.kernels``)."""
    m = t * per
    rng = np.random.default_rng(0)
    rows = jnp.asarray(rng.standard_normal((m, n)).astype(np.float32))
    base = jnp.asarray(rng.standard_normal((t, n)).astype(np.float32))
    w = jnp.asarray(rng.uniform(1.0, 100.0, m).astype(np.float32))
    seg = jnp.asarray(np.repeat(np.arange(t), per).astype(np.int32))
    leaf_sizes = (n // 2, n - n // 2)
    seg1 = jnp.zeros(per, jnp.int32)

    def fused():
        return kernel_ops.fed_reduce(
            w, rows, seg, t, base, normalize=True, leaf_sizes=leaf_sizes,
            quant_ref=base, quant_enabled=jnp.ones(m, bool))

    def separate():
        # the pre-fusion sequence: per-trial round trip + per-trial reduce
        outs = []
        for i in range(t):
            sl = slice(i * per, (i + 1) * per)
            rt = _roundtrip_rows(rows[sl], base[i], leaf_sizes)
            outs.append(kernel_ops.fed_reduce(
                w[sl], rt, seg1, 1, base[i][None], normalize=True)[0])
        return jnp.stack(outs)

    bitmatch = bool(
        (np.asarray(fused()) == np.asarray(separate())).all())
    fused_us = _time(fused)
    separate_us = _time(separate)
    emit(f"kernel/fed_reduce_fused_{t}x{per}x{n}", fused_us,
         f"bitmatch={bitmatch}")
    emit(f"kernel/fed_reduce_separate_{t}x{per}x{n}", separate_us,
         f"speedup={separate_us / fused_us:.2f}")

    traffic = fed_reduce_traffic(m, n, t, quant=True, base=True)
    stream_gbs = _measure_stream_gbs()
    fused_s = fused_us * 1e-6
    bound_s = traffic.bound_s_at(stream_gbs * 1e9)

    payload = {
        "bench": "fed_reduce",
        "t": t, "m": m, "n": n,
        "fused_us": fused_us,
        "separate_us": separate_us,
        "speedup": separate_us / fused_us,
        "bitmatch": bitmatch,
        "bytes": traffic.bytes_hbm,
        "stream_gbs": stream_gbs,
        "achieved_gbs": traffic.bytes_hbm / fused_s / 1e9,
        "bound_fraction": bound_s / fused_s,
        "tpu_v5e_bound_us": traffic.bound_s(TPU_V5E) * 1e6,
        "virtual_fleet_m1e6": _quote_million_clients(),
    }
    print("BENCH " + json.dumps(payload), flush=True)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f)
    return payload


def _quote_million_clients(m: int = 1_000_000,
                           k: int = 10_000_000) -> dict:
    """CompT/TransT quote for an M=1e6 cohort out of a K=1e7 VirtualFleet:
    memory stays cohort-sized (the fleet never materializes (K,) arrays),
    and the times follow ``account_sync_round`` semantics — the round's
    critical path is the slowest included client's compute / transfer."""
    from repro.core import CostModel
    from repro.runtime.profiles import virtual_fleet

    n_params = 25_000
    cm = CostModel(flops_per_example=50_000, param_count=n_params)
    c1 = cm.train_flops_per_example
    down, up = cm.traffic_halves()
    e = 2.0
    fleet = virtual_fleet("mobile", k, seed=0)
    rng = np.random.default_rng(0)
    cids = rng.integers(0, k, m)
    sizes = rng.integers(10, 100, m).astype(np.float64)

    t0 = time.perf_counter()
    flops = (c1 * e) * sizes
    comp = flops / (fleet.ref_flops_per_s * fleet.speeds(cids))
    bw = fleet.bws(cids)
    trans = (down / (fleet.ref_bytes_per_s * bw)
             + up / (fleet.ref_bytes_per_s * bw))
    round_cost = cm.add_timed_round(
        comp_time=float(comp.max()), trans_time=float(trans.max()),
        comp_load=c1 * e * float(sizes.sum()),
        trans_load=float(n_params) * m)
    quote_s = time.perf_counter() - t0
    emit("kernel/virtual_fleet_quote_m1e6", quote_s * 1e6,
         f"k={k}")
    return {
        "m": m, "k": k,
        "comp_t": round_cost.comp_t, "trans_t": round_cost.trans_t,
        "comp_l": round_cost.comp_l, "trans_l": round_cost.trans_l,
        "quote_s": quote_s,
    }


def main(settings: BenchSettings, json_path=None):
    # fed_aggregate: the legacy single-lane server reduction
    m, n = 20, 1_000_000
    w = jnp.full((m,), 1.0 / m)
    d = jax.random.normal(KEY, (m, n))
    agg = jax.jit(ref.fed_aggregate_ref)
    emit("kernel/fed_aggregate_ref_20x1M", _time(agg, w, d),
         f"bytes={d.nbytes}")

    # fed_reduce: fused segment aggregation vs the separate-call sequence
    bench_fed_reduce(json_path=json_path)

    # flash attention reference at a prefill-ish shape
    q = jax.random.normal(KEY, (1, 8, 1024, 64))
    k = jax.random.normal(KEY, (1, 2, 1024, 64))
    v = jax.random.normal(KEY, (1, 2, 1024, 64))
    fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    emit("kernel/flash_attention_ref_1k", _time(fa, q, k, v),
         "flops=%.3g" % (4 * 1024 * 1024 * 8 * 64))

    # rglru scan
    a = jax.random.uniform(KEY, (4, 2048, 512), minval=0.9, maxval=0.999)
    b = jax.random.normal(KEY, (4, 2048, 512))
    rg = jax.jit(ref.rglru_scan_ref)
    emit("kernel/rglru_scan_ref_4x2048x512", _time(rg, a, b),
         f"bytes={a.nbytes * 2}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    ap.add_argument("--full-suite", action="store_true",
                    help="also run the flash/rglru/fed_aggregate rows")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.full_suite:
        main(BenchSettings(), json_path=args.json)
    else:
        bench_fed_reduce(json_path=args.json)
