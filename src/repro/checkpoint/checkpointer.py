"""Minimal npz-based pytree checkpointing (no orbax in this container).

Flattens the pytree with path-derived keys; restores into the same
treedef.  Works for params, optimizer state, and FL server state.

Two layers:

``save_checkpoint``/``load_checkpoint`` — the original single-pair API
(kept for existing callers; NOT crash-safe and not dtype-exact for
extension dtypes).

``save_snapshot``/``load_snapshot``/``restore_tree`` — the hardened
serving-snapshot API (PR 9).  Crash-safety comes from a two-slot scheme:
each save writes a ``.npz``/``.json`` pair into the OLDER of two slots
(``<base>.a.*`` / ``<base>.b.*``) via temp files + atomic renames, never
touching the newer slot — so a writer killed at ANY instant leaves at
most one torn slot, and the loader (which validates json parse, npz
readability, and a shared random nonce stored in both halves) falls back
to the other slot, losing at most one snapshot generation.  This mirrors
the JSONL store's torn-tail policy.  Dtype exactness comes from
recording every leaf's dtype name in the json half: npz round-trips
extension dtypes like bfloat16 as raw void bytes, so the loader re-views
them (``ml_dtypes`` lookup) and ``restore_tree`` coerces each leaf back
to its template's type (python/numpy scalars included).
"""

from __future__ import annotations

import json
import os
import pathlib
import zipfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_NONCE_KEY = "__nonce__"
_SLOTS = (".a", ".b")


def _key(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        elif hasattr(e, "name"):
            parts.append(str(e.name))
        else:
            parts.append(str(e))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Any, *, step: int = 0,
                    metadata: dict | None = None):
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    leaves = {}
    jax.tree_util.tree_map_with_path(
        lambda p, x: leaves.setdefault(_key(p), np.asarray(x)), tree)
    np.savez(path.with_suffix(".npz"), **leaves)
    meta = {"step": step, **(metadata or {})}
    path.with_suffix(".json").write_text(json.dumps(meta))
    return str(path.with_suffix(".npz"))


def load_checkpoint(path: str, like: Any):
    """Restore into the structure of ``like`` (a template pytree)."""
    path = pathlib.Path(path)
    data = np.load(path.with_suffix(".npz"))
    restored = jax.tree_util.tree_map_with_path(
        lambda p, x: jax.numpy.asarray(data[_key(p)]), like)
    meta = {}
    if path.with_suffix(".json").exists():
        meta = json.loads(path.with_suffix(".json").read_text())
    return restored, meta


# ---------------------------------------------------------------------------
# hardened serving snapshots (two-slot, torn-write tolerant, dtype-exact)
# ---------------------------------------------------------------------------

def _dtype_from_name(name: str) -> np.dtype:
    """Resolve a recorded dtype name, including the ml_dtypes extension
    types (bfloat16 etc.) that numpy alone does not know."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _slot_paths(base, slot: str) -> Tuple[pathlib.Path, pathlib.Path]:
    s = str(base) + slot
    return pathlib.Path(s + ".npz"), pathlib.Path(s + ".json")


def _read_slot(base, slot: str) -> Optional[Tuple[dict, dict]]:
    """Validate one slot end to end; None on ANY defect (missing half,
    unparseable json, truncated npz, nonce mismatch between halves)."""
    npz_p, json_p = _slot_paths(base, slot)
    if not (npz_p.exists() and json_p.exists()):
        return None
    try:
        meta = json.loads(json_p.read_text())
        nonce = meta["nonce"]
        with np.load(npz_p) as data:
            if _NONCE_KEY not in data.files:
                return None
            if bytes(data[_NONCE_KEY]).hex() != nonce:
                return None
            arrays = {k: data[k] for k in data.files if k != _NONCE_KEY}
    except (OSError, ValueError, KeyError, EOFError, zipfile.BadZipFile):
        # the defects a torn write can leave behind: unreadable file,
        # unparseable json (ValueError covers JSONDecodeError), missing
        # meta key, truncated or corrupt npz archive
        return None
    dtypes = meta.get("dtypes", {})
    for k, arr in arrays.items():
        name = dtypes.get(k)
        if name and arr.dtype.name != name:
            dt = _dtype_from_name(name)
            arrays[k] = (arr.view(dt) if arr.dtype.itemsize == dt.itemsize
                         else arr.astype(dt))
    return arrays, meta


def _fsync_write(path: pathlib.Path, writer) -> None:
    with open(path, "wb") as f:
        writer(f)
        f.flush()
        os.fsync(f.fileno())


def save_snapshot(path: str, tree: Any, *, step: int = 0,
                  metadata: dict | None = None) -> str:
    """Write one snapshot generation crash-safely and return the npz path.

    The target slot is the stale one (invalid, absent, or lower step) —
    the newest valid slot is never touched, so a kill mid-write costs at
    most this generation.  Within the slot: temp files, fsync, then two
    atomic renames (npz first; a kill between them leaves a nonce
    mismatch the loader rejects)."""
    base = pathlib.Path(path)
    base.parent.mkdir(parents=True, exist_ok=True)
    leaves: Dict[str, np.ndarray] = {}
    jax.tree_util.tree_map_with_path(
        lambda p, x: leaves.setdefault(_key(p), np.asarray(x)), tree)
    assert _NONCE_KEY not in leaves, f"reserved leaf key {_NONCE_KEY}"
    nonce = os.urandom(8).hex()
    meta = {"step": int(step), "nonce": nonce,
            "dtypes": {k: v.dtype.name for k, v in leaves.items()},
            **(metadata or {})}

    # pick the slot to overwrite: invalid/absent beats valid, lower step
    # beats higher
    def slot_step(slot: str) -> float:
        got = _read_slot(base, slot)
        return float(got[1].get("step", 0)) if got is not None else -np.inf
    target = min(_SLOTS, key=slot_step)

    npz_p, json_p = _slot_paths(base, target)
    tmp_npz = pathlib.Path(str(base) + target + ".tmp.npz")
    tmp_json = pathlib.Path(str(base) + target + ".tmp.json")
    _fsync_write(tmp_npz, lambda f: np.savez(
        f, **{_NONCE_KEY: np.frombuffer(bytes.fromhex(nonce), np.uint8)},
        **leaves))
    _fsync_write(tmp_json, lambda f: f.write(json.dumps(meta).encode()))
    os.replace(tmp_npz, npz_p)
    os.replace(tmp_json, json_p)
    return str(npz_p)


def load_snapshot(path: str) -> Tuple[Dict[str, np.ndarray], dict]:
    """Newest valid generation: (dtype-restored arrays by leaf key, meta).
    Raises FileNotFoundError when no slot validates."""
    base = pathlib.Path(path)
    best = None
    for slot in _SLOTS:
        got = _read_slot(base, slot)
        if got is not None and (best is None
                                or got[1].get("step", 0)
                                > best[1].get("step", 0)):
            best = got
    if best is None:
        raise FileNotFoundError(f"no valid snapshot slot at {base}.{{a,b}}")
    return best


def restore_tree(arrays: Dict[str, np.ndarray], like: Any,
                 prefix: str = "") -> Any:
    """Rebuild a pytree shaped like ``like`` from ``load_snapshot``
    arrays, coercing each leaf back to its template's type: jax arrays
    stay jax (dtype preserved — no float64 downcast), numpy stays numpy,
    python/numpy scalars come back as scalars of the template's type."""
    def pick(p, t):
        arr = arrays[prefix + _key(p)]
        if isinstance(t, jax.Array):
            return jax.numpy.asarray(arr)
        if isinstance(t, np.ndarray):
            return arr
        if np.isscalar(t):
            return type(t)(arr.item())
        return arr
    return jax.tree_util.tree_map_with_path(pick, like)
