"""Event-driven heterogeneous FL runtime.

Per-client device profiles (speed / bandwidth / availability / dropout), a
virtual-clock event queue, three execution modes (sync with straggler
cutoff, FedAsync-style staleness-weighted async, FedBuff-style buffered
aggregation), and a vmapped batched client-execution path.
"""

from repro.runtime.batched import batched_local_train  # noqa: F401
from repro.runtime.engine import (EventDrivenRuntime,  # noqa: F401
                                  EventLoopState, RuntimeConfig)
from repro.runtime.events import (EventQueue, MergedEventQueue,  # noqa: F401
                                  TrialQueueView, VirtualClock)
from repro.runtime.sharded import (ShardedRound,  # noqa: F401
                                   sharded_fedavg_train)
from repro.runtime.profiles import (PROFILES, DeviceClass, Fleet,  # noqa: F401
                                    HeterogeneityProfile, VirtualFleet,
                                    get_profile, homogeneous_fleet,
                                    sample_fleet, virtual_fleet)
