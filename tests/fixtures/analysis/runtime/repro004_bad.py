"""BAD fixture: wall clock and host randomness in virtual-clock code.

This file sits under a ``runtime/`` path, so every marked call smuggles
host nondeterminism into what must be a pure function of seeds and the
virtual clock.  REPRO004 must fire on each.
"""

import random
import time

import numpy as np


def virtual_round(queue):
    start = time.time()                 # REPRO004: wall clock
    jitter = random.random()            # REPRO004: global random module
    rng = np.random.default_rng()       # REPRO004: unseeded generator
    draw = np.random.uniform()          # REPRO004: global numpy state
    return start + jitter + draw + rng.uniform()
