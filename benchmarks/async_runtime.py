"""Benchmark: the event-driven heterogeneous runtime.

Part 1 — batched client execution: wall-clock of the vmapped cohort path
(runtime/batched.py, size-bucketed) vs the sequential per-client jit loop at
M in {4, 16, 32, 64}.  The acceptance bar is batched < sequential from
M >= 16.

Part 2 — runtime-mode sweep under a straggler fleet: sync (wait for all),
sync with a 0.5-quantile straggler cutoff, async (FedAsync), and buffered
(FedBuff, K=M/2), all at the same (M, E).  Reports final accuracy, virtual
wall-clock, and the four overheads — the regime where system-aware (M, E)
tuning actually matters.

Usage: PYTHONPATH=src python benchmarks/async_runtime.py [--rounds N]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit, small_model
from repro.core import CostModel
from repro.data import emnist_like
from repro.federated import FLConfig, FLServer, get_aggregator
from repro.federated.client import local_train
from repro.optim.optimizers import get_optimizer
from repro.runtime import RuntimeConfig, sample_fleet
from repro.runtime.batched import batched_local_train


def bench_batched(reps: int = 3):
    ds = emnist_like(reduced=True)
    model = small_model("emnist")
    opt = get_optimizer("sgd", 0.03, momentum=0.9)
    params = model.init(jax.random.PRNGKey(0))
    print("# batched client execution vs sequential loop")
    for m in (4, 16, 32, 64):
        data = [ds.client_data(c) for c in range(m)]
        # warm both compile caches
        rng = np.random.default_rng(0)
        local_train(model, params, *data[0], passes=1.0, batch_size=10,
                    optimizer=opt, rng=rng)
        batched_local_train(model, params, data, passes=1.0, batch_size=10,
                            optimizer=opt, rng=np.random.default_rng(0))
        t0 = time.perf_counter()
        for _ in range(reps):
            rng = np.random.default_rng(0)
            for d in data:
                local_train(model, params, *d, passes=1.0, batch_size=10,
                            optimizer=opt, rng=rng)
        t_seq = (time.perf_counter() - t0) / reps
        t0 = time.perf_counter()
        for _ in range(reps):
            batched_local_train(model, params, data, passes=1.0,
                                batch_size=10, optimizer=opt,
                                rng=np.random.default_rng(0))
        t_bat = (time.perf_counter() - t0) / reps
        emit(f"seq_cohort_m{m}", t_seq * 1e6, f"{m} clients")
        emit(f"batched_cohort_m{m}", t_bat * 1e6,
             f"speedup={t_seq / t_bat:.2f}x")


def _server(rt, fleet, *, m, e, rounds):
    ds = emnist_like(reduced=True)
    model = small_model("emnist")
    n_params = sum(p.size for p in jax.tree.leaves(
        model.init(jax.random.PRNGKey(0))))
    return FLServer(
        model, ds, get_aggregator("fedavg"),
        get_optimizer("sgd", 0.03, momentum=0.9),
        CostModel(flops_per_example=2 * n_params, param_count=n_params),
        FLConfig(m=m, e=e, batch_size=10, target_accuracy=0.99,
                 max_rounds=rounds, eval_points=512),
        fleet=fleet, runtime_config=rt)


def bench_modes(rounds: int, m: int = 8, e: float = 1.0):
    print("# runtime modes under a straggler fleet "
          f"(M={m}, E={e:g}, {rounds} aggregations)")
    fleet_seed = 3
    modes = {
        "sync_full": RuntimeConfig(mode="sync"),
        "sync_cutoff": RuntimeConfig(mode="sync", deadline_quantile=0.5),
        "async": RuntimeConfig(mode="async"),
        "buffered": RuntimeConfig(mode="buffered", buffer_k=max(m // 2, 1)),
    }
    n_clients = emnist_like(reduced=True).n_clients
    for name, rt in modes.items():
        fleet = sample_fleet("stragglers", n_clients, seed=fleet_seed)
        srv = _server(rt, fleet, m=m, e=e, rounds=rounds)
        t0 = time.perf_counter()
        res = srv.run()
        wall = time.perf_counter() - t0
        c = res.total_cost
        emit(f"runtime_{name}", wall * 1e6,
             f"acc={res.final_accuracy:.3f} t_sim={res.sim_time:.3g} "
             f"CompT={c.comp_t:.3g} TransT={c.trans_t:.3g} "
             f"CompL={c.comp_l:.3g} TransL={c.trans_l:.3g}")


def main(settings=None, *, rounds: int = 20, reps: int = 3):
    del settings  # runs at reduced scale only; full-scale is future work
    bench_batched(reps)
    bench_modes(rounds)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()
    main(rounds=args.rounds, reps=args.reps)
