"""Fault model + hardened checkpointer (PR 9: fault-tolerant serving).

Pins the fault subsystem's contracts below the scheduler:

  * the failure/churn draws are STATELESS pure functions of the virtual
    clock (``hash01``) — a fleet with the fault model armed but never
    firing is bit-identical to one without it (zero rng consumption);
  * sync retry/reassignment — a failed cohort slot is detected at its
    virtual arrival instant, backed off, reassigned to a fresh client
    (which can itself fail, chaining), and its wasted CompT/TransT is
    charged to the round cost;
  * event retry — a FAILURE event charges the wasted work and
    re-dispatches the SAME client after backoff with attempt+1;
  * churn — epoch-based membership on the virtual clock, epoch 0 full,
    ``min_active`` floor, inactive clients invisible to selection;
  * ``TrialSpec`` knobs — key stability at defaults, validation;
  * the two-slot snapshot checkpointer — dtype-exact round-trips
    (bfloat16 included), torn-write fallback to the previous generation.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:   # only the property tests need hypothesis; unit tests always run
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from faultlib import FailureWindow, install_failures, scripted_failure_fn
from repro.checkpoint import load_snapshot, restore_tree, save_snapshot
from repro.configs.paper_models import MLPConfig
from repro.core import CostModel
from repro.data.synthetic import DataSpec, make_dataset
from repro.experiments import TrialSpec, run_trial, serve
from repro.federated import FLConfig, FLServer, get_aggregator
from repro.models import build_model
from repro.optim.optimizers import get_optimizer
from repro.runtime import RuntimeConfig, sample_fleet
from repro.runtime.profiles import ChurnSchedule, hash01


def small_dataset(seed=1):
    return make_dataset(DataSpec(
        name="ft_test", n_classes=4, shape=(12,), n_train_clients=24,
        n_test_clients=8, size_log_mean=2.5, size_log_std=0.5, seed=seed))


def mk_server(*, rt=None, fleet=None, max_rounds=3, m=5, e=2.0):
    ds = small_dataset()
    model = build_model(MLPConfig(name="mlp_ft", in_dim=12, hidden=(16,),
                                  n_classes=4))
    n_params = sum(p.size for p in jax.tree.leaves(
        model.init(jax.random.PRNGKey(0))))
    return FLServer(
        model, ds, get_aggregator("fedavg"),
        get_optimizer("sgd", 0.05, momentum=0.9),
        CostModel(flops_per_example=2 * n_params, param_count=n_params),
        FLConfig(m=m, e=e, batch_size=4, target_accuracy=0.99,
                 max_rounds=max_rounds, eval_points=128),
        fleet=fleet, runtime_config=rt)


def tiny_spec(**kw):
    base = dict(dataset="emnist", aggregator="fedavg", seed=0,
                tuner="fedtune", m0=3, e0=1.0, rounds=2,
                target_accuracy=0.99, batch_size=5, eval_points=128)
    base.update(kw)
    return TrialSpec(**base)


def assert_result_parity(a, b):
    assert a.reached_target == b.reached_target
    assert a.rounds == b.rounds
    assert a.final_accuracy == b.final_accuracy
    assert a.total_cost.as_tuple() == b.total_cost.as_tuple()
    assert [r.accuracy for r in a.history] == [r.accuracy for r in b.history]
    assert a.sim_time == b.sim_time
    assert a.dispatch_log == b.dispatch_log
    assert a.staleness_log == b.staleness_log


FAIL_FIRST = [FailureWindow(cid=c, max_attempt=1) for c in range(24)]


# ---------------------------------------------------------------------------
# the stateless draw
# ---------------------------------------------------------------------------

def test_hash01_deterministic_and_uniform():
    assert hash01(1, 2, 3) == hash01(1, 2, 3)
    assert hash01(1, 2, 3) != hash01(1, 2, 4)
    draws = [hash01(0, i) for i in range(2000)]
    assert all(0.0 <= d < 1.0 for d in draws)
    assert 0.4 < float(np.mean(draws)) < 0.6


def test_fleet_failure_draw_is_stateless():
    fleet = sample_fleet("homogeneous", 8, seed=3)
    assert not fleet.has_failures()
    assert not fleet.fails(0, 1.0)               # unarmed: never fails
    fleet.failure = np.full(8, 0.5)
    assert fleet.has_failures()
    # same (cid, t, attempt) always agrees; different attempt re-draws
    draws = [fleet.fails(2, 3.75, 0) for _ in range(5)]
    assert len(set(draws)) == 1
    hits = sum(fleet.fails(c, t * 0.1, a)
               for c in range(8) for t in range(100) for a in range(2))
    assert 0.35 < hits / 1600 < 0.65             # ~the armed hazard
    fleet.failure = np.zeros(8)
    assert not fleet.has_failures()              # rate 0 == unarmed
    fleet.failure_fn = scripted_failure_fn(
        [FailureWindow(cid=1, lo=2.0, hi=4.0)])
    assert fleet.has_failures()                  # script overrides hazard
    assert fleet.fails(1, 3.0) and not fleet.fails(1, 4.0)
    assert not fleet.fails(0, 3.0)


def test_churn_schedule_membership():
    sch = ChurnSchedule(period=10.0, rate=0.5, seed=7, min_active=2)
    assert sch.active_mask(16, 3.0).all()        # epoch 0: everyone
    m1 = sch.active_mask(16, 15.0)
    assert m1.sum() >= 2                         # min_active floor
    np.testing.assert_array_equal(m1, sch.active_mask(16, 19.9))  # frozen
    assert ChurnSchedule(period=10.0, rate=0.5, seed=8,
                         min_active=2).active_mask(16, 15.0).sum() != 16
    # brutal rate: the floor forces the lowest absent ids back in
    harsh = ChurnSchedule(period=5.0, rate=0.999, seed=0, min_active=3)
    assert harsh.active_mask(10, 12.0).sum() == 3


def test_churn_from_string():
    sch = ChurnSchedule.from_string("12:0.4:2", seed=5)
    assert (sch.period, sch.rate, sch.seed, sch.min_active) == (12.0, 0.4, 5, 2)
    assert ChurnSchedule.from_string("8:0.2").min_active == 1
    for bad in ("12", "0:0.5", "10:1.5", "10:0.5:0", "a:b"):
        with pytest.raises(ValueError):
            ChurnSchedule.from_string(bad)


def test_runtime_config_retry_validation():
    assert RuntimeConfig().max_retries == 2
    with pytest.raises(ValueError):
        RuntimeConfig(max_retries=-1)
    with pytest.raises(ValueError):
        RuntimeConfig(retry_backoff=-0.1)


# ---------------------------------------------------------------------------
# armed-but-silent fault model must not move a float
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["sync", "async", "buffered"])
def test_never_firing_failure_fn_is_bit_identical(mode):
    """``has_failures()`` is true (the failure code paths all run) but no
    dispatch ever fails: the result must be bit-identical to a fleet with
    no fault model at all — the checks consume zero rng."""
    base = mk_server(rt=RuntimeConfig(mode=mode),
                     fleet=sample_fleet("stragglers", 24, seed=3)).run()
    armed_fleet = install_failures(sample_fleet("stragglers", 24, seed=3),
                                   [])           # empty script: never fires
    assert armed_fleet.has_failures()
    armed = mk_server(rt=RuntimeConfig(mode=mode), fleet=armed_fleet).run()
    assert_result_parity(base, armed)


def test_zero_rate_spec_parity_and_key_stability():
    """failure_rate=0.0 / churn=None are the defaults: same trial key, and
    the run is bit-identical to a spec that never heard of faults."""
    plain, explicit = tiny_spec(), tiny_spec(failure_rate=0.0, churn=None)
    assert plain.key() == explicit.key()
    assert "fail=" not in plain.key() and "churn=" not in plain.key()
    a, b = run_trial(plain), run_trial(explicit)
    assert a.history_acc == b.history_acc
    np.testing.assert_allclose(a.cost, b.cost, rtol=0, atol=0)
    # non-default knobs DO enter the key (distinct trials in the store)
    assert "fail=0.2" in tiny_spec(failure_rate=0.2).key()
    assert "churn=8:0.1" in tiny_spec(churn="8:0.1").key()


def test_spec_fault_knob_validation():
    with pytest.raises(ValueError):
        tiny_spec(failure_rate=1.0).validate()
    with pytest.raises(ValueError):
        tiny_spec(failure_rate=-0.1).validate()
    with pytest.raises(ValueError):
        tiny_spec(churn="nope").validate()
    tiny_spec(failure_rate=0.5, churn="10:0.2").validate()


# ---------------------------------------------------------------------------
# sync retry/reassignment
# ---------------------------------------------------------------------------

def test_sync_failure_retries_and_charges_cost():
    """Every selected client's first attempt fails; each failed slot is
    reassigned to a fresh client whose attempt-1 dispatch succeeds.  The
    round completes with a full cohort and the wasted work is charged."""
    rt = RuntimeConfig(mode="sync")
    base = mk_server(rt=rt, fleet=sample_fleet("homogeneous", 24,
                                               seed=3)).run()
    fleet = install_failures(sample_fleet("homogeneous", 24, seed=3),
                             FAIL_FIRST)
    failed = mk_server(rt=rt, fleet=fleet).run()
    assert failed.rounds == base.rounds          # rounds survive failures
    assert len(failed.history) == len(base.history)
    # wasted dispatches cost load and virtual time on top of the baseline
    # (the critical-path maxima are over a DIFFERENT replacement cohort,
    # so only the additive load sums are strictly ordered)
    assert failed.total_cost.comp_l > base.total_cost.comp_l
    assert failed.total_cost.trans_l > base.total_cost.trans_l
    assert failed.sim_time > base.sim_time
    for rec in failed.history:
        assert rec.m == 5                        # cohort refilled every round


def test_sync_failure_without_retries_shrinks_cohort():
    """max_retries=0: a failed slot is simply lost (still charged), the
    round aggregates the survivors."""
    fleet = install_failures(sample_fleet("homogeneous", 24, seed=3),
                             [FailureWindow(cid=c) for c in range(24)])
    res = mk_server(rt=RuntimeConfig(mode="sync", max_retries=0),
                    fleet=fleet, max_rounds=2).run()
    assert res.rounds == 2                       # round survives 100% failure
    assert all(r.n_updates == 0 for r in res.history)


def test_sync_chained_retries_give_up_at_max():
    """Clients fail unconditionally: each slot chains retries until
    max_retries is exhausted, then the round proceeds without it."""
    fleet = install_failures(sample_fleet("homogeneous", 24, seed=3),
                             [FailureWindow(cid=c) for c in range(24)])
    res = mk_server(rt=RuntimeConfig(mode="sync", max_retries=2),
                    fleet=fleet, max_rounds=1).run()
    base = mk_server(rt=RuntimeConfig(mode="sync"),
                     fleet=sample_fleet("homogeneous", 24, seed=3),
                     max_rounds=1).run()
    assert res.history[0].n_updates == 0         # nobody ever survived
    # 5 initial + 5*2 chained retries all charged their compute
    assert res.total_cost.comp_l > 2 * base.total_cost.comp_l


# ---------------------------------------------------------------------------
# event-loop retry (async / buffered)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["async", "buffered"])
def test_event_failure_redispatches_same_client(mode):
    rt = RuntimeConfig(mode=mode)
    base = mk_server(rt=rt,
                     fleet=sample_fleet("homogeneous", 24, seed=3)).run()
    fleet = install_failures(sample_fleet("homogeneous", 24, seed=3),
                             FAIL_FIRST)
    failed = mk_server(rt=rt, fleet=fleet).run()
    assert failed.rounds == base.rounds
    # every first dispatch died and was re-dispatched: the log doubles
    assert len(failed.dispatch_log) > len(base.dispatch_log)
    first_cids = [c for _, c, _ in base.dispatch_log[:5]]   # initial M=5
    retried = [c for _, c, _ in failed.dispatch_log]
    for cid in first_cids:                       # same client retried
        assert retried.count(cid) >= 2
    assert failed.total_cost.comp_l > base.total_cost.comp_l
    assert failed.sim_time > base.sim_time


def test_event_failure_gives_up_at_max_retries():
    """Every dispatch before virtual t=40000 (past the fault-free run's
    whole horizon) dies: retry chains are abandoned at max_retries and
    the slots reassigned, until the outage window closes and arrivals
    resume."""
    outage = 40000.0
    fleet = install_failures(sample_fleet("homogeneous", 24, seed=3),
                             [FailureWindow(cid=c, hi=outage)
                              for c in range(24)])
    base = mk_server(rt=RuntimeConfig(mode="async"),
                     fleet=sample_fleet("homogeneous", 24, seed=3),
                     max_rounds=2).run()
    res = mk_server(rt=RuntimeConfig(mode="async", max_retries=1),
                    fleet=fleet, max_rounds=2).run()
    assert res.rounds == 2                       # outage survived
    # the outage burned many dispatches before the first one could land
    assert len(res.dispatch_log) > len(base.dispatch_log)
    assert res.sim_time > outage > base.sim_time
    assert len(res.staleness_log) == len(base.staleness_log)


# ---------------------------------------------------------------------------
# churn through the engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["sync", "async", "buffered"])
def test_churn_runs_and_preserves_round_structure(mode):
    fleet = sample_fleet("homogeneous", 24, seed=3)
    fleet.churn = ChurnSchedule(period=5.0, rate=0.5, seed=1, min_active=4)
    res = mk_server(rt=RuntimeConfig(mode=mode), fleet=fleet).run()
    assert res.rounds == 3
    assert len(res.history) == 3


def test_serve_parity_under_faults():
    """The tentpole contract: trials with failures AND churn drained
    through the scheduler are bit-identical to standalone runs."""
    specs = [tiny_spec(seed=s, rounds=1 + s % 2, failure_rate=0.25,
                       churn="15:0.4",
                       mode=("sync", "async", "buffered")[s % 3])
             for s in range(4)]
    base = {s.key(): run_trial(s) for s in specs}
    for got in serve(specs, max_lanes=2):
        b = base[got.spec.key()]
        assert b.history_acc == got.history_acc
        assert b.final_accuracy == got.final_accuracy
        np.testing.assert_allclose(b.cost, got.cost, rtol=0, atol=0)
        assert b.dispatch_log == got.dispatch_log
        assert b.staleness_log == got.staleness_log


# ---------------------------------------------------------------------------
# hardened checkpointer: dtype-exact round-trip, torn-write fallback
# ---------------------------------------------------------------------------

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(4, 3)), dtype=jnp.bfloat16),
        "b": jnp.asarray(rng.normal(size=(3,)), dtype=jnp.float32),
        "n64": rng.normal(size=(2, 2)),              # np float64
        "step": int(rng.integers(1000)),
        "lr": float(rng.normal()),
        "acc": np.float64(rng.normal()),
    }


def assert_tree_roundtrip(tree, arrays):
    back = restore_tree(arrays, tree)
    for k, v in tree.items():
        r = back[k]
        assert type(r) is type(v), (k, type(r), type(v))
        if isinstance(v, (jnp.ndarray, np.ndarray)):
            assert r.dtype == v.dtype, k
            np.testing.assert_array_equal(np.asarray(r, np.float64),
                                          np.asarray(v, np.float64))
        else:
            assert r == v, k


def test_snapshot_roundtrip_preserves_dtypes(tmp_path):
    tree = _tree()
    save_snapshot(str(tmp_path / "s"), tree, step=1, metadata={"tag": "x"})
    arrays, meta = load_snapshot(str(tmp_path / "s"))
    assert (meta["step"], meta["tag"]) == (1, "x")
    assert arrays["w"].dtype.name == "bfloat16"      # not void bytes
    assert_tree_roundtrip(tree, arrays)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10**6))
    def test_snapshot_roundtrip_property(tmp_path_factory, seed):
        tmp = tmp_path_factory.mktemp("snapprop")
        tree = _tree(seed)
        save_snapshot(str(tmp / f"s{seed}"), tree, step=seed)
        arrays, _ = load_snapshot(str(tmp / f"s{seed}"))
        assert_tree_roundtrip(tree, arrays)
else:
    @pytest.mark.parametrize("seed", range(8))
    def test_snapshot_roundtrip_property(tmp_path, seed):
        tree = _tree(seed)
        save_snapshot(str(tmp_path / "s"), tree, step=seed)
        arrays, _ = load_snapshot(str(tmp_path / "s"))
        assert_tree_roundtrip(tree, arrays)


def test_snapshot_two_slots_keep_previous_generation(tmp_path):
    base = str(tmp_path / "s")
    save_snapshot(base, {"v": np.arange(3)}, step=1)
    save_snapshot(base, {"v": np.arange(3) + 10}, step=2)
    arrays, meta = load_snapshot(base)
    assert meta["step"] == 2                         # newest wins
    np.testing.assert_array_equal(arrays["v"], np.arange(3) + 10)
    # both generations exist on disk: gen 1 was never touched by save 2
    slots = sorted(p.name for p in tmp_path.iterdir())
    assert slots == ["s.a.json", "s.a.npz", "s.b.json", "s.b.npz"]


def test_snapshot_torn_npz_falls_back(tmp_path):
    base = str(tmp_path / "s")
    save_snapshot(base, {"v": np.arange(3)}, step=1)
    newest = save_snapshot(base, {"v": np.arange(3) + 10}, step=2)
    # tear the newest npz mid-write (truncate to half)
    raw = open(newest, "rb").read()
    open(newest, "wb").write(raw[:len(raw) // 2])
    arrays, meta = load_snapshot(base)
    assert meta["step"] == 1                         # previous generation
    np.testing.assert_array_equal(arrays["v"], np.arange(3))
    # the NEXT save overwrites the torn slot, not the surviving one
    save_snapshot(base, {"v": np.arange(3) + 20}, step=3)
    arrays, meta = load_snapshot(base)
    assert meta["step"] == 3
    np.testing.assert_array_equal(arrays["v"], np.arange(3) + 20)


def test_snapshot_nonce_mismatch_falls_back(tmp_path):
    """A kill between the two renames publishes a new npz with the OLD
    json: the nonce check rejects the mismatched pair."""
    base = str(tmp_path / "s")
    save_snapshot(base, {"v": np.arange(3)}, step=1)
    npz2 = save_snapshot(base, {"v": np.arange(3) + 10}, step=2)
    meta_path = npz2[:-len(".npz")] + ".json"
    meta = json.loads(open(meta_path).read())
    meta["nonce"] = "00" * 8
    open(meta_path, "w").write(json.dumps(meta))
    assert load_snapshot(base)[1]["step"] == 1


def test_snapshot_no_valid_slot_raises(tmp_path):
    base = str(tmp_path / "s")
    with pytest.raises(FileNotFoundError):
        load_snapshot(base)
    save_snapshot(base, {"v": np.arange(3)}, step=1)
    npz = str(tmp_path / "s.a.npz")
    open(npz, "wb").write(b"junk")
    with pytest.raises(FileNotFoundError):
        load_snapshot(base)
