"""Beyond-paper ablations: extensions the paper lists as future work
(§6), implemented and measured against the FedTune baseline.

  guided      — Oort-lite utility-based participant selection
  smallest    — deadline-style selection (bounds the CompT straggler term)
  int8-upload — compressed client deltas (TransL upload / 4)
  adaptive    — FedTune with magnitude-scaled steps (paper's noted
                'change hyper-parameters with adaptive degrees')
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (BenchSettings, emit, fedtune_for, improvement,
                               run_fl)
from repro.core.preferences import Preference

PREF = Preference(0.25, 0.25, 0.25, 0.25)


def _run(settings, label, *, selection="random", compression=None,
         adaptive=False, tuner_on=True):
    import jax
    from benchmarks.common import DATASETS, small_model
    from repro.core import CostModel
    from repro.federated import FLConfig, FLServer, get_aggregator
    from repro.optim.optimizers import get_optimizer

    ds = DATASETS["emnist"](reduced=not settings.full, seed=0)
    model = small_model("emnist")
    n_params = sum(p.size for p in jax.tree.leaves(
        model.init(jax.random.PRNGKey(0))))
    cm = CostModel(flops_per_example=2 * n_params, param_count=n_params)
    tuner = fedtune_for(PREF, settings.m0, settings.e0,
                        adaptive=adaptive) if tuner_on else None
    server = FLServer(
        model, ds, get_aggregator("fedavg"),
        get_optimizer("sgd", settings.lr, momentum=0.9), cm,
        FLConfig(m=settings.m0, e=settings.e0, batch_size=10,
                 target_accuracy=settings.target_accuracy,
                 max_rounds=settings.max_rounds, eval_points=512,
                 selection=selection, compression=compression),
        tuner=tuner)
    res = server.run()
    return res


def main(settings: BenchSettings):
    base = _run(settings, "baseline", tuner_on=False)
    emit("beyond/baseline-fixed", 0.0,
         f"rounds={base.rounds};acc={base.final_accuracy:.3f}")
    for label, kw in {
        "fedtune": {},
        "fedtune+guided": {"selection": "guided"},
        "fedtune+smallest": {"selection": "smallest"},
        "fedtune+int8upload": {"compression": "int8"},
        "fedtune+adaptive": {"adaptive": True},
    }.items():
        res = _run(settings, label, **kw)
        gain = improvement(PREF, base.total_cost, res.total_cost)
        emit(f"beyond/{label}", 0.0,
             f"gain={gain:+.2f}%;rounds={res.rounds};"
             f"acc={res.final_accuracy:.3f};M={res.final_m};E={res.final_e:g}")
