"""Pallas TPU kernel: weighted FL aggregation  out = base + sum_m w_m * delta_m.

This is the server-side hot spot of every FL round (paper eq. 1 aggregation):
a memory-bound weighted reduction over M participant deltas of N parameters.
Tiling: the parameter axis is cut into lane-aligned VMEM blocks; each grid
step loads an (M, BLOCK_N) tile of deltas, the (M, 1) weight column and a
(BLOCK_N,) base tile, and reduces over M in VREGs.  Arithmetic intensity is
~1 FLOP / 2 bytes -> firmly HBM-bandwidth-bound, so the only job of the
kernel is to stream deltas exactly once at full bandwidth.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 2048  # lane-aligned (16 x 128) f32 tile per delta row


def _kernel(w_ref, base_ref, x_ref, o_ref):
    # w: (M, 1) f32, base: (1, BLOCK_N), x: (M, BLOCK_N), o: (1, BLOCK_N)
    w = w_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    acc = jnp.sum(w * x, axis=0, keepdims=True)
    o_ref[...] = (acc + base_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fed_aggregate(weights, deltas, base=None, *, block_n: int = BLOCK_N,
                  interpret: bool = False):
    """weights: (M,); deltas: (M, N); base: (N,) or None -> (N,)."""
    m, n = deltas.shape
    if base is None:
        base = jnp.zeros((n,), deltas.dtype)
    pad = (-n) % block_n
    if pad:
        deltas = jnp.pad(deltas, ((0, 0), (0, pad)))
        base = jnp.pad(base, (0, pad))
    n_pad = n + pad
    w2 = weights.reshape(m, 1).astype(jnp.float32)
    base2 = base.reshape(1, n_pad)

    out = pl.pallas_call(
        _kernel,
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((m, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), deltas.dtype),
        interpret=interpret,
    )(w2, base2, deltas)
    return out[0, :n]
