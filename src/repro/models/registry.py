"""Uniform model handles.

``build_model(cfg)`` returns a ``Model`` with a consistent functional API
regardless of family (LM configs or the paper's vision configs), so the FL
substrate, launcher and benchmarks are model-agnostic.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.paper_models import MLPConfig, ResNetConfig
from repro.models import lm as lm_mod
from repro.models import mlp as mlp_mod
from repro.models import resnet as resnet_mod


@dataclass(frozen=True)
class Model:
    config: Any
    init: Callable[..., Any]                    # (key, dtype) -> params
    loss_fn: Callable[..., Any]                 # (params, batch) -> (loss, metrics)
    forward: Optional[Callable[..., Any]] = None
    init_cache: Optional[Callable[..., Any]] = None
    prefill: Optional[Callable[..., Any]] = None
    decode_step: Optional[Callable[..., Any]] = None
    flops_per_example: Optional[float] = None   # analytic fwd FLOPs (vision)


def _classifier_loss(forward):
    def loss_fn(params, cfg, batch):
        logits = forward(params, cfg, batch["x"])
        labels = batch["y"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        # optional per-example mask (for padded client batches)
        mask = batch.get("mask")
        if mask is None:
            loss = nll.mean()
            acc = (logits.argmax(-1) == labels).mean()
        else:
            denom = jnp.maximum(mask.sum(), 1)
            loss = jnp.where(mask, nll, 0.0).sum() / denom
            acc = jnp.where(mask, logits.argmax(-1) == labels, False).sum() / denom
        return loss, {"ce": loss, "acc": acc}
    return loss_fn


def build_model(cfg: Union[ModelConfig, ResNetConfig, MLPConfig]) -> Model:
    if isinstance(cfg, ModelConfig):
        return Model(
            config=cfg,
            init=lambda key, dtype=jnp.float32: lm_mod.init_params(cfg, key, dtype),
            loss_fn=lambda params, batch, **kw: lm_mod.loss_fn(params, cfg, batch, **kw),
            forward=lambda params, tokens, **kw: lm_mod.forward(params, cfg, tokens, **kw),
            init_cache=lambda batch, max_len, **kw: lm_mod.init_cache(cfg, batch, max_len, **kw),
            prefill=lambda params, tokens, cache, **kw: lm_mod.prefill(params, cfg, tokens, cache, **kw),
            decode_step=lambda params, token, pos, cache: lm_mod.decode_step(params, cfg, token, pos, cache),
        )
    if isinstance(cfg, ResNetConfig):
        fwd = resnet_mod.forward
        return Model(
            config=cfg,
            init=lambda key, dtype=jnp.float32: resnet_mod.init_params(cfg, key, dtype),
            loss_fn=lambda params, batch: _classifier_loss(fwd)(params, cfg, batch),
            forward=lambda params, x: fwd(params, cfg, x),
            flops_per_example=resnet_mod.flops_per_example(cfg),
        )
    if isinstance(cfg, MLPConfig):
        fwd = mlp_mod.forward
        return Model(
            config=cfg,
            init=lambda key, dtype=jnp.float32: mlp_mod.init_params(cfg, key, dtype),
            loss_fn=lambda params, batch: _classifier_loss(fwd)(params, cfg, batch),
            forward=lambda params, x: fwd(params, cfg, x),
            flops_per_example=mlp_mod.flops_per_example(cfg),
        )
    raise TypeError(f"unknown config type {type(cfg)}")
