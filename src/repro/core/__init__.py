from repro.core.costs import CostModel, SystemCost
from repro.core.preferences import Preference
from repro.core.fedtune import FedTune, FedTuneConfig
from repro.core.tuner import FixedTuner, Tuner

__all__ = ["CostModel", "SystemCost", "Preference", "FedTune",
           "FedTuneConfig", "FixedTuner", "Tuner"]
