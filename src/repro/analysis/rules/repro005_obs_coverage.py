"""REPRO005 — observability coverage and trace-schema name hygiene.

Two checks keep the PR 6 observability layer honest as the engine grows:

1. **Coverage** — the engine's plan/apply/account/finish factoring is
   the replay contract the sweep runner depends on, and PR 6 put a span
   on each stage so traces show the whole macro-step.  Any method named
   ``plan_*``/``apply_*``/``account_*``/``finish_*`` on a class in
   ``runtime/`` or ``experiments/`` must carry ``@obs.traced(...)`` or
   open an ``obs.span(...)`` — a new stage without a span is a blind
   spot in every Perfetto trace.
2. **Name catalog** — span names, metric names and phases are pinned in
   ``obs/trace_schema.json`` (``span_names`` / ``metric_names`` /
   ``phases``).  A literal name used at an ``obs.span``/``obs.record``/
   ``obs.traced``/``obs.counter``/``registry.inc|sample|observe|gauge``
   call site that is missing from the catalog means ``tools/
   trace_report.py`` and downstream dashboards silently drop it.
"""

from __future__ import annotations

import ast
import json
from pathlib import Path

from ..core import FileContext, Rule, register
from ..scopes import FuncNode, dotted_parts, final_name

COVERAGE_DIRS = {"runtime", "experiments"}
STAGE_PREFIXES = ("plan_", "apply_", "account_", "finish_")
REGISTRY_METHODS = {"inc", "sample", "observe", "gauge"}
SPAN_CALLS = {"span", "record", "traced"}

_SCHEMA_PATH = Path(__file__).resolve().parents[2] / "obs" / \
    "trace_schema.json"


def _load_catalogs():
    try:
        schema = json.loads(_SCHEMA_PATH.read_text(encoding="utf-8"))
    except (OSError, json.JSONDecodeError):
        return None
    return {
        "span_names": frozenset(schema.get("span_names", ())),
        "metric_names": frozenset(schema.get("metric_names", ())),
        "phases": frozenset(schema.get("phases", ())),
    }


def _str_arg(node: ast.Call):
    if node.args and isinstance(node.args[0], ast.Constant) \
            and isinstance(node.args[0].value, str):
        return node.args[0].value
    return None


def _phase_kwarg(node: ast.Call):
    for kw in node.keywords:
        if kw.arg == "phase" and isinstance(kw.value, ast.Constant) \
                and isinstance(kw.value.value, str):
            return kw.value.value
    return None


def _has_span(method) -> bool:
    for dec in method.decorator_list:
        if isinstance(dec, ast.Call) and final_name(dec.func) == "traced" \
                and "obs" in dotted_parts(dec.func):
            return True
    for node in ast.walk(method):
        if isinstance(node, ast.Call) \
                and final_name(node.func) in {"span", "record"} \
                and "obs" in dotted_parts(node.func):
            return True
    return False


@register
class ObsCoverage(Rule):
    id = "REPRO005"
    name = "observability-coverage"

    def __init__(self):
        self._catalogs = _load_catalogs()

    def check_file(self, ctx: FileContext):
        parts = set(ctx.rel.split("/"))
        if parts & COVERAGE_DIRS:
            self._check_coverage(ctx)
        self._check_names(ctx)

    def _check_coverage(self, ctx: FileContext):
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            for method in cls.body:
                if not isinstance(method, FuncNode):
                    continue
                if not method.name.startswith(STAGE_PREFIXES):
                    continue
                if not _has_span(method):
                    ctx.add(method, self.id,
                            f"engine stage `{cls.name}.{method.name}` has "
                            "no span instrumentation — decorate with "
                            "@obs.traced(...) so traces cover every "
                            "plan/apply/account/finish stage")

    def _check_names(self, ctx: FileContext):
        if self._catalogs is None:
            return
        spans = self._catalogs["span_names"]
        metrics = self._catalogs["metric_names"]
        phases = self._catalogs["phases"]
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = dotted_parts(node.func)
            last = chain[-1] if chain else None
            name = _str_arg(node)
            if name is None:
                continue
            if "obs" in chain and last in SPAN_CALLS:
                if name not in spans:
                    ctx.add(node, self.id,
                            f"span name '{name}' is not in trace_schema."
                            "json span_names — add it to the catalog so "
                            "trace tooling knows it")
                phase = _phase_kwarg(node)
                if phase is not None and phase not in phases:
                    ctx.add(node, self.id,
                            f"phase '{phase}' is not in trace_schema.json "
                            "phases — add it to the catalog")
            elif "obs" in chain and last == "counter":
                if name not in metrics:
                    ctx.add(node, self.id,
                            f"counter name '{name}' is not in trace_schema"
                            ".json metric_names — add it to the catalog")
            elif "registry" in chain and last in REGISTRY_METHODS:
                if name not in metrics:
                    ctx.add(node, self.id,
                            f"metric name '{name}' is not in trace_schema"
                            ".json metric_names — add it to the catalog "
                            "so tools/trace_report.py can label it")
