"""Dual-clock span tracer.

A :class:`Span` carries two time bases at once:

* **wall clock** — ``time.perf_counter()`` at enter/exit, i.e. what the
  host actually spent (JAX dispatch, compilation, python orchestration);
* **virtual clock** — the event runtime's simulated federated time
  (``VirtualClock.now``), i.e. what the *modelled* system spent.

The pair is what makes sweep traces legible: a lane whose virtual round
took 40 s of simulated client time may cost 3 ms of host time inside a
pack of 16 lanes — both numbers end up on adjacent Perfetto tracks.

Zero-cost-when-disabled contract: ``Tracer.span`` returns the shared
:data:`NULL_SPAN` (a no-op context manager with ``__slots__ = ()``) when
the tracer is off, and ``record``/``counter`` return immediately.  The
tracer never touches rngs or training values, so enabling it cannot
perturb results (bit-parity is pinned in tests/test_obs.py).

This module imports nothing from the rest of ``repro`` so every layer —
runtime, experiments, federated, launch — can instrument freely without
import cycles.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple


@dataclass
class Span:
    """One traced interval on (up to) two clocks.

    ``virtual_t0/t1`` are ``None`` for host-only spans (e.g. a pack
    compile); ``wall_t0 == wall_t1`` for retroactively recorded
    virtual-only intervals (e.g. an in-flight client window known once
    its arrival event pops).
    """

    name: str
    phase: Optional[str] = None
    trial: Optional[str] = None
    lane: Optional[int] = None
    round_idx: Optional[int] = None
    wall_t0: float = 0.0
    wall_t1: float = 0.0
    virtual_t0: Optional[float] = None
    virtual_t1: Optional[float] = None
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def wall_dur(self) -> float:
        return self.wall_t1 - self.wall_t0

    @property
    def virtual_dur(self) -> Optional[float]:
        if self.virtual_t0 is None or self.virtual_t1 is None:
            return None
        return self.virtual_t1 - self.virtual_t0


class _NullSpan:
    """Shared do-nothing span handed out while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc_value, tb):
        return False

    def set(self, **attrs):
        return self


NULL_SPAN = _NullSpan()


class _LiveSpan:
    """Context manager that stamps both clocks and appends to the tracer."""

    __slots__ = ("_tracer", "_span", "_clock", "_annotation")

    def __init__(self, tracer: "Tracer", span: Span, clock, annotation):
        self._tracer = tracer
        self._span = span
        self._clock = clock
        self._annotation = annotation

    def set(self, **attrs):
        self._span.attrs.update(attrs)
        return self

    def __enter__(self):
        if self._clock is not None:
            self._span.virtual_t0 = self._clock.now
        if self._annotation is not None:
            self._annotation.__enter__()
        self._span.wall_t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc_value, tb):
        self._span.wall_t1 = time.perf_counter()
        if self._annotation is not None:
            self._annotation.__exit__(exc_type, exc_value, tb)
        if self._clock is not None:
            self._span.virtual_t1 = self._clock.now
        self._tracer.spans.append(self._span)
        return False


class Tracer:
    """Process-wide span collector (singleton at :data:`tracer`).

    ``counters`` holds ``(name, wall_t, value)`` samples for Chrome
    "C"-phase counter tracks (e.g. the global ``t_sim`` watermark).
    """

    def __init__(self):
        self.enabled = False
        self.spans: List[Span] = []
        self.counters: List[Tuple[str, float, float]] = []
        self._annotation_cls: Optional[Callable] = None

    def enable(self, jax_annotations: bool = False, reset: bool = True):
        if reset:
            self.clear()
        self._annotation_cls = None
        if jax_annotations:
            try:
                from jax.profiler import TraceAnnotation
                self._annotation_cls = TraceAnnotation
            except (ImportError, AttributeError):
                # profiler unavailable -> spans still work
                self._annotation_cls = None
        self.enabled = True

    def disable(self):
        self.enabled = False
        self._annotation_cls = None

    def clear(self):
        self.spans = []
        self.counters = []

    def span(self, name: str, *, phase: Optional[str] = None,
             trial: Optional[str] = None, lane: Optional[int] = None,
             round_idx: Optional[int] = None, clock=None, **attrs):
        """Open a span; pass ``clock`` (an object with ``.now``) to also
        stamp virtual time at enter/exit."""
        if not self.enabled:
            return NULL_SPAN
        sp = Span(name=name, phase=phase, trial=trial, lane=lane,
                  round_idx=round_idx, attrs=attrs)
        ann = (self._annotation_cls(name)
               if self._annotation_cls is not None else None)
        return _LiveSpan(self, sp, clock, ann)

    def record(self, name: str, *,
               wall: Optional[Tuple[float, float]] = None,
               virtual: Optional[Tuple[float, float]] = None,
               phase: Optional[str] = None, trial: Optional[str] = None,
               lane: Optional[int] = None, round_idx: Optional[int] = None,
               **attrs):
        """Append a completed span whose bounds are already known — the
        way virtual intervals are traced, since their extent only exists
        after the clock has advanced past them."""
        if not self.enabled:
            return
        now = time.perf_counter()
        w0, w1 = wall if wall is not None else (now, now)
        v0, v1 = virtual if virtual is not None else (None, None)
        self.spans.append(Span(name=name, phase=phase, trial=trial,
                               lane=lane, round_idx=round_idx,
                               wall_t0=w0, wall_t1=w1,
                               virtual_t0=v0, virtual_t1=v1, attrs=attrs))

    def counter(self, name: str, value, wall_t: Optional[float] = None):
        if not self.enabled:
            return
        t = time.perf_counter() if wall_t is None else wall_t
        self.counters.append((name, t, float(value)))


tracer = Tracer()


def traced(name: str, phase: Optional[str] = None):
    """Method decorator: wrap calls in a span attributed to the owner's
    ``trace_label`` (the runtime sets this to the trial key).  When the
    tracer is off the only cost is one attribute check."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(self, *args, **kwargs):
            if not tracer.enabled:
                return fn(self, *args, **kwargs)
            with tracer.span(name, phase=phase,
                             trial=getattr(self, "trace_label", None)):
                return fn(self, *args, **kwargs)
        return wrapper
    return deco
