"""Continuous-batching trial scheduler (repro.experiments.scheduler).

Pins the subsystem's two contracts:

  * allocation determinism — ``LanePool`` hands out the lowest free lane,
    never double-assigns, and admission order is the queue order no matter
    how retirements interleave (property-tested with hypothesis);
  * bit-parity — every trial drained through ``serve()`` (sync, async,
    buffered, and mixed, with ``max_lanes`` < T forcing mid-flight
    admission and retirement) is BIT-identical to an independent
    ``FLServer.run()``: accuracies, costs, FedTune trajectories, dispatch
    and staleness logs.

Plus the satellites that enable it: ``MergedEventQueue.drop_trial``,
the result store's O(1) completed-key cache, pow2-padded stacked eval
bitmatch, the watched submissions file, and kill-mid-drain resume.
"""

import json
from collections import deque

import jax
import numpy as np
import pytest

try:   # only the property tests need hypothesis; unit tests always run
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.experiments import ResultStore, TrialSpec, run_trial, serve
from repro.experiments.scheduler import LanePool, TrialQueue, TrialScheduler
from repro.runtime.events import ARRIVAL, MergedEventQueue


def tiny_spec(**kw):
    base = dict(dataset="emnist", aggregator="fedavg", seed=0,
                tuner="fedtune", m0=3, e0=1.0, rounds=3,
                target_accuracy=0.99, batch_size=5, eval_points=128)
    base.update(kw)
    return TrialSpec(**base)


def assert_trial_parity(base, vec):
    """Round records must be identical: accuracies, FedTune (M, E)
    trajectories, cost totals — and for event-driven (async/buffered)
    trials, the full dispatch schedule and staleness sequence."""
    assert base.history_acc == vec.history_acc
    assert base.history_m == vec.history_m
    assert base.history_e == vec.history_e
    assert base.final_accuracy == vec.final_accuracy
    assert (base.final_m, base.final_e) == (vec.final_m, vec.final_e)
    np.testing.assert_allclose(base.cost, vec.cost, rtol=0, atol=0)
    assert base.reached == vec.reached
    assert base.rounds == vec.rounds
    assert base.dispatch_log == vec.dispatch_log
    assert base.staleness_log == vec.staleness_log


# ---------------------------------------------------------------------------
# LanePool: the page table
# ---------------------------------------------------------------------------

def test_lane_pool_alloc_release_reuse():
    pool = LanePool(3)
    assert pool.alloc("a") == 0
    assert pool.alloc("b") == 1
    assert pool.alloc("c") == 2
    assert (pool.n_live, pool.n_free) == (3, 0)
    assert pool.occupancy() == 1.0
    assert pool.live_mask() == [True, True, True]
    with pytest.raises(ValueError):
        pool.alloc("d")                      # full
    with pytest.raises(ValueError):
        pool.alloc("a")                      # double admission
    assert pool.release("b") == 1
    assert pool.live_mask() == [True, False, True]
    assert pool.live_keys() == ["a", "c"]
    assert pool.alloc("d") == 1              # lowest free lane, reused
    assert pool.lane_of("d") == 1
    assert pool.key_of(1) == "d"
    with pytest.raises(KeyError):
        pool.release("b")                    # released twice
    with pytest.raises(ValueError):
        LanePool(0)


if HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(cap=st.integers(1, 6),
           n_trials=st.integers(1, 20),
           retire_choices=st.lists(st.integers(0, 10**6), max_size=64))
    def test_lane_pool_invariants_under_interleaving(cap, n_trials,
                                                     retire_choices):
        """No double-assignment, alloc always hands out the LOWEST free
        lane, and the admission sequence equals the queue order no matter
        which live trial retires when."""
        pending = deque(f"k{i}" for i in range(n_trials))
        pool = LanePool(cap)
        choices = iter(retire_choices)
        admitted = []
        while pending or pool.n_live:
            while pending and pool.n_free:
                key = pending.popleft()
                free_before = [lane for lane in range(cap)
                               if pool.key_of(lane) is None]
                lane = pool.alloc(key)
                assert lane == min(free_before)      # lowest-free policy
                admitted.append(key)
            # page table is a bijection: every live key holds exactly the
            # lane that maps back to it
            live = pool.live_keys()
            assert len(live) == len(set(live)) == pool.n_live
            for key in live:
                assert pool.key_of(pool.lane_of(key)) == key
            assert pool.n_live + pool.n_free == cap
            # retire an arbitrary live trial (hypothesis picks which)
            victim = live[next(choices, 0) % len(live)]
            lane = pool.release(victim)
            assert pool.key_of(lane) is None
        assert admitted == [f"k{i}" for i in range(n_trials)]
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_lane_pool_invariants_under_interleaving():
        pass


# ---------------------------------------------------------------------------
# TrialQueue: dedup, resume set, watched submissions file
# ---------------------------------------------------------------------------

def test_trial_queue_dedup_and_completed():
    done_key = tiny_spec(seed=2).key()
    q = TrialQueue(specs=[tiny_spec(seed=0), tiny_spec(seed=1),
                          tiny_spec(seed=0)],          # dup in the seed grid
                   completed=[done_key])
    assert (q.n_submitted, q.n_skipped) == (2, 1)
    assert not q.submit(tiny_spec(seed=2))             # already completed
    assert q.n_skipped == 2
    assert len(q) == 2
    first = q.pop()
    assert first.key() == tiny_spec(seed=0).key()      # FIFO
    q.mark_done(first.key())
    assert not q.submit(tiny_spec(seed=0))             # done after the fact


def test_trial_queue_watch_file(tmp_path):
    path = tmp_path / "subs.jsonl"
    q = TrialQueue(watch_path=str(path))
    assert q.poll() == 0                               # absent file: no-op
    with open(path, "w") as f:
        f.write(json.dumps({"spec": tiny_spec(seed=0).to_dict()}) + "\n")
        f.write("{not json\n")                          # malformed: skipped
        f.write(json.dumps(tiny_spec(seed=1).to_dict()))  # torn tail
    assert q.poll() == 1                # good line in; tail left for later
    assert len(q) == 1
    assert q.poll() == 0                # tail still incomplete
    with open(path, "a") as f:
        f.write("\n")                    # writer finishes the line
        f.write(json.dumps({"spec": tiny_spec(seed=0).to_dict()}) + "\n")
    assert q.poll() == 1                # tail retried; duplicate skipped
    keys = [q.pop().key() for _ in range(2)]
    assert keys == [tiny_spec(seed=0).key(), tiny_spec(seed=1).key()]


# ---------------------------------------------------------------------------
# MergedEventQueue.drop_trial: a retired trial's events must vanish
# ---------------------------------------------------------------------------

def test_merged_queue_drop_trial():
    q = MergedEventQueue()
    q.push(0, 1.0, ARRIVAL, client_id=1)
    q.push(1, 0.5, ARRIVAL, client_id=2)
    q.push(0, 2.0, ARRIVAL, client_id=3)
    q.push(1, 3.0, ARRIVAL, client_id=4)
    assert q.drop_trial(0) == 2
    assert q.count_for(0) == 0
    assert q.drop_trial(0) == 0          # idempotent
    assert q.drop_trial(7) == 0          # never-admitted ordinal
    popped = [(ev.trial_ord, ev.client_id) for ev in (q.pop(), q.pop())]
    assert popped == [(1, 2), (1, 4)]    # survivor's order untouched
    # the dropped trial's seq counter keeps counting: ordinals are never
    # reused, so later pushes stay totally ordered
    assert q.push(0, 9.0, ARRIVAL).seq == 2


# ---------------------------------------------------------------------------
# ResultStore: completed-key cache (no per-admission JSONL re-parse)
# ---------------------------------------------------------------------------

def test_store_completed_keys_parses_once(tmp_path, monkeypatch):
    store = ResultStore(str(tmp_path / "r.jsonl"))
    store.append({"key": "a", "status": "done"})
    calls = {"n": 0}
    orig = ResultStore.load

    def counting_load(self):
        calls["n"] += 1
        return orig(self)

    monkeypatch.setattr(ResultStore, "load", counting_load)
    assert store.completed_keys() == {"a"}
    assert store.is_completed("a") and not store.is_completed("b")
    assert calls["n"] == 1               # built once...
    store.append({"key": "b", "status": "done"})
    store.append({"key": "c", "status": "running"})   # not done: not a key
    assert store.completed_keys() == {"a", "b"}
    assert calls["n"] == 1               # ...kept current by append
    store.clear()
    assert store.completed_keys() == set()
    assert calls["n"] == 2               # clear invalidates


# ---------------------------------------------------------------------------
# pow2-padded stacked eval: shape stability must not move a float
# ---------------------------------------------------------------------------

def test_evaluate_stacked_pad_pow2_bitmatch():
    from repro.experiments.runner import build_server
    from repro.federated.evaluation import _pow2_lanes, evaluate_stacked
    assert [_pow2_lanes(n) for n in (0, 1, 2, 3, 5, 8)] == [1, 1, 2, 4, 8, 8]
    srv = build_server(tiny_spec())
    params = [srv.model.init(jax.random.PRNGKey(s)) for s in range(5)]
    items = [(srv.model, srv.dataset, 128, p) for p in params]
    assert evaluate_stacked(items, pad_pow2=True) == evaluate_stacked(items)


# ---------------------------------------------------------------------------
# serve(): bit-parity under mid-flight admission and retirement
# ---------------------------------------------------------------------------

def test_serve_sync_parity_midflight():
    """max_lanes=2 over 6 sync trials with staggered round budgets: every
    retirement admits a new trial into a half-live pool."""
    specs = [tiny_spec(seed=s, rounds=1 + s % 3) for s in range(6)]
    base = [run_trial(s) for s in specs]
    got = serve(specs, max_lanes=2)
    assert len(got) == 6
    by_key = {r.spec.key(): r for r in got}
    for b in base:
        assert_trial_parity(b, by_key[b.spec.key()])
    assert all(r.engine.startswith("serve-sync/") for r in got)


def test_serve_event_parity_midflight():
    """Async + buffered trials through the merged-queue engine with lane
    churn: a retired trial's pending events are dropped and its ordinal
    never reused, so survivors' dispatch/staleness logs stay bit-exact."""
    specs = [tiny_spec(seed=s, rounds=1 + s % 3,
                       mode="async" if s % 2 == 0 else "buffered")
             for s in range(6)]
    base = [run_trial(s) for s in specs]
    got = serve(specs, max_lanes=2)
    by_key = {r.spec.key(): r for r in got}
    for b in base:
        assert_trial_parity(b, by_key[b.spec.key()])
    assert all(r.engine == "serve-events/batched" for r in got)


def test_serve_mixed_modes_parity():
    """One pool shared by sync AND event trials — the serving daemon's
    actual shape."""
    specs = ([tiny_spec(seed=s, rounds=1 + s) for s in range(3)]
             + [tiny_spec(seed=3, rounds=2, mode="async"),
                tiny_spec(seed=4, rounds=1, mode="buffered")])
    base = [run_trial(s) for s in specs]
    got = serve(specs, max_lanes=3)
    by_key = {r.spec.key(): r for r in got}
    for b in base:
        assert_trial_parity(b, by_key[b.spec.key()])


def test_scheduler_admission_order_and_stats():
    specs = [tiny_spec(seed=s, rounds=1 + s % 2) for s in range(5)]
    q = TrialQueue(specs=specs)
    sched = TrialScheduler(q, max_lanes=2)
    sched.drain()
    st_ = sched.stats
    assert (st_.admitted, st_.retired) == (5, 5)
    assert [k for k, _ in st_.admission_log] == [s.key() for s in specs]
    assert st_.steps > 0
    assert 0.0 < st_.mean_occupancy <= 1.0
    assert sched.pool.n_live == 0 and not q


def test_serve_kill_and_resume(tmp_path):
    """Kill mid-drain (max_results), resume over the same store: nothing
    reruns, the union covers the grid, store keys stay unique."""
    store = ResultStore(str(tmp_path / "serve.jsonl"))
    specs = [tiny_spec(seed=s, rounds=1 + s % 2) for s in range(5)]
    first = serve(specs, max_lanes=2, store=store, max_results=2)
    # soft limit: the step that crosses it may retire one per live lane
    assert 2 <= len(first) < 5
    done = {r.spec.key() for r in first}
    second = serve(specs, max_lanes=2, store=store)
    assert {r.spec.key() for r in second} == {s.key() for s in specs} - done
    keys = [r["key"] for r in store.load()]
    assert len(keys) == 5 and len(set(keys)) == 5
    # and a third invocation is a no-op: everything is already done
    assert serve(specs, max_lanes=2, store=store) == []
