"""Roofline terms from a compiled (dry-run) executable.

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / (links * link_bw)

``cost_analysis()`` provides FLOPs and bytes of the *per-device* SPMD
program.  Collective bytes are NOT in cost_analysis, so we parse the
compiled HLO text and sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
weighting all-reduce x2 (ring = reduce-scatter + all-gather).
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.roofline.hardware import Chip, TPU_V5E

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# moved-bytes multiplier per op (ring algorithms, large-message asymptote)
_MULT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string like 'bf16[16,1024]' or a tuple."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]*?)\s*"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(",
    re.MULTILINE)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum moved bytes per collective kind from (post-SPMD) HLO text."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        if m.group(0).rstrip().endswith("-done("):
            continue  # avoid double counting start/done pairs
        out[op] += _shape_bytes(type_str) * _MULT[op]
        counts[op] += 1
    out["_counts"] = counts  # type: ignore
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    # per-device quantities
    flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, float] = field(default_factory=dict)
    # terms (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    # usefulness
    model_flops: float = 0.0           # 6 * N(active) * D tokens (global)
    useful_ratio: float = 0.0          # model_flops / (flops * n_devices)
    peak_memory_bytes: float = 0.0     # per-device from memory_analysis
    notes: str = ""

    def finalize(self, chip: Chip = TPU_V5E):
        self.t_compute = self.flops / chip.peak_flops_bf16
        self.t_memory = self.hbm_bytes / chip.hbm_bandwidth
        self.t_collective = self.coll_bytes / (
            chip.ici_links_per_chip * chip.ici_link_bandwidth)
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        if self.model_flops and self.flops:
            self.useful_ratio = self.model_flops / (self.flops * self.n_devices)
        return self

    def row(self) -> str:
        return (f"{self.arch:22s} {self.shape:12s} {self.mesh:9s} "
                f"comp={self.t_compute*1e3:9.3f}ms "
                f"mem={self.t_memory*1e3:9.3f}ms "
                f"coll={self.t_collective*1e3:9.3f}ms "
                f"-> {self.bottleneck:10s} useful={self.useful_ratio:6.1%} "
                f"peakmem={self.peak_memory_bytes/2**30:6.2f}GiB")

    def to_json(self) -> str:
        d = dict(self.__dict__)
        return json.dumps(d, indent=1, default=float)


def analyze_compiled(compiled, *, arch: str, shape: str, mesh: str,
                     n_devices: int, model_flops: float = 0.0,
                     chip: Chip = TPU_V5E,
                     hlo_text: Optional[str] = None) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        # older jaxlibs wrap the per-program cost dict in a singleton list
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    counts = coll.pop("_counts", {})
    total_coll = float(sum(coll.values()))
    mem = compiled.memory_analysis()
    peak = 0.0
    try:
        peak = float(mem.temp_size_in_bytes + mem.argument_size_in_bytes
                     + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    except (AttributeError, TypeError):
        # older jaxlibs expose a partial MemoryAnalysis surface; peak
        # memory is informational, so keep the report with peak=0
        pass
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh, n_devices=n_devices,
        flops=flops, hbm_bytes=hbm, coll_bytes=total_coll,
        coll_breakdown={**coll, "counts": counts},
        model_flops=model_flops, peak_memory_bytes=peak)
    return rep.finalize(chip)
