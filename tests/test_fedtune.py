"""Unit + property tests for the FedTune controller (paper Algorithm 1)."""

import math

import pytest

try:   # only the property test needs hypothesis; unit tests always run
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.costs import SystemCost
from repro.core.fedtune import FedTune, FedTuneConfig
from repro.core.preferences import PAPER_PREFERENCES, Preference
from repro.core.tuner import FixedTuner, HyperParams


def mk(pref=Preference(0.25, 0.25, 0.25, 0.25), **kw):
    return FedTune(FedTuneConfig(preference=pref, **kw), HyperParams(20, 20))


def cost(t=1.0, q=1.0, z=1.0, v=1.0):
    return SystemCost(comp_t=t, trans_t=q, comp_l=z, trans_l=v)


def test_paper_preferences_sum_to_one():
    assert len(PAPER_PREFERENCES) == 15
    for p in PAPER_PREFERENCES:
        assert math.isclose(sum(p.as_tuple()), 1.0, abs_tol=1e-6)


def test_no_decision_below_eps():
    tuner = mk()
    hp = HyperParams(20, 20)
    out = tuner.on_round(0, 0.005, cost(), cost(), hp)  # gain 0.005 < 0.01
    assert (out.m, out.e) == (20, 20)
    assert tuner.decisions == 0


def test_first_decision_probes_m_up():
    tuner = mk()
    hp = HyperParams(20, 20)
    out = tuner.on_round(0, 0.05, cost(), cost(), hp)
    assert tuner.decisions == 1
    assert out.m == 21 and out.e == 20


def test_steps_are_unit_without_adaptive():
    tuner = mk()
    hp = HyperParams(20, 20)
    for r in range(6):
        nxt = tuner.on_round(r, 0.05 * (r + 1), cost(t=1 + r), cost(), hp)
        assert abs(nxt.m - hp.m) <= 1 and abs(nxt.e - hp.e) <= 1
        hp = nxt


def test_comp_l_only_preference_pushes_m_and_e_down():
    # gamma=1: CompL prefers smaller M and smaller E (Table 3).
    tuner = mk(Preference(0.0, 0.0, 1.0, 0.0))
    hp = HyperParams(20, 20)
    acc = 0.0
    for r in range(30):
        acc += 0.02
        # CompL grows with hp.m and hp.e (structurally true in the system)
        c = cost(z=float(hp.m * hp.e))
        hp = tuner.on_round(r, acc, c, c, hp)
    assert hp.m < 20 and hp.e < 20


def test_trans_t_only_preference_pushes_m_and_e_up():
    tuner = mk(Preference(0.0, 1.0, 0.0, 0.0))
    hp = HyperParams(20, 20)
    acc = 0.0
    for r in range(30):
        acc += 0.02
        c = cost(q=100.0 / (hp.m * hp.e))  # TransT improves with bigger M,E
        hp = tuner.on_round(r, acc, c, c, hp)
    assert hp.m > 20 and hp.e > 20


def test_penalty_multiplies_opposing_slopes():
    tuner = mk(Preference(0.25, 0.25, 0.25, 0.25), penalty=10.0)
    hp = HyperParams(20, 20)
    acc = 0.0
    # two decisions establish history; third can be judged bad
    for r in range(8):
        acc += 0.02
        hp = tuner.on_round(r, acc, cost(t=1 + r * 10, q=1 + r * 10,
                                         z=1 + r * 10, v=1 + r * 10), cost(), hp)
    # slopes must stay positive and finite
    assert all(x >= 0 and math.isfinite(x) for x in tuner.eta + tuner.zeta)


def test_clamping_at_one():
    tuner = mk(Preference(0.0, 0.0, 1.0, 0.0), m_max=50, e_max=50)
    hp = HyperParams(1, 1)
    acc = 0.0
    for r in range(10):
        acc += 0.02
        hp = tuner.on_round(r, acc, cost(z=float(hp.m * hp.e)),
                            cost(), hp)
        assert hp.m >= 1 and hp.e >= 1


def test_fixed_tuner_never_changes():
    t = FixedTuner()
    hp = HyperParams(20, 20)
    assert t.on_round(0, 0.9, cost(), cost(), hp) is hp


# ---------------------------------------------------------------------------
# controller edge cases (PR 2 bugfixes)
# ---------------------------------------------------------------------------

def test_decision_triggers_at_exactly_eps():
    """Paper convention: a decision activates when the accuracy gain is
    >= eps, inclusive."""
    tuner = mk()   # eps = 0.01
    out = tuner.on_round(0, 0.01, cost(), cost(), HyperParams(20, 20))
    assert tuner.decisions == 1
    assert (out.m, out.e) == (21, 20)   # first decision probes M up


@pytest.mark.parametrize("adaptive", [False, True])
def test_delta_zero_holds_m_and_e(adaptive):
    """Delta == 0 (the only weighted overhead saw no change) is no evidence
    either way: the hyper-parameters must HOLD, not take a spurious
    down-step — in the plain and the adaptive-step branch alike."""
    tuner = mk(Preference(1.0, 0.0, 0.0, 0.0), adaptive_step=adaptive)
    hp = HyperParams(20, 20)
    hp = tuner.on_round(0, 0.05, cost(t=1.0), cost(), hp)   # probe: (21, 20)
    assert (hp.m, hp.e) == (21, 20)
    # same gain, same window overhead -> identical normalized window,
    # diff == 0 on the only weighted term -> Delta-M == Delta-E == 0
    out = tuner.on_round(1, 0.10, cost(t=1.0), cost(), hp)
    assert tuner.decisions == 2
    assert (out.m, out.e) == (21, 20)


def test_bad_move_penalizes_exactly_the_opposing_slopes():
    """A bad M-up move must multiply exactly the M-down-favoring slopes
    (CompL, TransL) by the penalty and leave every zeta untouched."""
    tuner = mk(penalty=10.0)
    hp = HyperParams(20, 20)
    hp = tuner.on_round(0, 0.05, cost(), cost(), hp)        # probe M up
    assert (hp.m, hp.e) == (21, 20)
    # every normalized overhead doubles -> comparison > 0 -> bad move
    tuner.on_round(1, 0.10, cost(2.0, 2.0, 2.0, 2.0), cost(), hp)
    assert tuner.trace[-1]["bad"]
    assert tuner.eta == [1.0, 1.0, 10.0, 10.0]
    assert tuner.zeta == [1.0, 1.0, 1.0, 1.0]   # E never moved


def test_weighted_relative_to_tolerates_zero_baseline():
    """Zero baseline overheads are legitimate (e.g. a compressed-upload run
    whose window accrues no transmission) and must not crash."""
    base = SystemCost(comp_t=1.0, trans_t=0.0, comp_l=1.0, trans_l=1.0)
    cur = SystemCost(comp_t=1.0, trans_t=1.0, comp_l=1.0, trans_l=1.0)
    out = cur.weighted_relative_to(base, Preference(0.25, 0.25, 0.25, 0.25))
    assert math.isfinite(out) and out > 0.0    # worse on the zero baseline
    # an all-zero unweighted baseline term contributes nothing
    pref = Preference(1.0, 0.0, 0.0, 0.0)
    assert cur.weighted_relative_to(base, pref) == 0.0


def test_unknown_compression_method_names_the_valid_ones():
    from repro.federated.compression import upload_factor
    with pytest.raises(ValueError, match="int8"):
        upload_factor("int4")
    assert upload_factor("int8") < 1.0
    assert upload_factor(None) == 1.0


if HAVE_HYPOTHESIS:
    @given(
        alpha=st.floats(0, 1), beta=st.floats(0, 1), gamma=st.floats(0, 1),
        gains=st.lists(st.floats(0.011, 0.2), min_size=1, max_size=20),
        costs=st.lists(st.tuples(*[st.floats(0.1, 1e6)] * 4),
                       min_size=20, max_size=20),
    )
    @settings(max_examples=40, deadline=None)
    def test_fedtune_invariants(alpha, beta, gamma, gains, costs):
        """Property: under arbitrary positive overhead streams, FedTune
        keeps M,E within bounds, steps by at most 1, and never produces
        NaN slopes."""
        total = alpha + beta + gamma
        if total > 1.0:
            alpha, beta, gamma = (x / total for x in (alpha, beta, gamma))
            total = 1.0
        delta = max(0.0, 1.0 - total)
        pref = Preference(alpha, beta, gamma, delta)
        tuner = FedTune(FedTuneConfig(preference=pref, m_max=100, e_max=100),
                        HyperParams(20, 20))
        hp = HyperParams(20, 20)
        acc = 0.0
        for r, (t, q, z, v) in enumerate(costs):
            acc += gains[r % len(gains)]
            nxt = tuner.on_round(r, acc, cost(t, q, z, v), cost(), hp)
            assert 1 <= nxt.m <= 100 and 1 <= nxt.e <= 100
            assert abs(nxt.m - hp.m) <= 1 and abs(nxt.e - hp.e) <= 1
            hp = nxt
        for x in tuner.eta + tuner.zeta:
            assert math.isfinite(x) and x >= 0
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fedtune_invariants():
        pass
