"""command-r-35b — 40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
No biases anywhere.  [hf:CohereForAI/c4ai-command-r-v01]"""

from repro.configs.base import ModelConfig, uniform_layers

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22_528,
    vocab_size=256_000,
    layers=uniform_layers(40),
    qkv_bias=False,
    rope_theta=8_000_000.0,
    tie_embeddings=True,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
