"""Serving launcher: prefill + batched decode of an FL-trained model on the
host devices (reduced arch).  The 256/512-chip serve_step is exercised by
launch/dryrun.py; this driver RUNS the same code path end-to-end.

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-350m --tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    from repro.configs import get_config, reduced
    from repro.models import build_model

    cfg = reduced(get_config(args.arch), n_layers=4)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    b, s = args.batch, args.prompt_len
    prompt = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    fe = None
    p_len = 0
    if cfg.frontend is not None:
        fe = jax.random.normal(key, (b, cfg.frontend.seq_len,
                                     cfg.frontend.feature_dim))
        if cfg.frontend.kind == "vision_patches":
            p_len = cfg.frontend.seq_len

    cache = model.init_cache(b, max_len=p_len + s + args.tokens + 1)
    t0 = time.perf_counter()
    logits, cache = model.prefill(params, prompt, cache, frontend=fe,
                                  use_kernel=False)
    print(f"prefill: {b}x{s} in {time.perf_counter() - t0:.2f}s")

    step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, axis=-1)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.tokens):
        pos = jnp.int32(p_len + s + i)
        logits, cache = step(params, tok, pos, cache)
        tok = jnp.argmax(logits, axis=-1)
        out.append(tok)
    dt = time.perf_counter() - t0
    print(f"decoded {args.tokens} tokens x batch {b} in {dt:.2f}s "
          f"({args.tokens * b / dt:.1f} tok/s)")
    print("sampled ids[0]:", [int(t[0]) for t in out])


if __name__ == "__main__":
    main()
