"""Trial-serving daemon: drain an open-ended queue of tuning trials at
sustained lane occupancy (continuous batching over the sweep engines).

Where ``repro.launch.sweep`` packs a FIXED grid and lets lanes idle as
trials finish, this launcher runs the continuous-batching scheduler
(repro.experiments.scheduler): a ``LanePool`` of ``--max-lanes`` lanes, a
``TrialQueue`` seeded from a grid/preset and/or fed live from a watched
JSONL submissions file, retiring each lane the moment its trial reaches
target and admitting the next queued trial into the freed slot
mid-flight.  Every result is bit-identical to an independent
``FLServer.run()`` and streams to the JSONL result store as it retires,
so a killed daemon resumes past completed keys.

Usage:
  # write the 12-trial smoke queue into a submissions file (the submit side)
  PYTHONPATH=src python -m repro.launch.serve_trials \
      --preset serve-smoke --submit serve_subs.jsonl

  # drain it with 4 lanes; kill mid-drain with --limit, re-invoke to resume
  PYTHONPATH=src python -m repro.launch.serve_trials \
      --watch serve_subs.jsonl --max-lanes 4 --limit 6 --out runs/serve.jsonl
  PYTHONPATH=src python -m repro.launch.serve_trials \
      --watch serve_subs.jsonl --max-lanes 4 --out runs/serve.jsonl --trace

  # daemon mode: keep polling the submissions file after the queue drains
  # (any writer may append spec lines at any time); Ctrl-C to stop
  PYTHONPATH=src python -m repro.launch.serve_trials \
      --watch serve_subs.jsonl --daemon --max-lanes 8 --out runs/serve.jsonl

A submissions line is a ``TrialSpec.to_dict()`` JSON object (or any record
with a ``"spec"`` field — result-store rows can be piped back in);
malformed lines are skipped with a warning, half-written tails are retried
on the next poll.
"""

from __future__ import annotations

import argparse
import json
import time


def serve_smoke_specs(failure_rate: float = 0.0, churn: str | None = None):
    """The CI serve-smoke queue: 12 tiny trials whose round budgets are
    staggered (1..3) across sync, async, and buffered modes, so lanes
    retire at different times — exactly the drain shape continuous
    batching exists for (a fixed pack would idle up to 2/3 of its lanes
    by the last round).  ``failure_rate``/``churn`` perturb every trial
    with the fleet fault model (the chaos-smoke CI job serves the same
    queue at 10% failures with churn)."""
    from repro.experiments import TrialSpec
    specs = []
    for i in range(6):
        specs.append(TrialSpec(
            dataset="emnist", aggregator="fedavg", seed=i, tuner="fedtune",
            m0=3, e0=1.0, rounds=1 + i % 3, target_accuracy=0.99,
            batch_size=5, eval_points=128, mode="sync",
            failure_rate=failure_rate, churn=churn))
    for i in range(6):
        specs.append(TrialSpec(
            dataset="emnist", aggregator="fedavg", seed=i, tuner="fedtune",
            m0=3, e0=1.0, rounds=1 + i % 3, target_accuracy=0.99,
            batch_size=5, eval_points=128,
            mode="async" if i % 2 == 0 else "buffered",
            failure_rate=failure_rate, churn=churn))
    return specs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default=None, choices=("serve-smoke",),
                    help="named queue (serve-smoke = 12 staggered-budget "
                         "trials across sync/async/buffered)")
    ap.add_argument("--watch", default=None, metavar="PATH",
                    help="JSONL submissions file to poll for new trials "
                         "(one spec object per line, append-only)")
    ap.add_argument("--submit", default=None, metavar="PATH",
                    help="write the preset/grid specs as submission lines "
                         "to PATH and exit (the producer side of --watch)")
    ap.add_argument("--max-lanes", type=int, default=4,
                    help="lane pool capacity (concurrently live trials)")
    ap.add_argument("--pack", default="batched",
                    choices=("batched", "sharded"),
                    help="sync cohort packing (event trials pack batched)")
    ap.add_argument("--out", default="runs/serve.jsonl",
                    help="JSONL result store (resume key source)")
    ap.add_argument("--no-resume", action="store_true",
                    help="truncate the store instead of skipping "
                         "completed trial keys")
    ap.add_argument("--limit", type=int, default=0,
                    help="stop draining once N trials have retired this "
                         "invocation (0 = drain fully; the crossing step "
                         "may retire a few extra) — simulates a killed "
                         "daemon")
    ap.add_argument("--daemon", action="store_true",
                    help="after draining, keep polling --watch for new "
                         "submissions instead of exiting")
    ap.add_argument("--poll-seconds", type=float, default=1.0,
                    help="daemon-mode sleep between idle polls")
    ap.add_argument("--trace", nargs="?", const="auto", default=None,
                    metavar="PATH",
                    help="record a dual-clock trace (Chrome trace-event "
                         "JSON + metrics JSONL, paths derived from --out) "
                         "— shows the admit/retire drain and the "
                         "pool_occupancy gauge; bit-parity-neutral")
    ap.add_argument("--failure-rate", type=float, default=0.0,
                    metavar="P",
                    help="per-dispatch hard-failure hazard applied to "
                         "preset specs (coordinator retries/reassigns; "
                         "0 = fault-free)")
    ap.add_argument("--churn", default=None, metavar="SPEC",
                    help="fleet churn schedule 'period:rate[:min_active]' "
                         "applied to preset specs")
    ap.add_argument("--snapshot", nargs="?", const="auto", default=None,
                    metavar="PATH",
                    help="arm crash-safe boundary snapshots (two-slot, "
                         "torn-write tolerant; PATH defaults to "
                         "<out>.snap).  If a valid snapshot exists the "
                         "daemon RESUMES from it, replaying at most one "
                         "macro-step with duplicate store rows suppressed")
    ap.add_argument("--snapshot-every", type=int, default=1, metavar="N",
                    help="snapshot every N macro-steps (1 = every step)")
    ap.add_argument("--kill-after-steps", type=int, default=0, metavar="K",
                    help="exit abruptly (code 3, NO final snapshot) after "
                         "K macro-steps — the chaos-smoke crash injector")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    from repro.experiments import ResultStore
    from repro.experiments.scheduler import TrialQueue, TrialScheduler

    specs = (serve_smoke_specs(args.failure_rate, args.churn)
             if args.preset == "serve-smoke" else [])
    if not specs and not args.watch:
        ap.error("nothing to serve: give --preset and/or --watch "
                 "(or --submit to produce a submissions file)")

    if args.submit:
        with open(args.submit, "a") as f:
            for s in specs:
                f.write(json.dumps({"spec": s.to_dict()}) + "\n")
        print(f"serve: submitted {len(specs)} spec(s) -> {args.submit}",
              flush=True)
        return

    store = ResultStore(args.out)
    if args.no_resume:
        store.clear()
    snap_path = None
    if args.snapshot is not None:
        snap_path = (args.out + ".snap" if args.snapshot == "auto"
                     else args.snapshot)

    if args.trace is not None:
        from repro import obs
        obs.enable()

    sched = None
    if snap_path is not None and not args.no_resume:
        try:
            sched = TrialScheduler.restore(
                snap_path, store=store, pack=args.pack,
                watch_path=args.watch, verbose=args.verbose,
                snapshot_every=args.snapshot_every)
        except FileNotFoundError:
            pass           # no valid slot yet: cold start below
    if sched is not None:
        # resume: the snapshot's queue/lane/trial state is authoritative;
        # preset specs are re-offered (deduped against its seen/done sets)
        # and the store's completed keys merged for duplicate suppression
        for k in store.completed_keys():
            sched.queue.mark_done(k)
        for s in specs:
            sched.queue.submit(s)
        print(f"serve: resumed from {snap_path} at macro-step "
              f"{sched.stats.steps} ({sched.pool.n_live} live trial(s), "
              f"{len(sched.queue)} queued)", flush=True)
    else:
        queue = TrialQueue(specs=specs, watch_path=args.watch,
                           completed=store.completed_keys())
        queue.poll()
        print(f"serve: {queue.n_submitted} trial(s) queued; resume: "
              f"skipping {queue.n_skipped} completed/duplicate", flush=True)
        sched = TrialScheduler(queue, max_lanes=args.max_lanes, store=store,
                               pack=args.pack, verbose=args.verbose,
                               snapshot_path=snap_path,
                               snapshot_every=args.snapshot_every)
    t0 = time.perf_counter()
    try:
        while True:
            steps_before = sched.stats.steps
            sched.drain(max_results=args.limit or None,
                        max_steps=args.kill_after_steps or None)
            if (args.kill_after_steps and sched.stats.steps - steps_before
                    >= args.kill_after_steps):
                print(f"serve: simulated crash after "
                      f"{args.kill_after_steps} macro-step(s); re-invoke "
                      f"with --snapshot to resume from the last boundary",
                      flush=True)
                raise SystemExit(3)
            if not args.daemon or (args.limit
                                   and sched.stats.retired >= args.limit):
                break
            time.sleep(args.poll_seconds)
    except KeyboardInterrupt:
        print("serve: interrupted; store is resumable", flush=True)
    wall = time.perf_counter() - t0

    for res in sched.results:
        print(f"  done {res.spec.key()}  acc={res.final_accuracy:.3f} "
              f"rounds={res.rounds} engine={res.engine}", flush=True)
    st = sched.stats
    dupes = (f"; {sched.duplicates_suppressed} replayed row(s) suppressed"
             if sched.duplicates_suppressed else "")
    print(f"serve: retired {st.retired} trial(s) in {wall:.1f}s over "
          f"{st.steps} step(s); mean occupancy={st.mean_occupancy:.2f} "
          f"({sched.pool.capacity} lanes); store={args.out}{dupes}",
          flush=True)

    if args.trace is not None:
        from repro import obs
        from repro.obs.export import (trace_paths_for, write_chrome_trace,
                                      write_metrics_jsonl)
        obs.disable()
        trace_path, metrics_path = trace_paths_for(
            args.out, None if args.trace == "auto" else args.trace)
        write_chrome_trace(trace_path)
        n_rows = write_metrics_jsonl(metrics_path)
        print(f"serve: trace -> {trace_path} ({len(obs.tracer.spans)} "
              f"spans); metrics -> {metrics_path} ({n_rows} rows) — open "
              "the trace at https://ui.perfetto.dev", flush=True)


if __name__ == "__main__":
    main()
