"""granite-moe-1b-a400m — 24L d_model=1024 16H (GQA kv=8) d_ff=512/expert,
vocab=49155, MoE 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]"""

from repro.configs.base import FFN_MOE, ModelConfig, MoEConfig, uniform_layers

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    layers=uniform_layers(24, ffn=FFN_MOE),
    moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512),
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
