"""FL training launcher.

Two modes:
  * ``simulate`` (default) — the paper's experiment: host-level FL over the
    synthetic federated datasets with FedTune, small models, CPU-friendly.
  * ``mesh`` — the datacenter path: run ``fl_train_step`` (the dry-run
    artifact) on whatever devices exist, reduced arch.

Usage:
  PYTHONPATH=src python -m repro.launch.train --dataset emnist \
      --preference 0.25,0.25,0.25,0.25 --rounds 100 [--fedtune]
  PYTHONPATH=src python -m repro.launch.train --runtime buffered \
      --het stragglers --buffer-k 8 --fedtune
  PYTHONPATH=src python -m repro.launch.train --mode mesh --arch gemma2-2b

``--runtime`` picks the execution mode of the event-driven runtime
(sync = deadline rounds, async = FedAsync staleness weighting, buffered =
FedBuff K-update aggregation); ``--het`` samples a device fleet from a
named heterogeneity profile (homogeneous | mild | stragglers | mobile);
``--client-exec`` picks the sync-mode client-execution backend
(sequential | batched | sharded — sharded lays the cohort over a
``clients`` mesh axis and needs >1 device, e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""

from __future__ import annotations

import argparse

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("simulate", "mesh"), default="simulate")
    ap.add_argument("--dataset", default="emnist",
                    choices=("speech_command", "emnist", "cifar100"))
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--preference", default="0.25,0.25,0.25,0.25")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--target", type=float, default=0.5)
    ap.add_argument("--m", type=int, default=5)
    ap.add_argument("--e", type=float, default=2.0)
    ap.add_argument("--aggregator", default="fedavg")
    ap.add_argument("--fedtune", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--runtime", choices=("sync", "async", "buffered"),
                    default="sync")
    ap.add_argument("--het", default="homogeneous",
                    help="heterogeneity profile (homogeneous | mild | "
                         "stragglers | mobile)")
    ap.add_argument("--selection", default="random",
                    choices=("random", "guided", "smallest", "deadline"))
    ap.add_argument("--deadline-quantile", type=float, default=1.0,
                    help="sync: cut stragglers above this completion "
                         "quantile")
    ap.add_argument("--buffer-k", type=int, default=8,
                    help="buffered: updates aggregated per flush")
    ap.add_argument("--staleness-alpha", type=float, default=0.5)
    ap.add_argument("--batched", action="store_true",
                    help="deprecated alias for --client-exec batched")
    ap.add_argument("--client-exec", default=None,
                    choices=("sequential", "batched", "sharded"),
                    help="sync-mode client execution backend: sequential "
                         "per-client loop, batched vmapped cohort, or "
                         "sharded clients-as-mesh-axis (multi-device; on "
                         "CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--trace", nargs="?", const="runs/train.trace.json",
                    default=None, metavar="PATH",
                    help="record a dual-clock trace of the run: Chrome "
                         "trace-event JSON (open in Perfetto) plus a "
                         "metrics JSONL next to it; bit-parity-neutral")
    ap.add_argument("--trace-jax", action="store_true",
                    help="with --trace: also open jax.profiler trace "
                         "annotations per span")
    args = ap.parse_args()

    if args.mode == "mesh":
        from examples import distributed_fl  # same path, shared driver
        import sys
        sys.argv = ["distributed_fl", "--arch", args.arch]
        distributed_fl.main()
        return

    from repro.configs.paper_models import MLPConfig
    from repro.core import CostModel, FedTune, FedTuneConfig, Preference
    from repro.core.tuner import HyperParams
    from repro.data import (cifar100_like, emnist_like, speech_command_like)
    from repro.federated import FLConfig, FLServer, get_aggregator
    from repro.models import build_model
    from repro.optim.optimizers import get_optimizer

    ds_fns = {"speech_command": speech_command_like, "emnist": emnist_like,
              "cifar100": cifar100_like}
    dataset = ds_fns[args.dataset](reduced=not args.full)
    in_dim = int(__import__("numpy").prod(dataset.spec.shape))
    model = build_model(MLPConfig(name="mlp", in_dim=in_dim, hidden=(48,),
                                  n_classes=dataset.spec.n_classes))
    n_params = sum(p.size for p in jax.tree.leaves(
        model.init(jax.random.PRNGKey(0))))

    a, b, g, d = (float(x) for x in args.preference.split(","))
    pref = Preference(a, b, g, d)
    tuner = (FedTune(FedTuneConfig(preference=pref),
                     HyperParams(args.m, args.e)) if args.fedtune else None)
    from repro.runtime import RuntimeConfig, sample_fleet
    fleet = (None if args.het == "homogeneous"
             else sample_fleet(args.het, dataset.n_clients, seed=0))
    rtcfg = RuntimeConfig(
        mode=args.runtime, deadline_quantile=args.deadline_quantile,
        buffer_k=args.buffer_k, staleness_alpha=args.staleness_alpha,
        client_exec=args.client_exec or
        ("batched" if args.batched else "sequential"))
    server = FLServer(
        model, dataset, get_aggregator(args.aggregator),
        get_optimizer("sgd", 0.03, momentum=0.9),
        CostModel(flops_per_example=2 * n_params, param_count=n_params),
        FLConfig(m=args.m, e=args.e, batch_size=10,
                 target_accuracy=args.target, max_rounds=args.rounds,
                 log_every=max(args.rounds // 20, 1),
                 selection=args.selection),
        tuner=tuner, fleet=fleet, runtime_config=rtcfg)
    if args.trace is not None:
        from repro import obs
        obs.enable(jax_annotations=args.trace_jax)
    res = server.run()
    if args.trace is not None:
        from repro import obs
        from repro.obs.export import (trace_paths_for, write_chrome_trace,
                                      write_metrics_jsonl)
        obs.disable()
        trace_path, metrics_path = trace_paths_for("", args.trace)
        write_chrome_trace(trace_path)
        write_metrics_jsonl(metrics_path)
        print(f"trace -> {trace_path}; metrics -> {metrics_path} — open "
              "the trace at https://ui.perfetto.dev", flush=True)
    c = res.total_cost
    print(f"\ndone: rounds={res.rounds} acc={res.final_accuracy:.3f} "
          f"M={res.final_m} E={res.final_e:g} t_sim={res.sim_time:.4g}")
    print(f"CompT={c.comp_t:.4g} TransT={c.trans_t:.4g} "
          f"CompL={c.comp_l:.4g} TransL={c.trans_l:.4g}")
    if args.checkpoint:
        from repro.checkpoint import save_checkpoint
        # final params come back in FLResult; checkpoint them with the
        # run's scalar summary as metadata
        save_checkpoint(args.checkpoint, res.params, step=res.rounds,
                        metadata={
                            "final_accuracy": res.final_accuracy,
                            "costs": list(c.as_tuple()),
                            "runtime": args.runtime,
                            "het": args.het,
                            "sim_time": res.sim_time,
                        })
        print(f"checkpoint written to {args.checkpoint}")


if __name__ == "__main__":
    main()
