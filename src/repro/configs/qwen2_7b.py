"""qwen2-7b — 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
GQA with QKV bias.  [arXiv:2407.10671]"""

from repro.configs.base import ModelConfig, uniform_layers

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18_944,
    vocab_size=152_064,
    layers=uniform_layers(28),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="arXiv:2407.10671",
)
