"""Benchmark: client-execution backends (sequential | batched | sharded).

Per-round wall-clock of one sync cohort's local training + FedAvg
aggregation at M in {16, 64, 256}, for each backend:

  sequential — one jitted micro-step loop per client (federated/client.py)
  batched    — whole cohort vmapped on one device (runtime/batched.py)
  sharded    — cohort laid over a ``clients`` mesh axis with on-device
               psum aggregation (runtime/sharded.py); skipped (emitted as
               such) when only one device exists

The sharded rows only mean anything on a multi-device mesh; on a CPU host

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src:. python -m benchmarks.run --only sharded_cohort

splits the host into 8 XLA devices.  The sequential baseline is timed once
at M=256 regardless of reps (its dispatch overhead is the thing being
beaten; reps would only restate it).

Usage: PYTHONPATH=src python benchmarks/sharded_cohort.py [--reps N]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.paper_models import MLPConfig
from repro.data.synthetic import DataSpec, make_dataset
from repro.federated import get_aggregator
from repro.federated.client import local_train
from repro.models import build_model
from repro.optim.optimizers import get_optimizer
from repro.runtime.batched import batched_local_train
from repro.runtime.sharded import sharded_fedavg_train

COHORTS = (16, 64, 256)


def _dataset(n_clients: int):
    return make_dataset(DataSpec(
        name="shard_bench", n_classes=8, shape=(32,),
        n_train_clients=n_clients, n_test_clients=8,
        size_log_mean=2.3, size_log_std=0.4, seed=0))


def _round_seq(model, params, data, opt, fedavg, bs):
    rng = np.random.default_rng(0)
    ups = [local_train(model, params, x, y, passes=1.0, batch_size=bs,
                       optimizer=opt, rng=rng) for x, y in data]
    return fedavg(params, ups)


def _round_batched(model, params, data, opt, fedavg, bs):
    ups = batched_local_train(model, params, data, passes=1.0,
                              batch_size=bs, optimizer=opt,
                              rng=np.random.default_rng(0))
    return fedavg(params, ups)


def _round_sharded(model, params, data, opt, fedavg, bs):
    del fedavg  # aggregation is fused on device
    return sharded_fedavg_train(model, params, data, passes=1.0,
                                batch_size=bs, optimizer=opt,
                                rng=np.random.default_rng(0)).params


def main(settings=None, *, reps: int = 3):
    del settings  # reduced scale only; the sweep is over M, not data size
    n_dev = jax.device_count()
    ds = _dataset(max(COHORTS))
    model = build_model(MLPConfig(name="mlp_shard", in_dim=32, hidden=(48,),
                                  n_classes=8))
    opt = get_optimizer("sgd", 0.03, momentum=0.9)
    fedavg = get_aggregator("fedavg")
    params = model.init(jax.random.PRNGKey(0))
    bs = 8
    print(f"# client-execution backends over {n_dev} device(s)")
    backends = [("seq", _round_seq), ("batched", _round_batched)]
    if n_dev > 1:
        backends.append(("sharded", _round_sharded))
    else:
        emit("sharded_cohort/sharded", 0.0,
             "skipped: single device (set XLA_FLAGS="
             "--xla_force_host_platform_device_count=8)")
    for m in COHORTS:
        data = [ds.client_data(c) for c in range(m)]
        times = {}
        for name, fn in backends:
            # the sequential micro-step jit is shape-independent of M, so
            # it only needs warming once; batched/sharded compile per
            # bucketed (T, M) shape and need a warm pass at every M
            if name != "seq" or m == COHORTS[0]:
                fn(model, params, data, opt, fedavg, bs)
            r = 1 if (name == "seq" and m >= 256) else reps
            t0 = time.perf_counter()
            for _ in range(r):
                fn(model, params, data, opt, fedavg, bs)
            times[name] = (time.perf_counter() - t0) / r
        base = times["seq"]
        for name, t in times.items():
            emit(f"sharded_cohort/{name}_m{m}", t * 1e6,
                 f"speedup_vs_seq={base / t:.2f}x")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()
    main(reps=args.reps)
