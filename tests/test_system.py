"""End-to-end behaviour tests: FL training with fixed hyper-parameters and
with FedTune, on the synthetic federated datasets (the paper's pipeline)."""

import jax
import numpy as np
import pytest

from repro.configs.paper_models import MLPConfig
from repro.core import CostModel, FedTune, FedTuneConfig, Preference
from repro.core.tuner import HyperParams
from repro.data import emnist_like
from repro.federated import FLConfig, FLServer, get_aggregator
from repro.models import build_model
from repro.optim.optimizers import get_optimizer


def _setup(max_rounds=25, tuner=None, aggregator="fedavg", m=5, e=1.0,
           seed=0, prox_mu=0.0):
    ds = emnist_like(reduced=True, seed=seed)
    cfg = MLPConfig(name="mlp_t", in_dim=28 * 28, hidden=(32,), n_classes=16)
    model = build_model(cfg)
    n_params = sum(p.size for p in jax.tree.leaves(
        model.init(jax.random.PRNGKey(0))))
    cm = CostModel(flops_per_example=2 * n_params, param_count=n_params)
    server = FLServer(
        model, ds, get_aggregator(aggregator),
        get_optimizer("sgd", 0.05, momentum=0.9), cm,
        FLConfig(m=m, e=e, batch_size=10, target_accuracy=0.95,
                 max_rounds=max_rounds, eval_points=512, seed=seed,
                 prox_mu=prox_mu),
        tuner=tuner)
    return server


def test_fl_training_improves_accuracy():
    server = _setup(max_rounds=25)
    res = server.run()
    assert res.rounds == 25
    first = np.mean([h.accuracy for h in res.history[:5]])
    last = np.mean([h.accuracy for h in res.history[-5:]])
    assert last > first + 0.05, (first, last)
    assert res.total_cost.comp_l > 0 and res.total_cost.trans_l > 0


@pytest.mark.parametrize("aggregator", ["fednova", "fedadagrad", "fedprox"])
def test_aggregators_train(aggregator):
    server = _setup(max_rounds=10, aggregator=aggregator,
                    prox_mu=0.01 if aggregator == "fedprox" else 0.0)
    res = server.run()
    assert np.isfinite(res.final_accuracy)
    assert res.final_accuracy > 1.0 / 16  # beats chance


def test_fedtune_adjusts_hyperparameters():
    tuner = FedTune(
        FedTuneConfig(preference=Preference(0.0, 0.0, 1.0, 0.0)),
        HyperParams(5, 2))
    server = _setup(max_rounds=30, tuner=tuner, m=5, e=2.0)
    res = server.run()
    assert tuner.decisions >= 2
    # gamma=1 (CompL-only): FedTune should not grow both knobs
    assert not (res.final_m > 5 and res.final_e > 2)
    ms = {h.m for h in res.history}
    es = {h.e for h in res.history}
    assert len(ms) > 1 or len(es) > 1, "hyper-parameters never moved"


def test_round_costs_follow_current_hyperparams():
    server = _setup(max_rounds=8, m=3, e=1.0)
    res = server.run()
    for rec in res.history:
        assert rec.cost.trans_l == server.cost_model.param_count * rec.m


def test_fractional_passes_supported():
    server = _setup(max_rounds=4, e=0.5)  # paper's E=0.5: half the data
    res = server.run()
    assert res.rounds == 4
    assert np.isfinite(res.final_accuracy)
