from repro.data.synthetic import (FederatedDataset, make_dataset,
                                  speech_command_like, emnist_like,
                                  cifar100_like)
from repro.data.loader import client_batches

__all__ = ["FederatedDataset", "make_dataset", "speech_command_like",
           "emnist_like", "cifar100_like", "client_batches"]
