"""Observability subsystem: dual-clock span tracing + metrics.

The paper's whole argument is that hyper-parameter decisions must be
driven by *measured* system overhead — so the sweep engine needs to be
measurable itself.  This package provides:

  ``trace``   — a span tracer recording dual clocks per span (virtual
                simulation time from the event runtime's clock AND host
                wall-clock), attributed to trial/lane/round/phase.
  ``metrics`` — a registry of counters/gauges/histograms/series (lane
                occupancy, pack widths, pow2-padding waste, staleness,
                dropout/straggler counts, cache hit rates) that also backs
                the ``repro.perf`` phase-timer shim.
  ``export``  — Chrome trace-event JSON (loadable in Perfetto: one track
                per trial lane on both clocks), a metrics JSONL stream,
                and the checked-in trace-schema validator.

Contract: tracing is **zero-cost when disabled** (every instrumentation
site either checks ``obs.enabled()`` or goes through ``obs.span``, which
returns a shared no-op context manager when the tracer is off) and
**bit-parity-neutral when enabled** — spans and metrics only read clocks
and counts, never an rng or a float that feeds training.  Both halves are
pinned in tests/test_obs.py.

Typical wiring (what ``launch/sweep.py --trace`` does):

    from repro import obs
    obs.enable()                       # optionally jax_annotations=True
    ... run the sweep ...
    from repro.obs.export import write_chrome_trace, write_metrics_jsonl
    write_chrome_trace("out.trace.json")
    write_metrics_jsonl("out.metrics.jsonl")
"""

from __future__ import annotations

from repro.obs import metrics
from repro.obs.metrics import registry
from repro.obs.trace import NULL_SPAN, Span, Tracer, traced, tracer


def enabled() -> bool:
    """Is the process-wide tracer on?  Instrumentation sites in hot loops
    gate on this before building span/metric arguments."""
    return tracer.enabled


def enable(jax_annotations: bool = False, reset: bool = True):
    """Turn tracing + metric collection on.  ``jax_annotations=True``
    additionally opens a ``jax.profiler.TraceAnnotation`` per span so a
    device profile taken alongside lines up with our spans."""
    tracer.enable(jax_annotations=jax_annotations, reset=reset)


def disable():
    tracer.disable()


def span(name: str, **kw):
    """Context-managed span (see ``Tracer.span``); a shared no-op when
    tracing is disabled."""
    return tracer.span(name, **kw)


def record(name: str, **kw):
    """Record an already-bounded span retroactively (e.g. a virtual-time
    window known only after the clock advanced); no-op when disabled."""
    tracer.record(name, **kw)


def counter(name: str, value):
    """Sample a wall-clock-stamped counter track value (e.g. ``t_sim``);
    no-op when disabled."""
    tracer.counter(name, value)
