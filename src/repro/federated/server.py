"""FL server: round orchestration, participant selection, cost accounting,
evaluation, and the tuner hook (FedTune plugs in here).

This is the *simulation* loop used for the paper's experiments (small
models, CPU).  Since the event-driven runtime landed (repro.runtime), the
server is a thin facade: ``run()`` hands orchestration to the runtime engine
(sync / async / buffered execution over a device fleet), and the original
synchronous-homogeneous loop survives as ``run_legacy()`` — the runtime's
sync mode over a homogeneous fleet reproduces it round for round, which
``tests/test_runtime.py`` pins down.

The datacenter execution path — participants as mesh shards with psum
aggregation — lives in launch/train.py and is what the multi-pod dry-run
lowers.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

import jax
import numpy as np

from repro.core.costs import CostModel, SystemCost
from repro.core.tuner import HyperParams, Tuner
from repro.data.synthetic import FederatedDataset
from repro.federated.aggregation import Aggregator, ClientUpdate
from repro.federated.client import local_train
from repro.federated.evaluation import eval_due
from repro.models.registry import Model
from repro.optim.optimizers import Optimizer


@dataclass
class FLConfig:
    m: int = 20                    # initial participants per round
    e: float = 20.0                # initial local passes
    batch_size: int = 5
    target_accuracy: float = 0.8
    max_rounds: int = 500
    eval_points: int = 1024
    prox_mu: float = 0.0
    seed: int = 0
    eval_every: int = 1
    log_every: int = 0             # 0 = silent
    selection: str = "random"      # random | guided | smallest | deadline
    compression: Optional[str] = None  # None | "int8" upload deltas


@dataclass
class RoundRecord:
    round_idx: int
    m: int
    e: float
    accuracy: float
    cost: SystemCost
    wall_time: float
    sim_time: float = 0.0          # virtual clock at the end of the round
    n_updates: int = -1            # arrivals aggregated (-1 = legacy loop)


@dataclass
class FLResult:
    reached_target: bool
    rounds: int
    final_accuracy: float
    total_cost: SystemCost
    history: List[RoundRecord]
    final_m: int
    final_e: float
    params: Any = None             # final global model parameters
    sim_time: float = 0.0          # total virtual wall-clock (runtime modes)
    dispatch_log: Optional[List[tuple]] = None   # async/buffered: every
                                   # dispatch as (virtual t, cid, version)
    staleness_log: Optional[List[int]] = None    # async/buffered: staleness
                                   # of each applied (non-dropout) arrival


class FLServer:
    def __init__(self, model: Model, dataset: FederatedDataset,
                 aggregator: Aggregator, optimizer: Optimizer,
                 cost_model: CostModel, config: FLConfig,
                 tuner: Optional[Tuner] = None,
                 fleet=None, runtime_config=None):
        self.model = model
        self.dataset = dataset
        self.aggregator = aggregator
        self.optimizer = optimizer
        self.cost_model = cost_model
        self.config = config
        self.tuner = tuner or Tuner()
        self.rng = np.random.default_rng(config.seed)
        self._evaluator = None
        self.fleet = fleet
        self.runtime_config = runtime_config
        from repro.federated.selection import get_selector
        est_times = None
        if fleet is not None:
            # deadline-aware selection signal: expected dispatch->arrival
            # time per client (download + E passes of compute + upload)
            from repro.federated.compression import upload_factor
            c1 = cost_model.train_flops_per_example
            down, up = cost_model.traffic_halves(
                upload_factor(config.compression))
            # one vectorized pass (bit-identical per element to the scalar
            # est_round_time loop it replaced; works for VirtualFleet too,
            # where per-cid scalar indexing would draw one hash at a time)
            est_times = np.asarray(fleet.est_round_times(
                np.arange(dataset.n_clients),
                np.asarray(dataset.client_sizes, np.float64),
                config.e, c1, down, up))
        self.selector = get_selector(config.selection, dataset.n_clients,
                                     self.rng,
                                     client_sizes=dataset.client_sizes,
                                     est_times=est_times)

    # ------------------------------------------------------------------
    @property
    def evaluator(self):
        """This trial's ``Evaluator`` (federated/evaluation.py): the jitted
        accuracy kernel comes from the shared bounded LRU and the test
        batches from the per-dataset staging cache, so the T servers of a
        sweep share one compilation and one on-device test set."""
        if self._evaluator is None:
            from repro.federated.evaluation import Evaluator
            self._evaluator = Evaluator(self.model, self.dataset,
                                        self.config.eval_points)
        return self._evaluator

    def _evaluate(self, params) -> float:
        return self.evaluator.evaluate(params)

    # ------------------------------------------------------------------
    def _client_update(self, params, cid: int, e: float
                       ) -> Tuple[ClientUpdate, int]:
        """Run one client's local training against ``params``.  Shared by the
        legacy loop and the event-driven runtime so both consume the server
        rng stream identically (batch permutations)."""
        from repro import perf
        cfg = self.config
        x, y = self.dataset.client_data(int(cid))
        with perf.timed("train"):
            upd = local_train(
                self.model, params, x, y, passes=e,
                batch_size=cfg.batch_size, optimizer=self.optimizer,
                rng=self.rng, prox_mu=cfg.prox_mu)
            if cfg.compression:
                from repro.federated.compression import compress_delta
                upd = upd._replace(params=compress_delta(
                    params, upd.params, cfg.compression))
        upd = upd._replace(client_id=int(cid))
        self.selector.update(int(cid), upd.last_loss, len(y))
        return upd, len(y)

    # ------------------------------------------------------------------
    def run(self, params=None) -> FLResult:
        """Execute FL through the event-driven runtime.  Mode and fleet come
        from ``runtime_config`` / ``fleet`` (defaults: sync execution over a
        homogeneous unit fleet == the legacy loop's behavior)."""
        from repro.runtime.engine import EventDrivenRuntime, RuntimeConfig
        rt = EventDrivenRuntime(self, fleet=self.fleet,
                                config=self.runtime_config or RuntimeConfig())
        return rt.run(params)

    # ------------------------------------------------------------------
    def run_legacy(self, params=None) -> FLResult:
        """The original synchronous, homogeneous round loop (paper setting).
        Kept as the reference the runtime's sync mode is verified against."""
        cfg = self.config
        if params is None:
            params = self.model.init(jax.random.PRNGKey(cfg.seed))
        hp = HyperParams(m=cfg.m, e=cfg.e)
        history: List[RoundRecord] = []
        accuracy = 0.0
        reached = False

        for r in range(cfg.max_rounds):
            t0 = time.perf_counter()  # noqa: REPRO004 -- measures the RoundRecord.wall info field only; costs come from the cost model
            m = min(hp.m, self.dataset.n_clients)
            participants = self.selector.select(m)
            updates: List[ClientUpdate] = []
            examples = []
            for cid in participants:
                upd, n = self._client_update(params, int(cid), hp.e)
                updates.append(upd)
                examples.append(n)
            params = self.aggregator(params, updates)
            from repro.federated.compression import upload_factor
            round_cost = self.cost_model.add_round(
                examples, hp.e,
                upload_factor=upload_factor(cfg.compression))

            if eval_due(r, cfg.eval_every, cfg.max_rounds):
                accuracy = self._evaluate(params)
            wall = time.perf_counter() - t0  # noqa: REPRO004 -- RoundRecord.wall is informational; parity ignores it
            history.append(RoundRecord(r, hp.m, hp.e, accuracy,
                                       round_cost, wall))
            if cfg.log_every and (r + 1) % cfg.log_every == 0:
                print(f"  round {r+1:4d}  acc={accuracy:.4f}  M={hp.m} "
                      f"E={hp.e:g}  wall={wall:.2f}s", flush=True)
            if accuracy >= cfg.target_accuracy:
                reached = True
                break
            hp = self.tuner.on_round(r, accuracy, round_cost,
                                     self.cost_model.total, hp)
            hp = hp.clamped(self.dataset.n_clients, 100.0)

        return FLResult(
            reached_target=reached,
            rounds=len(history),
            final_accuracy=accuracy,
            total_cost=self.cost_model.total.copy(),
            history=history,
            final_m=hp.m,
            final_e=hp.e,
            params=params,
        )
