"""Target hardware constants (TPU v5e)."""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Chip:
    name: str
    peak_flops_bf16: float     # FLOP/s per chip
    hbm_bandwidth: float       # B/s per chip
    ici_link_bandwidth: float  # B/s per link
    ici_links_per_chip: int    # usable links on the 2D torus
    hbm_bytes: float


TPU_V5E = Chip(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
    ici_links_per_chip=2,      # effective concurrent links for ring collectives
    hbm_bytes=16e9,
)
