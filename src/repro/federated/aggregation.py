"""Server-side aggregation algorithms.

All aggregators consume per-participant results
  ClientUpdate(params, n_examples, n_steps)
and produce the new global params.  The weighted sums run through the
``fed_aggregate`` kernel path (Pallas on TPU, jnp reference elsewhere) on
flattened parameter vectors.

Implemented: FedAvg [McMahan'17], FedNova [Wang'20], and the adaptive
server optimizers FedAdagrad / FedAdam / FedYogi [Reddi'21].  FedProx is a
*client-side* proximal term (see federated/client.py) aggregated by FedAvg.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kernel_ops


class ClientUpdate(NamedTuple):
    params: Any        # client's local params after E passes
    n_examples: int
    n_steps: int       # local optimizer steps actually taken (tau_k)
    last_loss: float = 0.0  # final local loss (guided selection signal)


def _flatten(params):
    leaves, treedef = jax.tree.flatten(params)
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1) for l in leaves])
    return flat, (treedef, shapes, sizes)


def _unflatten(flat, meta):
    treedef, shapes, sizes = meta
    out = []
    off = 0
    for shape, size in zip(shapes, sizes):
        out.append(flat[off:off + size].reshape(shape))
        off += size
    return jax.tree.unflatten(treedef, out)


def _weighted_combine(weights: np.ndarray, param_list: List[Any],
                      base: Optional[Any] = None):
    """sum_k w_k * params_k (+ base), via the fed_aggregate kernel."""
    flats = []
    meta = None
    for p in param_list:
        f, meta = _flatten(p)
        flats.append(f)
    deltas = jnp.stack(flats)                     # (M, N)
    w = jnp.asarray(weights, jnp.float32)
    base_flat = _flatten(base)[0] if base is not None else None
    out = kernel_ops.fed_aggregate(w, deltas, base_flat)
    return _unflatten(out, meta)


# ---------------------------------------------------------------------------
# aggregators
# ---------------------------------------------------------------------------

class Aggregator:
    name = "base"

    def __call__(self, global_params, updates: List[ClientUpdate]):
        raise NotImplementedError


class FedAvg(Aggregator):
    name = "fedavg"

    def __call__(self, global_params, updates):
        n = float(sum(u.n_examples for u in updates))
        w = np.array([u.n_examples / n for u in updates], np.float32)
        return _weighted_combine(w, [u.params for u in updates])


class FedNova(Aggregator):
    """Normalized averaging: re-weights client *deltas* by their local step
    counts tau_k so heterogeneous E does not bias the update direction."""
    name = "fednova"

    def __call__(self, global_params, updates):
        n = float(sum(u.n_examples for u in updates))
        p = np.array([u.n_examples / n for u in updates], np.float32)
        tau = np.array([max(u.n_steps, 1) for u in updates], np.float32)
        tau_eff = float((p * tau).sum())
        # delta_k = (theta_k - theta) / tau_k ; theta' = theta + tau_eff * sum p_k d_k
        deltas = [
            jax.tree.map(lambda a, b: (a - b), u.params, global_params)
            for u in updates
        ]
        w = (p / tau) * tau_eff
        return _weighted_combine(w.astype(np.float32), deltas,
                                 base=global_params)


@dataclass
class _AdaptiveServer(Aggregator):
    """Reddi et al. adaptive server optimizers over the pseudo-gradient
    Delta = sum_k p_k (theta_k - theta)."""
    lr: float = 0.1
    b1: float = 0.0
    tau: float = 1e-3
    name = "adaptive"

    def __post_init__(self):
        self._m = None
        self._v = None

    def _second_moment(self, v, d2):
        raise NotImplementedError

    def __call__(self, global_params, updates):
        n = float(sum(u.n_examples for u in updates))
        w = np.array([u.n_examples / n for u in updates], np.float32)
        deltas = [jax.tree.map(lambda a, b: a - b, u.params, global_params)
                  for u in updates]
        delta = _weighted_combine(w, deltas)
        if self._m is None:
            self._m = jax.tree.map(jnp.zeros_like, delta)
            self._v = jax.tree.map(
                lambda x: jnp.full_like(x, self.tau ** 2), delta)
        self._m = jax.tree.map(lambda m, d: self.b1 * m + (1 - self.b1) * d,
                               self._m, delta)
        self._v = jax.tree.map(self._second_moment, self._v,
                               jax.tree.map(lambda d: d * d, delta))
        return jax.tree.map(
            lambda t, m, v: t + self.lr * m / (jnp.sqrt(v) + self.tau),
            global_params, self._m, self._v)


class FedAdagrad(_AdaptiveServer):
    name = "fedadagrad"

    def _second_moment(self, v, d2):
        return v + d2


class FedAdam(_AdaptiveServer):
    name = "fedadam"
    b2: float = 0.99

    def _second_moment(self, v, d2):
        return 0.99 * v + 0.01 * d2


class FedYogi(_AdaptiveServer):
    name = "fedyogi"

    def _second_moment(self, v, d2):
        return v - 0.01 * jnp.sign(v - d2) * d2


def get_aggregator(name: str, **kw) -> Aggregator:
    table = {
        "fedavg": FedAvg,
        "fedprox": FedAvg,     # proximal term lives client-side
        "fednova": FedNova,
        "fedadagrad": FedAdagrad,
        "fedadam": FedAdam,
        "fedyogi": FedYogi,
    }
    return table[name](**kw)
