"""Upload compression (beyond-paper): int8-quantized client deltas.

Clients upload quantized (theta_k - theta) instead of full-precision
parameters, cutting the paper's TransL by ~4x on the upload half of each
round; the server dequantizes before aggregation.  This composes with
FedTune: the controller sees the reduced TransL through the cost model's
``upload_factor`` and steers (M, E) accordingly.

Compression is a *lane transform*: the quantize->dequantize round trip is
one leaf function (``_roundtrip_leaf``) exposed two ways —

  ``compress_delta``       — per-tree, what ``FLServer._client_update``
                             applies after one client's local training.
  ``compress_delta_lanes`` — vmapped over an (M, ...)-stacked cohort with
                             an optional per-lane enable mask, what the
                             batched/sharded/sweep cohort packers apply to
                             their packed rows (each lane quantized against
                             ITS trial's dispatch-time global params).

Both entry points are jitted compilations of the same graph, so lane i of
the stacked transform is BIT-identical to the per-tree round trip — which
is what lets upload-compressed trials run through the vectorized sweep
engines instead of falling back to one-at-a-time execution (pinned in
tests/test_extensions.py and tests/test_experiments.py).
"""

from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# bytes(transmitted)/bytes(f32) for the upload half of a round
FACTORS = {None: 1.0, "none": 1.0, "int8": 0.25 + 1e-3}


def _roundtrip_leaf(g, c):
    """One leaf's quantize->transmit->dequantize simulation: symmetric
    int8 over the delta, per-leaf scale, zero deltas reconstruct exactly
    (the 1e-12 clamp only guards the 0/0 of an all-zero delta)."""
    delta = (c - g).astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(delta)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(delta / scale), -127, 127).astype(jnp.int8)
    return g + (q.astype(jnp.float32) * scale).astype(g.dtype)


@jax.jit
def _tree_roundtrip(global_params, client_params):
    return jax.tree.map(_roundtrip_leaf, global_params, client_params)


def compress_delta(global_params: Any, client_params: Any,
                   method: str = "int8") -> Any:
    """Simulate the quantize->transmit->dequantize round trip and return the
    client params the SERVER reconstructs."""
    if method in (None, "none"):
        return client_params
    upload_factor(method)          # ValueError naming valid methods
    return _tree_roundtrip(global_params, client_params)


def lane_roundtrip(global_b: Any, params_b: Any, enabled=None) -> Any:
    """The round trip vmapped over an (M, ...)-stacked cohort: lane i is
    quantized against ITS reference params ``global_b[i]`` (the trial's
    dispatch-time global model).  ``enabled`` is an optional (M,) bool mask
    — lanes of uncompressed trials pass through unchanged, so mixed grids
    pack into one cohort.  Pure jax: callable inside jit / shard_map (the
    sharded packer fuses it before its on-device segment sum)."""
    def leaf(g, c):
        rec = jax.vmap(_roundtrip_leaf)(g, c)
        if enabled is None:
            return rec
        gate = enabled.reshape((-1,) + (1,) * (rec.ndim - 1))
        return jnp.where(gate, rec, c)
    return jax.tree.map(leaf, global_b, params_b)


@jax.jit
def _lanes_all(global_b, params_b):
    return lane_roundtrip(global_b, params_b)


@jax.jit
def _lanes_masked(global_b, params_b, enabled):
    return lane_roundtrip(global_b, params_b, enabled)


def compress_delta_lanes(global_b: Any, params_b: Any,
                         enabled=None) -> Any:
    """Jitted entry point for the cohort packers: ``lane_roundtrip`` as its
    own dispatch, bit-identical per lane to ``compress_delta`` on that
    lane's (global, params) pair."""
    if enabled is None:
        return _lanes_all(global_b, params_b)
    return _lanes_masked(global_b, params_b, jnp.asarray(enabled))


def lane_mask(methods: Sequence[Optional[str]]) -> Optional[np.ndarray]:
    """Per-lane enable mask from the lanes' ``TrialSpec.compression``
    values; None when no lane compresses (the packers skip the transform
    entirely).  Unknown methods raise, naming the valid ones."""
    for m in methods:
        upload_factor(m)
    mask = np.array([m not in (None, "none") for m in methods], bool)
    return mask if mask.any() else None


def upload_factor(method: str | None) -> float:
    try:
        return FACTORS[method]
    except KeyError:
        valid = ", ".join(repr(k) for k in FACTORS)
        raise ValueError(
            f"unknown compression method {method!r}; valid methods: {valid}"
        ) from None
