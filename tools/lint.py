#!/usr/bin/env python
"""Repo lint entry point — thin wrapper over ``repro.analysis``.

Equivalent to ``PYTHONPATH=src python -m repro.analysis`` but runnable
from the repo root without setting PYTHONPATH, mirroring the other
``tools/`` scripts.  Exit codes: 0 clean, 1 new findings, 2 error.

Usage: python tools/lint.py [paths...] [--format json] [--baseline P]
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis.cli import main  # noqa: E402 (path bootstrap first)

if __name__ == "__main__":
    raise SystemExit(main())
