import os
import sys

# Tests run on the single host CPU device (the 512-device override is ONLY
# set inside launch/dryrun.py, never globally).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
