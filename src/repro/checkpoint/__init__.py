from repro.checkpoint.checkpointer import (load_checkpoint, load_snapshot,
                                           restore_tree, save_checkpoint,
                                           save_snapshot)

__all__ = ["save_checkpoint", "load_checkpoint", "save_snapshot",
           "load_snapshot", "restore_tree"]
