"""Chaos tests: coordinator kills, client failures, and fleet churn
composed over the serving scheduler (PR 9's tentpole contract).

Two layers:

  * the kill-at-every-macro-step matrix — one uninterrupted serve fixes
    the reference store and its total macro-step count S; then for EVERY
    k in 1..S the coordinator is killed after k steps and restored from
    the two-slot snapshot.  Each resumed drain must (a) replay at most
    one macro-step, (b) end with a store bit-identical to the reference
    (volatile wall-clock field excluded), (c) never append a duplicate
    row — a trial that retired during the replayed step is suppressed;

  * the seeded chaos property — ``FaultPlan.random(seed)`` scripts an
    arbitrary interleaving of client failures, churn, and mid-drain
    coordinator kills over a mixed sync+async+buffered pool.  Whatever
    the interleaving: exactly one store row per trial key, rows
    bit-identical to the fault-free-COORDINATOR reference over the same
    (fault-perturbed) specs, LanePool invariants restored, and — when
    the plan drew failure rate 0 and no churn — rows bit-identical to
    standalone ``FLServer.run()`` through ``run_trial``.

Scenario generation is hypothesis-driven when hypothesis is installed;
otherwise a fixed seed set covering failures+churn+kills, snapshot_every
> 1, and the zero-rate branch runs the same property.
"""

import json

import pytest

try:   # the property test widens under hypothesis; the fallback always runs
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from faultlib import FaultPlan, serve_uninterrupted, serve_with_kills
from repro.experiments import TrialSpec, run_trial


def tiny_spec(**kw):
    base = dict(dataset="emnist", aggregator="fedavg", seed=0,
                tuner="fedtune", m0=3, e0=1.0, rounds=2,
                target_accuracy=0.99, batch_size=5, eval_points=128)
    base.update(kw)
    return TrialSpec(**base)


def mixed_specs(plan=None, n=5):
    """A small mixed-mode pool with staggered budgets (lanes retire at
    different steps, so kills land mid-drain in interesting states)."""
    specs = [tiny_spec(seed=s, rounds=1 + s % 2,
                       mode=("sync", "async", "buffered", "sync",
                             "async")[s % 5])
             for s in range(n)]
    if plan is not None:
        specs = [plan.perturb(s) for s in specs]
    return specs


def assert_pool_drained(sched):
    """LanePool invariants after a full drain: empty page table, every
    lane back on the free list, bijection trivially empty."""
    pool = sched.pool
    assert pool.n_live == 0
    assert pool.n_free == pool.capacity
    assert sorted(pool._free) == list(range(pool.capacity))
    assert pool._page == {} and pool._lane == {}


def assert_one_row_per_key(rows, specs):
    keys = [r["key"] for r in rows]
    assert len(keys) == len(set(keys)), "duplicate store rows"
    assert set(keys) == {s.key() for s in specs}, "missing/extra trials"


# ---------------------------------------------------------------------------
# the kill matrix: die after EVERY macro-step, resume, compare stores
# ---------------------------------------------------------------------------

def test_kill_at_every_macro_step_resumes_bit_identical(tmp_path):
    specs = mixed_specs(n=4)
    ref = serve_uninterrupted(specs, tmp_path, max_lanes=2)
    total_steps = ref.sched.stats.steps
    assert total_steps >= 3          # the matrix needs room to be a matrix
    ref_rows = ref.rows_sans_wall()
    assert_one_row_per_key(ref.rows, specs)

    for k in range(1, total_steps + 1):
        plan = FaultPlan(kill_steps=(k,), snapshot_every=1, seed=1000 + k)
        out = serve_with_kills(specs, plan, tmp_path, max_lanes=2)
        assert out.rows_sans_wall() == ref_rows, f"kill at step {k}"
        assert_one_row_per_key(out.rows, specs)
        assert_pool_drained(out.sched)
        # at-most-one-step replay: the killed incarnation ran k steps from
        # a cold start; its successor resumed at the boundary BEFORE the
        # kill, so total executed steps exceed the reference by exactly
        # the one replayed step (fewer when the kill landed post-drain)
        assert sum(out.steps_executed) <= total_steps + 1
        assert out.sched.stats.steps == total_steps
        assert out.duplicates_suppressed <= out.sched.pool.capacity


def test_kill_with_sparse_snapshots_replays_at_most_every(tmp_path):
    """snapshot_every=3: a kill loses at most 3 macro-steps, and the
    store still converges bit-identically (replayed retirements are
    suppressed, not duplicated)."""
    specs = mixed_specs(n=4)
    ref = serve_uninterrupted(specs, tmp_path, max_lanes=2, tag="ref3")
    total_steps = ref.sched.stats.steps
    ref_rows = ref.rows_sans_wall()
    for k in (2, total_steps // 2 + 1, total_steps):
        plan = FaultPlan(kill_steps=(k,), snapshot_every=3, seed=2000 + k)
        out = serve_with_kills(specs, plan, tmp_path, max_lanes=2)
        assert out.rows_sans_wall() == ref_rows, f"kill at step {k}"
        assert_one_row_per_key(out.rows, specs)
        assert sum(out.steps_executed) <= total_steps + 3


# ---------------------------------------------------------------------------
# the chaos property
# ---------------------------------------------------------------------------

def chaos_property(seed, tmp_path):
    plan = FaultPlan.random(seed)
    specs = mixed_specs(plan)
    ref = serve_uninterrupted(specs, tmp_path, max_lanes=3,
                              tag=f"ref_{seed}")
    assert_one_row_per_key(ref.rows, specs)
    assert_pool_drained(ref.sched)

    out = serve_with_kills(specs, plan, tmp_path, max_lanes=3)
    assert out.rows_sans_wall() == ref.rows_sans_wall(), plan
    assert_one_row_per_key(out.rows, specs)
    assert_pool_drained(out.sched)
    # zero re-runs: every row beyond a replayed step's suppression was
    # computed exactly once, so the final scheduler's retired count plus
    # prior incarnations' covers the pool exactly
    assert out.sched.stats.retired == len(specs)

    if plan.failure_rate == 0.0 and plan.churn is None:
        # kills alone must not move a float vs standalone FLServer.run()
        for spec in specs:
            base = run_trial(spec).to_record()
            (row,) = [r for r in out.rows_sans_wall()
                      if r["key"] == spec.key()]
            row = dict(row)
            for d in (base, row):       # volatile / engine-label fields
                d.pop("wall", None)
                d.pop("engine", None)
            # the store row went through JSON (tuples -> lists): put the
            # in-memory record through the same codec before comparing
            base = json.loads(json.dumps(base))
            assert row == base, spec.key()


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    @given(seed=st.integers(0, 10**6))
    def test_chaos_interleavings(tmp_path_factory, seed):
        chaos_property(seed, tmp_path_factory.mktemp(f"chaos{seed}"))
else:
    # seeds chosen to cover: failures+churn+kills at snapshot_every=1 (0),
    # failures+churn+3 kills at snapshot_every=2 (9), and rate-0/no-churn
    # with kills — the standalone-parity branch (11)
    @pytest.mark.parametrize("seed", [0, 9, 11])
    def test_chaos_interleavings(tmp_path, seed):
        chaos_property(seed, tmp_path)
