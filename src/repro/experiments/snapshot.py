"""Scheduler snapshot/restore: the crash-safe half of fault-tolerant
serving.

``snapshot_scheduler`` serializes a ``TrialScheduler``'s COMPLETE state at
a macro-step boundary — every live trial's params/rngs/clock/histories,
in-flight dispatch snapshots, FedBuff delta buffers, the merged event
queue's pending heap, the lane page table, the trial queue, and the
scheduler's own counters — through the hardened two-slot checkpointer
(repro.checkpoint).  ``restore_scheduler`` rebuilds a scheduler that
replays the interrupted macro-step and then continues bit-identically to
an uninterrupted drain.

Serialization split: everything array-shaped (params trees, in-flight
dispatch snapshots, buffered deltas) goes into the npz half keyed by a
``t{i}/...`` leaf prefix; everything host-side (rng bit-generator states,
virtual clocks, cost totals, FedTune controller state, histories, queue
and pool inventories) is JSON in the metadata half.  Restore rebuilds each
trial via ``build_server`` (so model/optimizer/dataset come from the
shared caches) and then OVERWRITES all stochastic state — it deliberately
never calls ``init_event_state``, whose dispatch draws would desync the
restored rng streams.

The at-most-one-step contract (pinned in tests/test_chaos.py): snapshots
are taken at macro-step boundaries, so a kill loses only the partial step
after the last boundary; on restore that step replays.  A trial that
retired DURING the replayed step before the kill already has its row in
the JSONL store — the scheduler's ``_retire`` suppresses the duplicate
append (``store.is_completed``), so the store ends bit-identical to the
uninterrupted serve, rows in the same order.
"""

from __future__ import annotations

import heapq
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import load_snapshot, restore_tree, save_snapshot
from repro.core.costs import SystemCost
from repro.core.fedtune import FedTune, _Window
from repro.core.tuner import HyperParams
from repro.federated.server import RoundRecord
from repro.runtime.engine import _InFlight
from repro.runtime.events import TaggedEvent

SNAPSHOT_VERSION = 1


# ---------------------------------------------------------------------------
# small host-state codecs
# ---------------------------------------------------------------------------

def _rng_state(rng: np.random.Generator) -> dict:
    return rng.bit_generator.state          # JSON-serializable dict


def _set_rng_state(rng: np.random.Generator, state: dict) -> None:
    rng.bit_generator.state = state


def _record_to_dict(r: RoundRecord) -> dict:
    return {"round_idx": r.round_idx, "m": r.m, "e": r.e,
            "accuracy": r.accuracy, "cost": list(r.cost.as_tuple()),
            "wall_time": r.wall_time, "sim_time": r.sim_time,
            "n_updates": r.n_updates}


def _record_from_dict(d: dict) -> RoundRecord:
    return RoundRecord(round_idx=int(d["round_idx"]), m=int(d["m"]),
                       e=float(d["e"]), accuracy=float(d["accuracy"]),
                       cost=SystemCost(*d["cost"]),
                       wall_time=float(d["wall_time"]),
                       sim_time=float(d["sim_time"]),
                       n_updates=int(d["n_updates"]))


def _tuner_state(tuner) -> Optional[dict]:
    if not isinstance(tuner, FedTune):
        return None                          # FixedTuner is stateless
    return {
        "current": [tuner.current.m, tuner.current.e],
        "prev_hp": ([tuner.prev_hp.m, tuner.prev_hp.e]
                    if tuner.prev_hp is not None else None),
        "last_acc": tuner._last_acc,
        "acc_at_last_decision": tuner._acc_at_last_decision,
        "window_cost": list(tuner._window_cost.as_tuple()),
        "prv": list(tuner._prv.values) if tuner._prv is not None else None,
        "prvprv": (list(tuner._prvprv.values)
                   if tuner._prvprv is not None else None),
        "eta": list(tuner.eta), "zeta": list(tuner.zeta),
        "decisions": tuner.decisions,
        "trace": tuner.trace,
    }


def _set_tuner_state(tuner, d: Optional[dict]) -> None:
    if d is None or not isinstance(tuner, FedTune):
        return
    tuner.current = HyperParams(int(d["current"][0]), float(d["current"][1]))
    tuner.prev_hp = (HyperParams(int(d["prev_hp"][0]), float(d["prev_hp"][1]))
                     if d["prev_hp"] is not None else None)
    tuner._last_acc = float(d["last_acc"])
    tuner._acc_at_last_decision = float(d["acc_at_last_decision"])
    tuner._window_cost = SystemCost(*d["window_cost"])
    tuner._prv = (_Window(values=list(d["prv"]))
                  if d["prv"] is not None else None)
    tuner._prvprv = (_Window(values=list(d["prvprv"]))
                     if d["prvprv"] is not None else None)
    tuner.eta = list(d["eta"])
    tuner.zeta = list(d["zeta"])
    tuner.decisions = int(d["decisions"])
    # JSON round-trips the decision windows' tuples as lists
    tuner.trace = [dict(t, window=tuple(t["window"])) if "window" in t
                   else dict(t) for t in d["trace"]]


def _engine_state(tr) -> dict:
    """Host state shared by sync and event live trials: the runtime's
    clocks/rngs, the server's cost totals, and any stateful selector."""
    d = {
        "clock": tr.eng.clock.now,
        "srv_rng": _rng_state(tr.srv.rng),
        "sys_rng": _rng_state(tr.eng.sys_rng),
        "cost_total": list(tr.srv.cost_model.total.as_tuple()),
        "cost_rounds": tr.srv.cost_model.rounds,
        "tuner": _tuner_state(tr.srv.tuner),
    }
    if hasattr(tr.srv.selector, "utility"):
        d["sel_utility"] = [float(u) for u in tr.srv.selector.utility]
    return d


def _set_engine_state(tr, d: dict) -> None:
    tr.eng.clock._now = float(d["clock"])
    _set_rng_state(tr.srv.rng, d["srv_rng"])      # selector shares this rng
    _set_rng_state(tr.eng.sys_rng, d["sys_rng"])
    tr.srv.cost_model.total = SystemCost(*d["cost_total"])
    tr.srv.cost_model.rounds = int(d["cost_rounds"])
    _set_tuner_state(tr.srv.tuner, d.get("tuner"))
    if "sel_utility" in d:
        tr.srv.selector.utility = np.array(d["sel_utility"])


def _collect_leaves(leaves: Dict[str, Any], prefix: str, tree: Any) -> None:
    from repro.checkpoint.checkpointer import _key
    jax.tree_util.tree_map_with_path(
        lambda p, x: leaves.setdefault(prefix + _key(p), np.asarray(x)),
        tree)


# ---------------------------------------------------------------------------
# snapshot
# ---------------------------------------------------------------------------

def snapshot_scheduler(sched, path: str) -> str:
    """Serialize the scheduler at a macro-step boundary; returns the
    written npz path."""
    leaves: Dict[str, Any] = {}
    trials: List[dict] = []

    for tr in sched._sync_live:
        i = len(trials)
        _collect_leaves(leaves, f"t{i}/params/", tr.params)
        trials.append({
            "kind": "sync", "spec": tr.spec.to_dict(),
            "hp": [tr.hp.m, tr.hp.e],
            "round_idx": tr.round_idx, "accuracy": tr.accuracy,
            "reached": tr.reached, "done": tr.done, "wall": tr.wall,
            "history": [_record_to_dict(r) for r in tr.history],
            "engine": _engine_state(tr),
        })

    for tr in sched._event_live:
        i = len(trials)
        st = tr.st
        _collect_leaves(leaves, f"t{i}/params/", st.params)
        inflight = []
        for j, (cid, fl) in enumerate(st.inflight.items()):
            _collect_leaves(leaves, f"t{i}/if{j}/", fl.params)
            inflight.append({"cid": int(cid), "version": fl.version,
                             "e": fl.e, "n_examples": fl.n_examples,
                             "comp_time": fl.comp_time,
                             "trans_time": fl.trans_time,
                             "attempt": fl.attempt})
        for j, delta in enumerate(st.buffer._deltas):
            _collect_leaves(leaves, f"t{i}/d{j}/", delta)
        trials.append({
            "kind": "event", "spec": tr.spec.to_dict(),
            "trial_ord": tr.view.trial_ord,
            "hp": [st.hp.m, st.hp.e],
            "version": st.version, "accuracy": st.accuracy,
            "reached": st.reached, "done": tr.done, "wall": tr.wall,
            "pend_comp": list(st.pend_comp),
            "pend_trans": list(st.pend_trans),
            "pend_comp_load": st.pend_comp_load,
            "pend_trans_load": st.pend_trans_load,
            "last_agg_clock": st.last_agg_clock,
            "history": [_record_to_dict(r) for r in st.history],
            "dispatch_log": [list(t) for t in st.dispatch_log],
            "staleness_log": list(st.staleness_log),
            "inflight": inflight,
            "buffer_weights": [float(w) for w in st.buffer._weights],
            "engine": _engine_state(tr),
        })

    ev = sched._ev
    meta = {
        "version": SNAPSHOT_VERSION,
        "trials": trials,
        "pool": {"capacity": sched.pool.capacity,
                 "page": {str(lane): key
                          for lane, key in sched.pool._page.items()}},
        "queue": {
            "pending": [s.to_dict() for s in sched.queue._pending],
            "seen": sorted(sched.queue._seen),
            "done": sorted(sched.queue._done),
            "watch_pos": sched.queue._watch_pos,
            "n_submitted": sched.queue.n_submitted,
            "n_skipped": sched.queue.n_skipped,
        },
        "merged": {
            "seq": {str(k): v for k, v in ev.merged._seq.items()},
            "events": [[e.time, e.trial_ord, e.seq, e.kind, e.client_id]
                       for e in ev.merged._heap],
        },
        "ev": {"n_steps": ev.n_steps, "next_ord": ev.next_ord},
        "stats": {"admitted": sched.stats.admitted,
                  "retired": sched.stats.retired,
                  "steps": sched.stats.steps,
                  "occupancy_sum": sched.stats.occupancy_sum,
                  "admission_log": [list(t)
                                    for t in sched.stats.admission_log]},
        "sync_steps": sched._sync_steps,
    }
    return save_snapshot(path, leaves, step=sched.stats.steps, metadata=meta)


# ---------------------------------------------------------------------------
# restore
# ---------------------------------------------------------------------------

def restore_scheduler(sched, path: str) -> None:
    """Rebuild ``sched``'s live state from the newest valid snapshot at
    ``path``.  ``sched`` must be freshly constructed (empty pool, no live
    trials); its queue/store/pack wiring is kept, everything else is
    overwritten."""
    from repro.experiments.grid import spec_from_dict
    from repro.experiments.runner import (_EventTrial, _make_live,
                                          build_server)
    from repro.federated.aggregation import FedBuffAggregator
    from repro.runtime.engine import (EventDrivenRuntime, EventLoopState,
                                      RuntimeConfig)
    from repro.runtime.events import TrialQueueView

    arrays, meta = load_snapshot(path)
    if meta.get("version") != SNAPSHOT_VERSION:
        raise ValueError(f"unsupported snapshot version "
                         f"{meta.get('version')!r} at {path}")

    # queue: internal inventory, NOT submit() (no re-validation/counting)
    q = sched.queue
    q._pending.clear()
    q._pending.extend(spec_from_dict(d) for d in meta["queue"]["pending"])
    q._seen = set(meta["queue"]["seen"])
    q._done |= set(meta["queue"]["done"])
    q._watch_pos = int(meta["queue"]["watch_pos"])
    q.n_submitted = int(meta["queue"]["n_submitted"])
    q.n_skipped = int(meta["queue"]["n_skipped"])

    # lane page table: capacity comes from the snapshot (the lane<->trial
    # mapping is only meaningful at its own capacity), held lanes are
    # re-pinned and the free list derived (min-heap by index)
    from repro.experiments.scheduler import LanePool
    sched.pool = pool = LanePool(int(meta["pool"]["capacity"]))
    pool._page = {int(l): k for l, k in meta["pool"]["page"].items()}
    pool._lane = {k: l for l, k in pool._page.items()}
    pool._free = [l for l in range(pool.capacity) if l not in pool._page]

    ev = sched._ev
    ev.n_steps = int(meta["ev"]["n_steps"])
    ev.next_ord = int(meta["ev"]["next_ord"])
    ev.merged._seq = {int(k): int(v)
                      for k, v in meta["merged"]["seq"].items()}

    for i, td in enumerate(meta["trials"]):
        spec = spec_from_dict(td["spec"])
        eng_d = td["engine"]
        if td["kind"] == "sync":
            tr = _make_live(spec)
            tr.hp = HyperParams(int(td["hp"][0]), float(td["hp"][1]))
            tr.params = restore_tree(arrays, tr.params,
                                     prefix=f"t{i}/params/")
            tr.round_idx = int(td["round_idx"])
            tr.accuracy = float(td["accuracy"])
            tr.reached = bool(td["reached"])
            tr.done = bool(td["done"])
            tr.wall = float(td["wall"])
            tr.history = [_record_from_dict(r) for r in td["history"]]
            _set_engine_state(tr, eng_d)
            sched._sync_live.append(tr)
            continue

        # event trial: manual construction — init_event_state would draw
        # from the rngs we are about to overwrite
        srv = build_server(spec)
        eng = EventDrivenRuntime(srv, fleet=srv.fleet,
                                 config=srv.runtime_config
                                 or RuntimeConfig())
        eng.trace_label = spec.key()
        trial_ord = int(td["trial_ord"])
        view = TrialQueueView(ev.merged, trial_ord)
        tr = _EventTrial(spec=spec, srv=srv, eng=eng, view=view)
        template = srv.model.init(jax.random.PRNGKey(srv.config.seed))
        rt = eng.rt
        st = EventLoopState(
            hp=HyperParams(int(td["hp"][0]), float(td["hp"][1])),
            params=restore_tree(arrays, template, prefix=f"t{i}/params/"),
            buffer=FedBuffAggregator(
                buffer_k=rt.buffer_k, server_lr=rt.server_lr,
                staleness_alpha=rt.staleness_alpha,
                staleness_kind=rt.staleness_kind))
        st.version = int(td["version"])
        st.accuracy = float(td["accuracy"])
        st.reached = bool(td["reached"])
        st.pend_comp = [float(v) for v in td["pend_comp"]]
        st.pend_trans = [float(v) for v in td["pend_trans"]]
        st.pend_comp_load = float(td["pend_comp_load"])
        st.pend_trans_load = float(td["pend_trans_load"])
        st.last_agg_clock = float(td["last_agg_clock"])
        st.history = [_record_from_dict(r) for r in td["history"]]
        st.dispatch_log = [tuple(t) for t in td["dispatch_log"]]
        st.staleness_log = [int(s) for s in td["staleness_log"]]
        for j, fd in enumerate(td["inflight"]):
            st.inflight[int(fd["cid"])] = _InFlight(
                client_id=int(fd["cid"]),
                params=restore_tree(arrays, template, prefix=f"t{i}/if{j}/"),
                version=int(fd["version"]), e=float(fd["e"]),
                n_examples=int(fd["n_examples"]),
                comp_time=float(fd["comp_time"]),
                trans_time=float(fd["trans_time"]),
                attempt=int(fd["attempt"]))
        for j, w in enumerate(td["buffer_weights"]):
            st.buffer._deltas.append(
                restore_tree(arrays, template, prefix=f"t{i}/d{j}/"))
            st.buffer._weights.append(float(w))
        tr.st = st
        tr.done = bool(td["done"])
        tr.wall = float(td["wall"])
        _set_engine_state(tr, eng_d)
        ev.by_ord[trial_ord] = tr
        sched._event_live.append(tr)

    # the merged heap: original (time, trial_ord, seq) keys, re-heapified
    heap = [TaggedEvent(time=float(t), trial_ord=int(o), seq=int(s),
                        kind=str(k), client_id=int(c))
            for t, o, s, k, c in meta["merged"]["events"]]
    heapq.heapify(heap)
    ev.merged._heap = heap
    counts: Dict[int, int] = {}
    for e in heap:
        counts[e.trial_ord] = counts.get(e.trial_ord, 0) + 1
    ev.merged._count = counts

    stats = meta["stats"]
    sched.stats.admitted = int(stats["admitted"])
    sched.stats.retired = int(stats["retired"])
    sched.stats.steps = int(stats["steps"])
    sched.stats.occupancy_sum = float(stats["occupancy_sum"])
    sched.stats.admission_log = [tuple(t) for t in stats["admission_log"]]
    sched._sync_steps = int(meta["sync_steps"])
