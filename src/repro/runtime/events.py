"""Virtual-clock event queues for the heterogeneous FL runtime.

A tiny discrete-event core: events carry a virtual timestamp and are popped
in time order with a monotonically increasing sequence number breaking ties,
so two events at the same instant always replay in push order — the whole
simulation is a pure function of its seeds.  The clock never goes backwards;
popping an event advances it.

Two queue flavors:

``EventQueue``       — one trial's events, keyed (time, seq).  Drives the
                       standalone ``EventDrivenRuntime`` loop.
``MergedEventQueue`` — events of MANY concurrent trials in one heap, keyed
                       (time, trial_ord, seq).  Drives the vectorized
                       async/buffered sweep engine
                       (repro.experiments.runner), which packs pending
                       client completions across trials into one cohort.
                       Cross-trial ties at the same instant break by the
                       trial's stable ordinal (assigned from sorted trial
                       keys), and within a trial by the per-trial push
                       sequence — the SAME tie order the trial's standalone
                       ``EventQueue`` would produce, which is what makes a
                       merged re-run (or a resume) replay each trial's
                       events bit-identically.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List

# event kinds
ARRIVAL = "arrival"          # a client's update reaches the server
DROPOUT = "dropout"          # a client died mid-round; its work is lost
FAILURE = "failure"          # the dispatch was consumed but the update never
                             # returns: the client (or its link) hard-failed.
                             # Distinct from DROPOUT — a dropout's work is
                             # merely lost at the cutoff, a failure triggers
                             # the coordinator's retry/reassignment policy
                             # (EventDrivenRuntime.handle_failure).


@dataclass(order=True)
class Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    client_id: int = field(compare=False, default=-1)


class VirtualClock:
    """Monotonic simulated time."""

    def __init__(self):
        self._now = 0.0

    @property
    def now(self) -> float:
        return self._now

    def advance_to(self, t: float):
        assert t >= self._now - 1e-12, f"clock went backwards: {t} < {self._now}"
        self._now = max(self._now, t)


class EventQueue:
    """One trial's pending events, popped in (time, push-order) order."""

    def __init__(self):
        self._heap: List[Event] = []
        self._seq = 0

    def push(self, time: float, kind: str, client_id: int = -1) -> Event:
        ev = Event(time=float(time), seq=self._seq, kind=kind,
                   client_id=client_id)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


# ---------------------------------------------------------------------------
# merged multi-trial queue (vectorized async/buffered sweeps)
# ---------------------------------------------------------------------------

@dataclass(order=True)
class TaggedEvent:
    """An event tagged with the trial it belongs to.  Ordering is total and
    deterministic: (time, trial_ord, seq) — cross-trial ties break by the
    trial's stable ordinal, within-trial ties by per-trial push order
    (identical to what the trial's own ``EventQueue`` would do, so merged
    execution replays each trial's event order exactly)."""
    time: float
    trial_ord: int
    seq: int
    kind: str = field(compare=False)
    client_id: int = field(compare=False, default=-1)


class MergedEventQueue:
    """One heap spanning all live trials of a vectorized event-driven sweep.

    ``push`` stamps the event with the trial's own monotone sequence
    counter; ``requeue`` re-inserts a popped event UNCHANGED (used by the
    sweep runner to defer a trial's next event while an earlier arrival of
    the same trial is still training in the packed cohort).  ``count_for``
    answers the per-trial emptiness question the engine's dispatch deadlock
    guard asks."""

    def __init__(self):
        self._heap: List[TaggedEvent] = []
        self._seq: Dict[int, int] = {}
        self._count: Dict[int, int] = {}

    def push(self, trial_ord: int, time: float, kind: str,
             client_id: int = -1) -> TaggedEvent:
        seq = self._seq.get(trial_ord, 0)
        self._seq[trial_ord] = seq + 1
        ev = TaggedEvent(time=float(time), trial_ord=trial_ord, seq=seq,
                         kind=kind, client_id=client_id)
        self._count[trial_ord] = self._count.get(trial_ord, 0) + 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> TaggedEvent:
        ev = heapq.heappop(self._heap)
        self._count[ev.trial_ord] -= 1
        return ev

    def requeue(self, ev: TaggedEvent):
        """Put a popped event back with its original (time, trial_ord, seq)
        key — heap order is restored exactly."""
        self._count[ev.trial_ord] += 1
        heapq.heappush(self._heap, ev)

    def drop_trial(self, trial_ord: int) -> int:
        """Remove every pending event of a retired trial and return how
        many were dropped.  The continuous-batching scheduler retires a
        lane the moment its trial reaches target; without this the heap
        would carry the retired trial's traffic forever (each stale event
        popped and skipped one macro-step at a time).  The per-trial seq
        counter is deliberately kept: ordinals are never reused, and a
        monotone seq is what makes the (time, trial_ord, seq) order
        total."""
        n = self._count.get(trial_ord, 0)
        if n:
            self._heap = [ev for ev in self._heap
                          if ev.trial_ord != trial_ord]
            heapq.heapify(self._heap)
            self._count[trial_ord] = 0
        return n

    def count_for(self, trial_ord: int) -> int:
        return self._count.get(trial_ord, 0)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


class TrialQueueView:
    """``EventQueue``-shaped facade binding ONE trial onto a
    ``MergedEventQueue``: the runtime engine's dispatch/fill helpers push
    through it without knowing they are part of a merged sweep, and its
    truthiness answers 'does THIS trial still have queued events?' (the
    question the dispatch deadlock guard asks), not global emptiness."""

    def __init__(self, merged: MergedEventQueue, trial_ord: int):
        self.merged = merged
        self.trial_ord = trial_ord

    def push(self, time: float, kind: str, client_id: int = -1):
        return self.merged.push(self.trial_ord, time, kind, client_id)

    def __len__(self) -> int:
        return self.merged.count_for(self.trial_ord)

    def __bool__(self) -> bool:
        return self.merged.count_for(self.trial_ord) > 0
