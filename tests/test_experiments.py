"""Tests for the sweep-orchestration subsystem (repro.experiments):
grid validation at expansion time, vectorized-vs-independent trial parity,
store resume semantics, and the paper-style table emitter."""

import jax
import numpy as np
import pytest

from repro.experiments import (CANONICAL_PREFERENCE, ResultStore, SweepSpec,
                               TrialSpec, paper_table, parse_preferences,
                               run_sweep, run_trial, run_vectorized)
from repro.experiments.grid import spec_from_dict

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs a multi-device mesh (XLA_FLAGS="
           "--xla_force_host_platform_device_count=4)")


def tiny_spec(**kw):
    base = dict(dataset="emnist", aggregator="fedavg", seed=0,
                tuner="fedtune", m0=3, e0=1.0, rounds=3,
                target_accuracy=0.99, batch_size=5, eval_points=128)
    base.update(kw)
    return TrialSpec(**base)


# ---------------------------------------------------------------------------
# grid expansion + validation
# ---------------------------------------------------------------------------

def test_grid_expands_product_and_collapses_fixed_baselines():
    sweep = SweepSpec(datasets=("emnist",),
                      aggregators=("fedavg", "fedadam"),
                      preferences=parse_preferences("0,14"),
                      seeds=(0, 1), base=tiny_spec())
    specs = sweep.expand()
    # fedtune: 2 agg x 2 pref x 2 seeds = 8; fixed: 2 agg x 2 seeds = 4
    assert len(specs) == 12
    assert len({s.key() for s in specs}) == 12
    fixed = [s for s in specs if s.tuner == "fixed"]
    assert len(fixed) == 4
    assert all(s.preference == CANONICAL_PREFERENCE for s in fixed)
    # every fedtune trial's baseline twin is in the grid
    keys = {s.key() for s in specs}
    for s in specs:
        if s.tuner == "fedtune":
            assert s.baseline_key() in keys


def test_grid_unknown_aggregator_raises_at_expansion():
    sweep = SweepSpec(aggregators=("fedavg", "fedsgd"), base=tiny_spec())
    with pytest.raises(ValueError, match="fedavg"):
        sweep.expand()


def test_grid_unknown_client_exec_and_mode_raise():
    with pytest.raises(ValueError, match="sequential"):
        tiny_spec(client_exec="warp").validate()
    with pytest.raises(ValueError, match="sync"):
        tiny_spec(mode="psychic").validate()
    with pytest.raises(ValueError, match="emnist"):
        tiny_spec(dataset="mnist").validate()
    with pytest.raises(ValueError, match="preference"):
        tiny_spec(preference=(1.0, 1.0, 0.0, 0.0)).validate()


def test_spec_key_roundtrip_through_dict():
    s = tiny_spec(aggregator="fednova", preference=(0.5, 0.5, 0.0, 0.0))
    assert spec_from_dict(s.to_dict()) == s


def test_parse_preferences_forms():
    assert len(parse_preferences("all")) == 15
    assert parse_preferences("0") == [(1.0, 0.0, 0.0, 0.0)]
    assert parse_preferences("1,0,0,0;0,1,0,0") == [(1.0, 0.0, 0.0, 0.0),
                                                   (0.0, 1.0, 0.0, 0.0)]
    # a bare 4-element list: a quad only when it sums to 1, else indices
    # (paper_tables.py's default '0,1,4,14' is four indices)
    assert parse_preferences("1,0,0,0") == [(1.0, 0.0, 0.0, 0.0)]
    assert len(parse_preferences("0,1,4,14")) == 4
    assert parse_preferences("0,1,4,14")[0] == (1.0, 0.0, 0.0, 0.0)
    with pytest.raises(ValueError):
        parse_preferences("99")


# ---------------------------------------------------------------------------
# vectorized multi-trial parity: T=4 packed == 4 independent FLServer.run()
# ---------------------------------------------------------------------------

def assert_trial_parity(base, vec):
    """Round records must be identical: accuracies, FedTune (M, E)
    trajectories, cost totals — and for event-driven (async/buffered)
    trials, the full dispatch schedule and staleness sequence."""
    assert base.history_acc == vec.history_acc
    assert base.history_m == vec.history_m
    assert base.history_e == vec.history_e
    assert base.final_accuracy == vec.final_accuracy
    assert (base.final_m, base.final_e) == (vec.final_m, vec.final_e)
    np.testing.assert_allclose(base.cost, vec.cost, rtol=0, atol=0)
    assert base.reached == vec.reached
    assert base.rounds == vec.rounds
    assert base.dispatch_log == vec.dispatch_log
    assert base.staleness_log == vec.staleness_log


def test_vectorized_matches_independent_runs_fedavg():
    specs = [tiny_spec(seed=s) for s in range(4)]
    base = [run_trial(s) for s in specs]
    vec = run_vectorized(specs)
    for b, v in zip(base, vec):
        assert_trial_parity(b, v)


def test_vectorized_matches_independent_runs_fedadam():
    """One adaptive-server aggregator: per-trial optimizer state (m, v) must
    stay private to each packed trial."""
    specs = [tiny_spec(seed=s, aggregator="fedadam") for s in range(4)]
    base = [run_trial(s) for s in specs]
    vec = run_vectorized(specs)
    for b, v in zip(base, vec):
        assert_trial_parity(b, v)


def test_vectorized_mixed_aggregators_and_fixed_tuner():
    """Trials with different aggregators and tuners pack into one cohort
    without cross-talk."""
    specs = [tiny_spec(seed=0, aggregator="fedavg"),
             tiny_spec(seed=1, aggregator="fednova"),
             tiny_spec(seed=0, tuner="fixed",
                       preference=CANONICAL_PREFERENCE)]
    base = [run_trial(s) for s in specs]
    vec = run_vectorized(specs)
    for b, v in zip(base, vec):
        assert_trial_parity(b, v)


def test_vectorized_rejects_unknown_pack_and_accepts_compression():
    with pytest.raises(ValueError, match="pack"):
        run_vectorized([tiny_spec()], pack="origami")
    # upload-compressed trials vectorize (lane-wise quantization) — the
    # old sequential-only rejection is gone
    res = run_vectorized([tiny_spec(compression="int8", rounds=2)])
    assert res[0].engine.startswith("vectorized")


# ---------------------------------------------------------------------------
# compression as a lane transform: compressed trials run through BOTH
# vectorized engines bit-identically to independent FLServer.run() calls
# (the PR-5 acceptance bar) — no sequential fallback remains
# ---------------------------------------------------------------------------

def test_vectorized_compressed_sync_matches_independent_runs():
    specs = [tiny_spec(seed=s, compression="int8") for s in range(4)]
    base = [run_trial(s) for s in specs]
    vec = run_vectorized(specs)
    for b, v in zip(base, vec):
        assert v.engine.startswith("vectorized/")
        assert_trial_parity(b, v)


def test_vectorized_compressed_events_match_independent_runs():
    """Compressed async AND buffered trials off the merged event queue:
    each lane quantizes against its dispatch snapshot, exactly as
    _client_update does per arrival."""
    specs = [tiny_spec(seed=0, mode="async", compression="int8"),
             tiny_spec(seed=1, mode="async", compression="int8"),
             tiny_spec(seed=0, mode="buffered", rounds=2,
                       compression="int8"),
             tiny_spec(seed=1, mode="buffered", rounds=2,
                       compression="int8")]
    base = [run_trial(s) for s in specs]
    vec = run_vectorized(specs)
    for b, v in zip(base, vec):
        assert v.engine.startswith("vectorized-events/")
        assert_trial_parity(b, v)


def test_vectorized_mixed_compression_lanes_one_pack():
    """Compressed and uncompressed trials pack into ONE cohort: the lane
    mask applies the round trip only to compressed lanes, and neither
    side perturbs the other."""
    specs = [tiny_spec(seed=0),
             tiny_spec(seed=0, compression="int8"),
             tiny_spec(seed=1, mode="async"),
             tiny_spec(seed=1, mode="async", compression="int8")]
    base = [run_trial(s) for s in specs]
    vec = run_vectorized(specs)
    for b, v in zip(base, vec):
        assert_trial_parity(b, v)


def test_run_sweep_compressed_stays_vectorized(capsys):
    """run_sweep no longer routes compressed trials through the
    sequential fallback (and no longer says so)."""
    specs = [tiny_spec(seed=s, compression="int8", rounds=2)
             for s in range(2)]
    res = run_sweep(specs)
    out = capsys.readouterr().out
    assert "sequentially" not in out
    assert all(r.engine.startswith("vectorized") for r in res)


# ---------------------------------------------------------------------------
# merged-event-queue parity: T=4 vectorized async/buffered == 4 independent
# FLServer.run() calls (accuracies, costs, dispatch/staleness records,
# (M, E) trajectories) — the PR-4 acceptance bar
# ---------------------------------------------------------------------------

def test_vectorized_async_matches_independent_runs():
    specs = [tiny_spec(seed=s, mode="async") for s in range(4)]
    base = [run_trial(s) for s in specs]
    vec = run_vectorized(specs)
    for b, v in zip(base, vec):
        assert b.staleness_log, "async trials must record staleness"
        assert b.dispatch_log, "async trials must record dispatches"
        assert_trial_parity(b, v)


def test_vectorized_buffered_matches_independent_runs():
    """FedBuff trials: K-deep delta buffers stay private per trial, and
    flush-round records replay exactly."""
    specs = [tiny_spec(seed=s, mode="buffered", rounds=2) for s in range(4)]
    base = [run_trial(s) for s in specs]
    vec = run_vectorized(specs)
    for b, v in zip(base, vec):
        assert_trial_parity(b, v)


def test_vectorized_async_heterogeneous_fleet_parity():
    """A straggler fleet exercises the merged queue's dropout path (loads
    charged, concurrency refilled inline) and wide arrival-time spreads."""
    specs = [tiny_spec(seed=s, mode="async", het="stragglers")
             for s in range(3)]
    base = [run_trial(s) for s in specs]
    vec = run_vectorized(specs)
    for b, v in zip(base, vec):
        assert_trial_parity(b, v)


def test_vectorized_event_rerun_reproduces_exactly():
    """Re-running a merged-queue sweep replays the identical event order:
    same dispatch schedule, staleness sequence, and round records (the
    resume/re-run determinism the merged queue's (time, trial_key, seq)
    tie order exists to guarantee)."""
    specs = [tiny_spec(seed=s, mode="async") for s in range(3)]
    first = run_vectorized(specs)
    second = run_vectorized(specs)
    for a, b in zip(first, second):
        assert_trial_parity(a, b)


def test_vectorized_mixed_modes_one_sweep():
    """One run_vectorized call spanning all three runtime regimes: sync
    trials pack per round, async/buffered off the merged queue, results in
    input order, every trial bit-matching its standalone run."""
    specs = [tiny_spec(seed=0, mode="sync"),
             tiny_spec(seed=1, mode="async"),
             tiny_spec(seed=2, mode="buffered", rounds=2),
             tiny_spec(seed=3, mode="async", aggregator="fedadam")]
    base = [run_trial(s) for s in specs]
    vec = run_vectorized(specs)
    for s, b, v in zip(specs, base, vec):
        assert v.spec == s
        assert_trial_parity(b, v)


@multidevice
def test_sharded_pack_matches_batched_pack():
    """The clients-mesh packed cohort (per-trial segment sum + psum) agrees
    with the single-device pack up to float reassociation — including
    compressed lanes (quantized in the shard body) and the 'none'
    spelling, which must NOT be treated as compression enabled."""
    specs = [tiny_spec(seed=0), tiny_spec(seed=1, compression="none"),
             tiny_spec(seed=2, compression="int8")]
    vb = run_vectorized(specs, pack="batched")
    vs = run_vectorized(specs, pack="sharded")
    for b, s in zip(vb, vs):
        assert b.history_m == s.history_m
        assert b.history_e == s.history_e
        np.testing.assert_allclose(b.history_acc, s.history_acc, atol=1e-3)
        np.testing.assert_allclose(b.cost, s.cost, rtol=1e-6)


# ---------------------------------------------------------------------------
# the stacked evaluation subsystem (federated/evaluation.py)
# ---------------------------------------------------------------------------

def test_stacked_evaluator_bitmatches_single_evaluator():
    """Lane i of a stacked evaluation equals Evaluator.evaluate on that
    trial's params EXACTLY — the float sequence the parity contract needs."""
    from repro.experiments.runner import build_server
    from repro.federated.evaluation import Evaluator, StackedEvaluator
    srv = build_server(tiny_spec())
    params = [srv.model.init(jax.random.PRNGKey(s)) for s in range(5)]
    single = Evaluator(srv.model, srv.dataset, 128)
    stacked = StackedEvaluator(srv.model, srv.dataset, 128)
    expect = [single.evaluate(p) for p in params]
    got = stacked.evaluate(params)
    assert got == expect
    # and through the grouping entry point, in item order
    from repro.federated.evaluation import evaluate_stacked
    items = [(srv.model, srv.dataset, 128, p) for p in params]
    assert evaluate_stacked(items) == expect


def test_stacked_eval_parity_every_aggregator():
    """Vectorized per-round accuracies bit-match standalone runs for every
    aggregator the grid accepts — the stacked eval sits on the round path
    of all of them."""
    from repro.federated.aggregation import AGGREGATORS
    specs = [tiny_spec(seed=0, rounds=2, aggregator=a)
             for a in sorted(AGGREGATORS)]
    base = [run_trial(s) for s in specs]
    vec = run_vectorized(specs)
    for b, v in zip(base, vec):
        assert_trial_parity(b, v)


def test_eval_fn_cache_eviction_never_changes_results():
    """Regression for the old module-level FIFO dict: a capacity-1 LRU
    forced to evict and recompile must reproduce the identical accuracy."""
    from repro.experiments.runner import build_server
    from repro.federated.evaluation import EvalFnCache, Evaluator
    srv_a = build_server(tiny_spec())
    srv_b = build_server(tiny_spec(dataset="cifar100"))
    cache = EvalFnCache(capacity=1)
    ev_a = Evaluator(srv_a.model, srv_a.dataset, 128, fn_cache=cache)
    ev_b = Evaluator(srv_b.model, srv_b.dataset, 128, fn_cache=cache)
    pa = srv_a.model.init(jax.random.PRNGKey(0))
    pb = srv_b.model.init(jax.random.PRNGKey(0))
    first_a = ev_a.evaluate(pa)
    first_b = ev_b.evaluate(pb)          # evicts a's jitted fn
    assert len(cache) == 1
    assert ev_a.evaluate(pa) == first_a  # recompiled, identical result
    assert ev_b.evaluate(pb) == first_b
    with pytest.raises(ValueError):
        EvalFnCache(capacity=0)


# ---------------------------------------------------------------------------
# store: resume + table emission
# ---------------------------------------------------------------------------

def test_store_resume_skips_completed_keys(tmp_path):
    store = ResultStore(str(tmp_path / "r.jsonl"))
    specs = [tiny_spec(seed=s, rounds=2) for s in range(2)]
    run_sweep(specs, store=store)
    assert store.completed_keys() == {s.key() for s in specs}
    # a re-invocation would filter on completed_keys: nothing pending
    pending = [s for s in specs if s.key() not in store.completed_keys()]
    assert pending == []
    # corrupt tail (killed mid-write) is skipped, earlier records survive
    with open(store.path, "a") as f:
        f.write('{"key": "trunc')
    assert len(store.load()) == 2


def test_paper_table_reports_fedtune_vs_fixed(tmp_path):
    store = ResultStore(str(tmp_path / "t.jsonl"))
    specs = [tiny_spec(rounds=2),
             tiny_spec(rounds=2, tuner="fixed",
                       preference=CANONICAL_PREFERENCE)]
    run_sweep(specs, store=store)
    table = paper_table(store.load())
    assert "emnist" in table and "fedavg" in table and "%" in table
    # unpaired records tabulate to nothing, not an error
    assert "no fedtune" in paper_table([])


def test_store_resume_covers_event_trials(tmp_path):
    """Async trials run through run_sweep land in the store and resume by
    key exactly like sync ones."""
    store = ResultStore(str(tmp_path / "a.jsonl"))
    specs = [tiny_spec(seed=s, mode="async", rounds=2) for s in range(2)]
    res = run_sweep(specs, store=store)
    assert all(r.engine.startswith("vectorized-events") for r in res)
    assert store.completed_keys() == {s.key() for s in specs}


# ---------------------------------------------------------------------------
# fleet-profile axes + het-aware / legacy-tolerant table emission
# ---------------------------------------------------------------------------

def test_sweep_hets_axis_expands_and_keys_distinct():
    sweep = SweepSpec(datasets=("emnist",), aggregators=("fedavg",),
                      preferences=parse_preferences("14"), seeds=(0,),
                      hets=("homogeneous", "stragglers"), base=tiny_spec())
    specs = sweep.expand()
    # (fedtune + fixed) x 2 profiles, all distinct keys
    assert len(specs) == 4
    assert {s.het for s in specs} == {"homogeneous", "stragglers"}
    assert len({s.key() for s in specs}) == 4


def _fake_record(spec, cost, drop_spec_keys=()):
    d = spec.to_dict()
    for k in drop_spec_keys:
        d.pop(k, None)
    return {"key": spec.key(), "status": "done",
            "baseline_key": spec.baseline_key(), "spec": d,
            "reached": False, "rounds": spec.rounds,
            "final_accuracy": 0.4, "final_m": spec.m0, "final_e": spec.e0,
            "cost": cost, "sim_time": 1.0, "wall": 0.1, "engine": "test",
            "history_m": [], "history_e": [], "history_acc": []}


def test_paper_table_renders_het_profile_columns():
    rows = []
    for het in ("homogeneous", "stragglers"):
        tuned = tiny_spec(het=het)
        fixed = tiny_spec(het=het, tuner="fixed",
                          preference=CANONICAL_PREFERENCE)
        rows.append(_fake_record(tuned, [80.0, 80.0, 80.0, 80.0]))
        rows.append(_fake_record(fixed, [100.0, 100.0, 100.0, 100.0]))
    table = paper_table(rows)
    assert "fedavg·homogeneous" in table
    assert "fedavg·stragglers" in table


def test_sweep_compressions_axis_expands_and_keys_distinct():
    sweep = SweepSpec(datasets=("emnist",), aggregators=("fedavg",),
                      preferences=parse_preferences("14"), seeds=(0,),
                      compressions=(None, "int8"), base=tiny_spec())
    specs = sweep.expand()
    # (fedtune + fixed) x 2 compression methods, all distinct keys
    assert len(specs) == 4
    assert {s.compression for s in specs} == {None, "int8"}
    assert len({s.key() for s in specs}) == 4
    # "none" normalizes to None so keys stay stable across spellings
    alias = SweepSpec(datasets=("emnist",), aggregators=("fedavg",),
                      preferences=parse_preferences("14"), seeds=(0,),
                      compressions=("none", "int8"), base=tiny_spec())
    assert {s.key() for s in alias.expand()} == {s.key() for s in specs}


def test_paper_table_renders_compression_columns():
    rows = []
    for comp in (None, "int8"):
        tuned = tiny_spec(compression=comp)
        fixed = tiny_spec(compression=comp, tuner="fixed",
                          preference=CANONICAL_PREFERENCE)
        rows.append(_fake_record(tuned, [80.0, 80.0, 80.0, 80.0]))
        rows.append(_fake_record(fixed, [100.0, 100.0, 100.0, 100.0]))
    table = paper_table(rows)
    assert "fedavg·int8" in table
    assert "fedavg·none" in table
    # legacy rows without the compression field tabulate as uncompressed
    legacy = [_fake_record(tiny_spec(), [80.0] * 4,
                           drop_spec_keys=("compression",)),
              _fake_record(tiny_spec(tuner="fixed",
                                     preference=CANONICAL_PREFERENCE),
                           [100.0] * 4, drop_spec_keys=("compression",))]
    assert "fedavg" in paper_table(legacy)


def test_paper_table_tolerates_legacy_rows_missing_het():
    """Records written before the het/preference fields existed (pre-PR-4
    stores) must tabulate under the defaults, not KeyError."""
    tuned = tiny_spec()
    fixed = tiny_spec(tuner="fixed", preference=CANONICAL_PREFERENCE)
    rows = [_fake_record(tuned, [80.0] * 4,
                         drop_spec_keys=("het", "preference")),
            _fake_record(fixed, [100.0] * 4, drop_spec_keys=("het",))]
    table = paper_table(rows)
    assert "fedavg" in table and "%" in table
    # and a record with no spec dict at all is skipped, not fatal
    assert "no fedtune" in paper_table([{"key": "x", "status": "done",
                                         "cost": [1, 1, 1, 1]}])
