"""Benchmark: vectorized T-trials-at-once vs T sequential FLServer.run().

The sweep engine's claim is that trials are an *axis*, not a queue: packing
every live trial's cohort into one scan/vmap amortizes the per-step
dispatch overhead that dominates T independent runs on small FL models —
and, since the stacked evaluation subsystem, the per-aggregation evals of
all live trials execute as one dispatch too.  This benchmark runs the same
T-trial grid (emnist-reduced, FedTune over the paper's preference vectors
so all trials share one dataset and one test set) both ways and reports
wall-clock, phase split, speedup, and parity:

  sequential — T full ``FLServer.run()`` calls, one after another (the
               pre-sweep-engine workflow)
  vectorized — ``run_vectorized`` packing all T trials: per virtual round
               (sync) or per merged-event-queue macro-step with one
               arrival-lane per trial (``--mode async|buffered``)

Wall-clock is split into ``train_s`` (cohort/client training dispatches),
``eval_s`` (accuracy dispatches), and ``other_s`` (host orchestration)
through the ``repro.perf`` counters, so the eval-amortization win of the
stacked evaluator is visible separately from the training win.
``--compression int8`` runs the same grid with upload-compressed trials —
they vectorize lane-wise, so ``sequential_trials`` must stay 0.

Both engines are warmed once (same shapes, so the second run measures
steady state, not XLA compilation) and parity is checked on the per-trial
round records: identical accuracies, costs, FedTune (M, E) trajectories —
and, for the event-driven modes, identical dispatch and staleness logs ==
the vectorized engine is a faithful T-way replica.

Emits the usual CSV rows plus one BENCH-format JSON line (and ``--json``
writes it to a file for CI artifact upload):

  BENCH {"bench": "sweep_engine", "mode": "sync", "t": 8, "seq_s": ...,
         "vec_s": ..., "speedup": ..., "bitmatch": true,
         "train_s": ..., "eval_s": ..., "other_s": ...,
         "seq_phases": {...}, "vec_phases": {...},
         "occupancy": ..., "padding_waste": ..., "phase_calls": {...},
         "sequential_trials": 0, ...}

The timed vectorized run executes under the observability subsystem
(repro.obs, parity-neutral): ``occupancy`` is the mean fraction of the T
lanes still live per macro-step, ``padding_waste`` the fraction of packed
cohort steps spent on pow2 padding, and ``phase_calls`` the number of
train/eval dispatches behind the phase seconds (the amortization factor).

Usage: PYTHONPATH=src:. python benchmarks/sweep_engine.py [--t 8]
       [--rounds 4] [--mode async] [--compression int8]
       [--json sweep_bench.json]
"""

from __future__ import annotations

import argparse
import json
import time

from benchmarks.common import emit
from repro import obs, perf
from repro.core.preferences import PAPER_PREFERENCES
from repro.experiments import TrialSpec, run_trial, run_vectorized, serve


def _specs(t: int, rounds: int, mode: str, compression: str = None):
    # event-driven modes run E0=2.0: each arrival is one client's training,
    # so deeper local runs are the regime where packing arrivals pays.
    # Trials span the paper's preference vectors at one seed: they share a
    # dataset (and test set), so the stacked evaluator amortizes their
    # per-aggregation evals into one dispatch.
    e0 = 1.0 if mode == "sync" else 2.0
    return [TrialSpec(dataset="emnist", aggregator="fedavg", seed=0,
                      preference=PAPER_PREFERENCES[
                          s % len(PAPER_PREFERENCES)].as_tuple(),
                      tuner="fedtune", m0=10, e0=e0, rounds=rounds,
                      target_accuracy=0.99, batch_size=5, eval_points=256,
                      mode=mode, compression=compression)
            for s in range(t)]


def _staggered_specs(t: int, rounds: int, mode: str):
    """A staggered-target grid: round budgets cycle 1..rounds, so trials
    finish at different virtual times — the drain shape where a fixed
    pack idles lanes and continuous batching refills them."""
    e0 = 1.0 if mode == "sync" else 2.0
    return [TrialSpec(dataset="emnist", aggregator="fedavg", seed=0,
                      preference=PAPER_PREFERENCES[
                          s % len(PAPER_PREFERENCES)].as_tuple(),
                      tuner="fedtune", m0=10, e0=e0,
                      rounds=1 + s % rounds,
                      target_accuracy=0.99, batch_size=5, eval_points=256,
                      mode=mode)
            for s in range(t)]


def _run_sequential(specs):
    return [run_trial(s) for s in specs]


def _timed_phases(fn):
    """Run ``fn`` with fresh perf counters; returns (result, phase dict).
    Per-phase call counts ride along (``perf.calls`` was tracked but never
    exported before): for the vectorized engine they count packed cohort /
    stacked eval dispatches, for sequential per-client / per-trial calls —
    the amortization factor in one number."""
    perf.reset()
    t0 = time.perf_counter()
    res = fn()
    total = time.perf_counter() - t0
    train = perf.seconds("train")
    ev = perf.seconds("eval")
    return res, total, {
        "total_s": round(total, 4), "train_s": round(train, 4),
        "eval_s": round(ev, 4),
        "other_s": round(max(total - train - ev, 0.0), 4),
        "train_calls": perf.calls("train"), "eval_calls": perf.calls("eval")}


def main(settings=None, *, t: int = 8, rounds: int = 4, mode: str = "sync",
         pack: str = "batched", compression: str = None,
         json_path: str = None):
    del settings    # reduced scale only: the sweep is over T, not data size
    import jax
    specs = _specs(t, rounds, mode, compression)

    # warm both engines (compilation + dataset materialization), then time
    # the steady state — grids are deterministic, so shapes repeat exactly
    _run_sequential(specs)
    seq, seq_s, seq_phases = _timed_phases(lambda: _run_sequential(specs))

    run_vectorized(specs, pack=pack)
    # trace the timed vectorized run: occupancy and padding-waste land in
    # BENCH.  Instrumentation is per-round host-side bookkeeping (gated,
    # parity-neutral), so vec_s stays an honest engine timing.
    obs.enable()
    vec, vec_s, vec_phases = _timed_phases(
        lambda: run_vectorized(specs, pack=pack))
    snap = obs.registry.snapshot()
    lanes = [r["value"] for r in obs.registry.series("lanes_live")]
    obs.disable()
    occupancy = (sum(lanes) / len(lanes) / t) if lanes else 0.0
    steps_pad = snap["counters"].get("pack_steps_padded", 0.0)
    padding_waste = (1.0 - snap["counters"].get("pack_steps_real", 0.0)
                     / steps_pad) if steps_pad else 0.0

    bitmatch = True
    max_acc_diff = 0.0
    for b, v in zip(seq, vec):
        if (b.history_m, b.history_e) != (v.history_m, v.history_e):
            bitmatch = False
        for a, c in zip(b.history_acc, v.history_acc):
            d = abs(a - c)
            max_acc_diff = max(max_acc_diff, d)
            if d > 0:
                bitmatch = False
        if tuple(b.cost) != tuple(v.cost):
            bitmatch = False
        # event-driven modes: the full dispatch schedule and staleness
        # sequence must replay exactly too
        if (b.dispatch_log, b.staleness_log) != (v.dispatch_log,
                                                 v.staleness_log):
            bitmatch = False

    speedup = seq_s / vec_s if vec_s > 0 else float("inf")
    emit(f"sweep_engine/{mode}_sequential_t{t}", seq_s * 1e6, "baseline")
    emit(f"sweep_engine/{mode}_vectorized_t{t}", vec_s * 1e6,
         f"speedup_vs_seq={speedup:.2f}x")
    payload = {"bench": "sweep_engine", "mode": mode, "t": t,
               "rounds": rounds, "pack": pack,
               "compression": compression,
               "devices": jax.device_count(),
               "seq_s": round(seq_s, 4), "vec_s": round(vec_s, 4),
               "speedup": round(speedup, 3), "bitmatch": bitmatch,
               "max_acc_diff": max_acc_diff,
               # the vectorized run's phase split (+ both engines' full
               # splits): eval_s amortization is the stacked evaluator's win
               "train_s": vec_phases["train_s"],
               "eval_s": vec_phases["eval_s"],
               "other_s": vec_phases["other_s"],
               "seq_phases": seq_phases, "vec_phases": vec_phases,
               # observability of the timed vectorized run: mean live-lane
               # occupancy (fraction of T still running per macro-step) and
               # the pow2-padding waste of its packed cohort dispatches
               "occupancy": round(occupancy, 4),
               "padding_waste": round(padding_waste, 4),
               "phase_calls": {"train": vec_phases["train_calls"],
                               "eval": vec_phases["eval_calls"]},
               # compressed grids must vectorize: no trial may have taken
               # the one-at-a-time path
               "sequential_trials": sum(
                   not r.engine.startswith("vectorized") for r in vec)}
    print("BENCH " + json.dumps(payload), flush=True)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f)
            f.write("\n")
    return payload


def serve_main(*, t: int = 12, max_lanes: int = 4, rounds: int = 3,
               mode: str = "sync", pack: str = "batched",
               json_path: str = None):
    """Fixed-pack vs continuous-batching on a staggered-target grid.

    Three timed runs over the SAME t trials (round budgets cycling
    1..rounds so they finish at different times): sequential baseline,
    the fixed-set vectorized engine (its ``lanes_live`` occupancy decays
    as trials finish), and the continuous-batching scheduler with
    ``max_lanes`` lanes (its ``pool_occupancy`` stays near 1.0 until the
    queue runs dry).  Bitmatch compares every served trial against its
    sequential twin — admission order and lane reuse must never change a
    trial's floats."""
    import jax
    specs = _staggered_specs(t, rounds, mode)
    assert len({s.key() for s in specs}) == t, "staggered grid keys collide"

    _run_sequential(specs)
    seq, seq_s, seq_phases = _timed_phases(lambda: _run_sequential(specs))

    # fixed pack: all t trials admitted at once, lanes idle as they finish
    run_vectorized(specs, pack=pack)
    obs.enable()
    _fixed, fixed_s, fixed_phases = _timed_phases(
        lambda: run_vectorized(specs, pack=pack))
    lanes = [r["value"] for r in obs.registry.series("lanes_live")]
    obs.disable()
    occupancy_fixed = (sum(lanes) / len(lanes) / t) if lanes else 0.0

    # continuous batching: max_lanes lanes, freed slots refill mid-flight
    serve(list(specs), max_lanes=max_lanes, pack=pack)
    obs.enable()
    srv, serve_s, serve_phases = _timed_phases(
        lambda: serve(list(specs), max_lanes=max_lanes, pack=pack))
    occ = [r["value"] for r in obs.registry.series("pool_occupancy")]
    snap = obs.registry.snapshot()
    obs.disable()
    occupancy_serve = sum(occ) / len(occ) if occ else 0.0

    by_key = {r.spec.key(): r for r in srv}
    bitmatch = True
    max_acc_diff = 0.0
    for b in seq:
        v = by_key.get(b.spec.key())
        if v is None:
            bitmatch = False
            continue
        if (b.history_m, b.history_e) != (v.history_m, v.history_e):
            bitmatch = False
        for a, c in zip(b.history_acc, v.history_acc):
            d = abs(a - c)
            max_acc_diff = max(max_acc_diff, d)
            if d > 0:
                bitmatch = False
        if tuple(b.cost) != tuple(v.cost):
            bitmatch = False
        if (b.dispatch_log, b.staleness_log) != (v.dispatch_log,
                                                 v.staleness_log):
            bitmatch = False

    emit(f"sweep_engine/{mode}_fixed_pack_t{t}", fixed_s * 1e6,
         f"occupancy={occupancy_fixed:.2f}")
    emit(f"sweep_engine/{mode}_serve_t{t}_l{max_lanes}", serve_s * 1e6,
         f"occupancy={occupancy_serve:.2f}")
    payload = {"bench": "sweep_engine", "serve": True, "mode": mode,
               "t": t, "max_lanes": max_lanes, "rounds": rounds,
               "pack": pack, "devices": jax.device_count(),
               "seq_s": round(seq_s, 4), "fixed_s": round(fixed_s, 4),
               "serve_s": round(serve_s, 4),
               "speedup_vs_seq": round(seq_s / serve_s, 3) if serve_s else 0,
               "bitmatch": bitmatch, "max_acc_diff": max_acc_diff,
               # sustained lane occupancy: fixed pack over its t lanes vs
               # the scheduler's pool — the continuous-batching claim
               "occupancy_fixed": round(occupancy_fixed, 4),
               "occupancy_serve": round(occupancy_serve, 4),
               "trials_admitted": snap["counters"].get("trials_admitted", 0),
               "trials_retired": snap["counters"].get("trials_retired", 0),
               "seq_phases": seq_phases, "fixed_phases": fixed_phases,
               "serve_phases": serve_phases}
    print("BENCH " + json.dumps(payload), flush=True)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f)
            f.write("\n")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--t", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--mode", default="sync",
                    choices=("sync", "async", "buffered"),
                    help="runtime mode of the benchmarked trials (async/"
                         "buffered exercise the merged event-queue engine)")
    ap.add_argument("--pack", default="batched",
                    choices=("batched", "sharded"))
    ap.add_argument("--compression", default=None,
                    choices=(None, "none", "int8"),
                    help="upload compression for every trial (int8 trials "
                         "vectorize lane-wise)")
    ap.add_argument("--serve", action="store_true",
                    help="benchmark continuous batching: fixed-pack vs the "
                         "lane-pool scheduler on a staggered-target grid")
    ap.add_argument("--max-lanes", type=int, default=4,
                    help="scheduler lane-pool capacity for --serve")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    if args.serve:
        serve_main(t=args.t, max_lanes=args.max_lanes, rounds=args.rounds,
                   mode=args.mode, pack=args.pack, json_path=args.json)
    else:
        main(t=args.t, rounds=args.rounds, mode=args.mode, pack=args.pack,
             compression=None if args.compression in (None, "none")
             else args.compression,
             json_path=args.json)
