"""Paper Table 6: FedTune across aggregation algorithms."""

from __future__ import annotations

import numpy as np

from benchmarks.common import (BenchSettings, emit, fedtune_for, improvement,
                               run_fl)
from repro.core.preferences import PAPER_PREFERENCES


def main(settings: BenchSettings, prefs=None):
    prefs = prefs or PAPER_PREFERENCES[:6]
    for aggregator in ("fedavg", "fednova", "fedadagrad"):
        base = run_fl("emnist", settings, aggregator=aggregator)
        gains = []
        for pref in prefs:
            tuner = fedtune_for(pref, settings.m0, settings.e0)
            res = run_fl("emnist", settings, tuner=tuner,
                         aggregator=aggregator)
            gains.append(improvement(pref, base.total_cost, res.total_cost))
        emit(f"table6/{aggregator}", base.wall * 1e6,
             f"mean_gain={np.mean(gains):+.2f}%;std={np.std(gains):.2f};"
             f"base_acc={base.final_accuracy:.3f}")
