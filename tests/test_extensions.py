"""Beyond-paper extensions: participant selection, upload compression,
adaptive-step FedTune."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.costs import CostModel
from repro.core.fedtune import FedTune, FedTuneConfig
from repro.core.preferences import Preference
from repro.core.tuner import HyperParams
from repro.federated.compression import (compress_delta,
                                         compress_delta_lanes, lane_mask,
                                         upload_factor)
from repro.federated.selection import get_selector


def test_selectors_return_unique_valid_ids():
    rng = np.random.default_rng(0)
    sizes = rng.integers(1, 100, 64)
    for name in ("random", "guided", "smallest"):
        sel = get_selector(name, 64, rng, client_sizes=sizes)
        ids = sel.select(10)
        assert len(np.unique(ids)) == len(ids)
        assert ids.min() >= 0 and ids.max() < 64


def test_guided_prefers_high_loss_clients():
    rng = np.random.default_rng(0)
    sel = get_selector("guided", 20, rng)
    for cid in range(20):
        sel.update(cid, loss=10.0 if cid < 3 else 0.01, n_examples=10)
    picks = [set(sel.select(5)) for _ in range(10)]
    hits = sum(len({0, 1, 2} & p) for p in picks) / 10
    assert hits >= 2.5, "guided selection should exploit high-loss clients"


def test_smallest_selector_bounds_straggler():
    rng = np.random.default_rng(0)
    sizes = np.arange(1, 65)
    sel = get_selector("smallest", 64, rng, client_sizes=sizes)
    ids = sel.select(8)
    assert sizes[ids].max() <= 16  # picks from the small half


def test_int8_compression_roundtrip_close():
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (64, 32))}
    c = {"w": g["w"] + 0.01 * jax.random.normal(key, (64, 32))}
    rec = compress_delta(g, c, "int8")
    err = float(jnp.abs(rec["w"] - c["w"]).max())
    scale = float(jnp.abs(c["w"] - g["w"]).max())
    assert err <= scale / 100  # 127-level quantization of the delta


def _delta_scale(g, c):
    """The per-leaf quantization scale compress_delta uses."""
    return max(float(jnp.max(jnp.abs(c - g))) / 127.0, 1e-12)


def _roundtrip_properties(g, c):
    """The compress_delta contract on one (global, client) leaf pair:
    identity under method='none', exactness for zero deltas, per-element
    roundtrip error bounded by scale/2, and per-tree == lane-wise."""
    # idempotent under method="none" (and None): the client params object
    # passes through untouched
    assert compress_delta(g, c, "none") is c
    assert compress_delta(g, c, None) is c
    # exact for zero deltas (the 1e-12 scale clamp guards the 0/0)
    zero = compress_delta(g, g, "int8")
    for lg, lz in zip(jax.tree.leaves(g), jax.tree.leaves(zero)):
        np.testing.assert_array_equal(np.asarray(lg), np.asarray(lz))
    # roundtrip error <= scale/2 per element (+ one ulp of the
    # reconstruction for the float32 add g + deq)
    rec = compress_delta(g, c, "int8")
    for lg, lc, lr in zip(jax.tree.leaves(g), jax.tree.leaves(c),
                          jax.tree.leaves(rec)):
        scale = _delta_scale(lg, lc)
        err = np.abs(np.asarray(lr, np.float64) - np.asarray(lc, np.float64))
        tol = (scale * 0.5000001
               + np.spacing(np.abs(np.asarray(lc, np.float32))))
        assert np.all(err <= tol), (float(err.max()), scale)
    # bit-identical between the per-tree and vmapped lane-wise paths
    stack = jax.tree.map(lambda a, b: jnp.stack([a, b]), g, c)
    lanes = compress_delta_lanes(
        jax.tree.map(lambda a: jnp.stack([a, a]), g), stack)
    for lr, lz, ls in zip(jax.tree.leaves(rec), jax.tree.leaves(zero),
                          jax.tree.leaves(lanes)):
        np.testing.assert_array_equal(np.asarray(ls[0]), np.asarray(lz))
        np.testing.assert_array_equal(np.asarray(ls[1]), np.asarray(lr))


def test_compress_delta_roundtrip_properties():
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    g = {"w": jax.random.normal(k1, (33, 17)), "b": jax.random.normal(k2, (17,))}
    c = jax.tree.map(
        lambda x, n: x + 0.02 * n, g,
        {"w": jax.random.normal(k3, (33, 17)),
         "b": jnp.zeros((17,))})        # one leaf with a zero delta inside
    _roundtrip_properties(g, c)


def test_compress_delta_lane_mask_passthrough():
    """Masked-off lanes come back bit-identical to their inputs; masked-on
    lanes match the per-tree round trip; lane_mask validates methods and
    returns None when nothing compresses."""
    key = jax.random.PRNGKey(5)
    g = jax.random.normal(key, (4, 8, 3))
    c = g + 0.01 * jax.random.normal(jax.random.PRNGKey(6), (4, 8, 3))
    mask = lane_mask(["int8", None, "int8", "none"])
    np.testing.assert_array_equal(mask, [True, False, True, False])
    out = compress_delta_lanes({"w": g}, {"w": c}, mask)["w"]
    for i in range(4):
        ref = compress_delta({"w": g[i]}, {"w": c[i]}, "int8")["w"]
        expect = ref if mask[i] else c[i]
        np.testing.assert_array_equal(np.asarray(out[i]), np.asarray(expect))
    assert lane_mask([None, "none"]) is None
    with pytest.raises(ValueError, match="int8"):
        lane_mask(["int4"])


def test_compress_delta_property_fuzz():
    """Hypothesis sweep of the roundtrip contract over adversarial float
    patterns (huge/tiny scales, constant leaves, sign flips)."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis.extra import numpy as hnp

    @settings(max_examples=25, deadline=None)
    @given(
        base=hnp.arrays(np.float32, (5, 3),
                        elements=dict(min_value=-1e3, max_value=1e3,
                                      allow_nan=False, allow_infinity=False)),
        delta=hnp.arrays(np.float32, (5, 3),
                         elements=dict(min_value=-1.0, max_value=1.0,
                                       allow_nan=False,
                                       allow_infinity=False)),
        scale=hypothesis.strategies.sampled_from(
            [0.0, 1e-9, 1e-3, 1.0, 1e4]),
    )
    def check(base, delta, scale):
        g = {"w": jnp.asarray(base)}
        c = {"w": jnp.asarray(base + scale * delta)}
        _roundtrip_properties(g, c)

    check()


def test_upload_factor_reduces_translocost():
    cm_full = CostModel(1e6, 1e5)
    cm_comp = CostModel(1e6, 1e5)
    r1 = cm_full.add_round([10] * 5, 1.0, upload_factor=1.0)
    r2 = cm_comp.add_round([10] * 5, 1.0,
                           upload_factor=upload_factor("int8"))
    assert r2.trans_l < 0.7 * r1.trans_l
    assert r2.comp_l == r1.comp_l


def test_adaptive_step_fedtune_moves_faster():
    pref = Preference(0.0, 0.0, 1.0, 0.0)
    plain = FedTune(FedTuneConfig(preference=pref), HyperParams(20, 20))
    adaptive = FedTune(FedTuneConfig(preference=pref, adaptive_step=True),
                       HyperParams(20, 20))
    from repro.core.costs import SystemCost
    acc = 0.0
    hp_p = hp_a = HyperParams(20, 20)
    for r in range(12):
        acc += 0.02
        cost_p = SystemCost(1, 1, float(hp_p.m * hp_p.e) * 100, 1)
        cost_a = SystemCost(1, 1, float(hp_a.m * hp_a.e) * 100, 1)
        hp_p = plain.on_round(r, acc, cost_p, cost_p, hp_p)
        hp_a = adaptive.on_round(r, acc, cost_a, cost_a, hp_a)
    assert hp_a.m + hp_a.e <= hp_p.m + hp_p.e
