"""Suppression fixture: a noqa WITHOUT a justification does not
suppress — the finding is kept and annotated."""


def probe(fn):
    try:
        return fn()
    except Exception:  # noqa: REPRO007
        return None
