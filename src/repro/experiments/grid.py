"""Sweep grids: TrialSpec (one FL training, fully determined) and SweepSpec
(a product grid over the paper's experiment axes).

A TrialSpec pins EVERYTHING a trial needs — dataset, aggregator, preference
vector, seed, tuner, runtime mode, (M0, E0), rounds — so its ``key()`` is a
stable resume handle: re-running a sweep skips every key already present in
the result store.  Validation is EAGER and round-trips through the real
constructors (``get_aggregator``, ``RuntimeConfig``, ``Preference``,
``upload_factor``, ``get_profile``): an unknown aggregator or client-exec
name raises a ValueError naming the valid options at grid-expansion time,
not minutes into trial 37.

``SweepSpec.expand()`` is the product over
    preferences x aggregators x datasets x seeds x (M0, E0) x tuners
    x runtime modes x fleet profiles,
with one reduction: fixed-tuner (baseline) trials ignore the preference
vector, so the preference axis is collapsed to ``CANONICAL_PREFERENCE`` for
them and duplicates are dropped — T fedtune trials share one fixed baseline
per (dataset, aggregator, seed, M0, E0) cell, exactly how the paper's
tables normalize.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field, fields, replace
from typing import List, Optional, Sequence, Tuple

from repro.core.preferences import PAPER_PREFERENCES, Preference

VALID_DATASETS = ("speech_command", "emnist", "cifar100")
VALID_TUNERS = ("fedtune", "fixed")
CANONICAL_PREFERENCE = (0.25, 0.25, 0.25, 0.25)


@dataclass(frozen=True)
class TrialSpec:
    """One FL training, fully determined — the unit of sweep work.

    Result-bearing fields (all part of ``key()``):
      dataset     — synthetic federation family: speech_command | emnist
                    | cifar100 (``reduced`` selects the small CI variant).
      aggregator  — server aggregation: fedavg | fedprox | fednova |
                    fedadagrad | fedadam | fedyogi.
      preference  — the paper's (α, β, γ, δ) weights over CompT/TransT/
                    CompL/TransL; must sum to 1.
      seed        — drives model init, server rng (selection + batch
                    order), system rng, and fleet sampling.
      tuner       — fedtune (Alg. 1 controller) | fixed (the baseline
                    the tables normalize against).
      mode        — runtime regime: sync | async (FedAsync) | buffered
                    (FedBuff).
      het         — fleet heterogeneity profile: homogeneous | mild |
                    stragglers | mobile (runtime/profiles.py).
      m0, e0      — initial participants per round / local passes: the
                    (M, E) pair FedTune tunes from.
      rounds      — max rounds (sync) or max aggregations (async/
                    buffered); target_accuracy stops a trial early.
      compression — None | 'int8' upload deltas; compressed trials
                    vectorize like any others (the quantize->dequantize
                    round trip is a per-lane transform in the cohort
                    packers).
      failure_rate— per-dispatch hard-failure hazard in [0, 1); nonzero
                    arms the coordinator's retry/reassignment policy
                    (runtime/engine.py). 0 keeps keys and results
                    bit-identical to pre-failure runs.
      churn       — fleet membership schedule "period:rate[:min_active]"
                    (runtime/profiles.ChurnSchedule) or None.

    Execution-only fields (absent from ``key()`` because every backend is
    result-parity-equal, pinned in tests): ``client_exec``.
    """
    dataset: str = "emnist"
    aggregator: str = "fedavg"
    preference: Tuple[float, float, float, float] = CANONICAL_PREFERENCE
    seed: int = 0
    tuner: str = "fedtune"              # fedtune | fixed
    mode: str = "sync"                  # runtime mode (sync|async|buffered)
    client_exec: str = "sequential"     # sequential-engine backend
    het: str = "homogeneous"            # fleet heterogeneity profile
    m0: int = 5
    e0: float = 2.0
    rounds: int = 30
    target_accuracy: float = 0.5
    batch_size: int = 10
    prox_mu: float = 0.0
    compression: Optional[str] = None
    reduced: bool = True
    eval_points: int = 512
    lr: float = 0.03
    failure_rate: float = 0.0           # per-dispatch hard-failure hazard
    churn: Optional[str] = None         # "period:rate[:min_active]" schedule

    # ------------------------------------------------------------------
    def validate(self) -> "TrialSpec":
        """Raise ValueError (naming the valid options) on any axis value the
        real constructors would reject.  Returns self so expansion can chain
        ``spec.validate()``."""
        from repro.federated.aggregation import get_aggregator
        from repro.federated.compression import upload_factor
        from repro.runtime.engine import RuntimeConfig
        from repro.runtime.profiles import PROFILES

        if self.dataset not in VALID_DATASETS:
            raise ValueError(f"unknown dataset {self.dataset!r}; valid "
                             "datasets: " + ", ".join(VALID_DATASETS))
        if self.tuner not in VALID_TUNERS:
            raise ValueError(f"unknown tuner {self.tuner!r}; valid tuners: "
                             + ", ".join(VALID_TUNERS))
        if self.het != "homogeneous" and self.het not in PROFILES:
            raise ValueError(f"unknown het profile {self.het!r}; valid "
                             "profiles: homogeneous, "
                             + ", ".join(sorted(PROFILES)))
        get_aggregator(self.aggregator)                  # ValueError w/ names
        RuntimeConfig(mode=self.mode, client_exec=self.client_exec)
        upload_factor(self.compression)
        try:
            Preference(*self.preference)
        except AssertionError as e:
            raise ValueError(f"bad preference {self.preference}: {e}") from None
        if self.rounds < 1 or self.m0 < 1 or self.e0 <= 0:
            raise ValueError(f"bad (rounds={self.rounds}, m0={self.m0}, "
                             f"e0={self.e0}); all must be positive")
        if not 0.0 <= self.failure_rate < 1.0:
            raise ValueError(f"bad failure_rate {self.failure_rate}; "
                             "must be in [0, 1)")
        if self.churn is not None:
            from repro.runtime.profiles import ChurnSchedule
            ChurnSchedule.from_string(self.churn)    # ValueError on bad spec
        return self

    # ------------------------------------------------------------------
    def key(self) -> str:
        """Stable trial identity — the resume handle in the result store.
        Every field that changes a trial's RESULTS is in the key;
        ``client_exec`` is deliberately absent because the execution
        backends are result-parity-equal (pinned in tests)."""
        p = ",".join(f"{v:g}" for v in self.preference)
        parts = [
            f"ds={self.dataset}", f"agg={self.aggregator}", f"pref={p}",
            f"seed={self.seed}", f"tuner={self.tuner}", f"mode={self.mode}",
            f"het={self.het}", f"m0={self.m0}", f"e0={self.e0:g}",
            f"rounds={self.rounds}", f"target={self.target_accuracy:g}",
            f"bs={self.batch_size}", f"lr={self.lr:g}",
            f"ev={self.eval_points}",
            f"red={int(self.reduced)}",
        ]
        if self.prox_mu:
            parts.append(f"mu={self.prox_mu:g}")
        if self.compression:
            parts.append(f"comp={self.compression}")
        # fault axes append only when enabled: pre-existing keys stay stable
        if self.failure_rate:
            parts.append(f"fail={self.failure_rate:g}")
        if self.churn:
            parts.append(f"churn={self.churn}")
        return "|".join(parts)

    def baseline_key(self) -> str:
        """Key of this trial's FixedTuner twin (the paper's normalization
        baseline): same cell, tuner=fixed, canonical preference."""
        return replace(self, tuner="fixed",
                       preference=CANONICAL_PREFERENCE).key()

    @property
    def is_baseline(self) -> bool:
        return self.tuner == "fixed"

    def preference_obj(self) -> Preference:
        return Preference(*self.preference)

    def to_dict(self) -> dict:
        return {f.name: getattr(self, f.name) for f in fields(self)}


def spec_from_dict(d: dict) -> TrialSpec:
    names = {f.name for f in fields(TrialSpec)}
    kw = {k: v for k, v in d.items() if k in names}
    if "preference" in kw:
        kw["preference"] = tuple(kw["preference"])
    return TrialSpec(**kw)


# ---------------------------------------------------------------------------
# sweep grids
# ---------------------------------------------------------------------------

@dataclass
class SweepSpec:
    """Product grid over the experiment axes.  ``inits`` carries the
    (M0, E0) axis as pairs; ``modes`` spans the runtime regimes
    (sync/async/buffered), ``hets`` the fleet heterogeneity profiles
    (homogeneous/mild/stragglers/mobile — see runtime/profiles.py), and
    ``compressions`` the upload-compression methods (None/'int8'), so one
    grid can cover the paper's aggregator rows ACROSS runtime regimes,
    device fleets, and upload budgets.  Any axis left at its default
    contributes a single column, keeping pre-existing store keys stable."""
    datasets: Sequence[str] = ("emnist",)
    aggregators: Sequence[str] = ("fedavg",)
    preferences: Sequence[Tuple[float, float, float, float]] = (
        CANONICAL_PREFERENCE,)
    seeds: Sequence[int] = (0,)
    tuners: Sequence[str] = VALID_TUNERS
    inits: Sequence[Tuple[int, float]] = ((5, 2.0),)
    modes: Sequence[str] = ("sync",)
    hets: Sequence[str] = ("homogeneous",)
    compressions: Sequence[Optional[str]] = (None,)
    base: TrialSpec = field(default_factory=TrialSpec)   # shared settings

    def expand(self) -> List[TrialSpec]:
        """The validated product grid, fixed-baseline duplicates collapsed.
        Order is deterministic (itertools.product over the given axis
        order), so ``--limit N`` resume prefixes are stable."""
        seen = {}
        for ds, agg, pref, seed, tn, (m0, e0), mode, het, comp in \
                itertools.product(
                    self.datasets, self.aggregators, self.preferences,
                    self.seeds, self.tuners, self.inits, self.modes,
                    self.hets, self.compressions):
            if tn == "fixed":
                pref = CANONICAL_PREFERENCE   # baseline ignores preference
            if comp in (None, "none"):
                comp = None                   # one spelling, stable keys
            spec = replace(self.base, dataset=ds, aggregator=agg,
                           preference=tuple(pref), seed=seed, tuner=tn,
                           m0=m0, e0=e0, mode=mode, het=het,
                           compression=comp).validate()
            seen.setdefault(spec.key(), spec)
        return list(seen.values())


def parse_preferences(text: str) -> List[Tuple[float, float, float, float]]:
    """CLI preference parsing: 'all' -> the paper's 15 vectors; '0,4,14' ->
    indices into PAPER_PREFERENCES; '1,0,0,0;0.25,0.25,0.25,0.25' ->
    literal quads separated by ';'.

    A bare 4-element comma list is ambiguous (four indices or one quad);
    quads must sum to 1, so it parses as a quad only when it does —
    '1,0,0,0' is the first paper vector, '0,1,4,14' is four indices."""
    text = text.strip()
    if text == "all":
        return [p.as_tuple() for p in PAPER_PREFERENCES]

    def quads() -> List[Tuple[float, float, float, float]]:
        out = []
        for quad in text.split(";"):
            vals = tuple(float(v) for v in quad.split(","))
            if len(vals) != 4:
                raise ValueError(f"preference {quad!r} is not a quad")
            out.append(vals)
        return out

    if ";" in text:
        return quads()
    if text.count(",") == 3 and abs(sum(
            float(v) for v in text.split(",")) - 1.0) < 1e-6:
        return quads()
    out = []
    for idx in text.split(","):
        i = int(idx)
        if not 0 <= i < len(PAPER_PREFERENCES):
            raise ValueError(f"preference index {i} out of range 0.."
                             f"{len(PAPER_PREFERENCES) - 1}")
        out.append(PAPER_PREFERENCES[i].as_tuple())
    return out
