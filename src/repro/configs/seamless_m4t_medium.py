"""seamless-m4t-medium — enc-dec, 12L d_model=1024 16H (kv=16, MHA) d_ff=4096
vocab=256206, multimodal (speech).  The audio frontend (mel + conv feature
extractor) is a STUB: ``input_specs`` provides precomputed frame embeddings.
[arXiv:2308.11596]"""

from repro.configs.base import (EncoderConfig, FrontendConfig, ModelConfig,
                                uniform_layers)

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256_206,
    layers=uniform_layers(12),
    encoder=EncoderConfig(n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
                          d_ff=4096, head_dim=64),
    frontend=FrontendConfig(kind="audio_frames", seq_len=1024, feature_dim=1024),
    tie_embeddings=True,
    source="arXiv:2308.11596",
)
