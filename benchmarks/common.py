"""Shared benchmark plumbing.

All FL benchmarks run at REDUCED scale by default so the whole suite
finishes on one CPU core (synthetic reduced datasets, small MLP/ResNet);
pass ``--full`` to benchmarks.run for paper-scale settings.  Every benchmark
prints ``name,us_per_call,derived`` CSV rows via ``emit``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np

from repro.configs.paper_models import MLPConfig
from repro.core import CostModel, FedTune, FedTuneConfig, Preference
from repro.core.tuner import HyperParams, Tuner
from repro.data import (cifar100_like, emnist_like, speech_command_like)
from repro.federated import FLConfig, FLServer, get_aggregator
from repro.models import build_model
from repro.optim.optimizers import get_optimizer


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


DATASETS = {
    "speech_command": speech_command_like,
    "emnist": emnist_like,
    "cifar100": cifar100_like,
}


@dataclass
class BenchSettings:
    full: bool = False
    seeds: int = 1
    max_rounds: int = 120
    target_accuracy: float = 0.5
    m0: int = 5
    e0: float = 2.0
    lr: float = 0.03
    batch_size: int = 10


def small_model(dataset_name: str, reduced: bool = True):
    """The benchmark workhorse: a small MLP sized to the dataset."""
    shapes = {"speech_command": (16 * 16, 10), "emnist": (28 * 28, 16),
              "cifar100": (16 * 16 * 3, 20)}
    in_dim, n_classes = shapes[dataset_name]
    cfg = MLPConfig(name=f"mlp_{dataset_name}", in_dim=in_dim,
                    hidden=(48,), n_classes=n_classes)
    return build_model(cfg)


def run_fl(dataset_name: str, settings: BenchSettings, *,
           tuner: Optional[Tuner] = None, aggregator: str = "fedavg",
           m: Optional[int] = None, e: Optional[float] = None,
           seed: int = 0, model=None, target: Optional[float] = None,
           max_rounds: Optional[int] = None):
    ds = DATASETS[dataset_name](reduced=not settings.full, seed=seed)
    model = model or small_model(dataset_name)
    n_params = sum(p.size for p in jax.tree.leaves(
        model.init(jax.random.PRNGKey(0))))
    flops = model.flops_per_example or 2 * n_params
    cm = CostModel(flops_per_example=flops, param_count=n_params)
    server = FLServer(
        model, ds, get_aggregator(aggregator),
        get_optimizer("sgd", settings.lr, momentum=0.9), cm,
        FLConfig(m=m if m is not None else settings.m0,
                 e=e if e is not None else settings.e0,
                 batch_size=settings.batch_size,
                 target_accuracy=target if target is not None
                 else settings.target_accuracy,
                 max_rounds=max_rounds or settings.max_rounds,
                 eval_points=512, seed=seed),
        tuner=tuner)
    t0 = time.perf_counter()
    res = server.run()
    res.wall = time.perf_counter() - t0
    return res


def fedtune_for(pref: Preference, m0: int, e0: float, *,
                penalty: float = 10.0, adaptive: bool = False) -> FedTune:
    return FedTune(FedTuneConfig(preference=pref, penalty=penalty,
                                 adaptive_step=adaptive),
                   HyperParams(m0, e0))


def improvement(pref: Preference, fixed_cost, tuned_cost) -> float:
    """Positive percentage = FedTune reduced the weighted overhead
    (paper's '+x%' convention = -I(fixed, tuned) * 100)."""
    return -100.0 * tuned_cost.weighted_relative_to(fixed_cost, pref)
