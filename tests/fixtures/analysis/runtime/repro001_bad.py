"""BAD fixture: the PR 5 eager-FMA incident pattern, re-introduced.

``compression._roundtrip_leaf`` once ran ``g * scale`` eagerly on one
engine and under jit on the other — XLA's FMA contraction made the two
paths differ in the last bit and broke the sweep-vs-independent parity
pin.  Everything arithmetic-on-params here is eager, so REPRO001 must
fire.  (Fixture files are parsed, never imported.)
"""

import jax
import jax.numpy as jnp

SCALE = 127.0


def roundtrip_delta(delta):
    q = jnp.round(delta * SCALE)        # REPRO001: eager mult on a delta
    return q / SCALE


def apply_update(global_params, delta):
    # REPRO001: eager tree.map arithmetic over params
    return jax.tree.map(lambda p, d: p + d, global_params, delta)
