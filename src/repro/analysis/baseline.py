"""Baseline ratchet: accepted findings that don't fail the build.

The checked-in baseline (``tools/analysis_baseline.json`` in CI, the
packaged ``baseline.json`` by default) lists findings that predate the
analyzer.  A run fails only on findings *not* in the baseline, so the
count can only ratchet down: fix a baselined finding and it simply
disappears; introduce a new one and CI goes red.  Identity is
``(path, rule, message)`` — line numbers shift too easily to key on.

This repo's baseline is empty by policy: every finding at introduction
time was either fixed or carries a justified ``# noqa``.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import List, Set, Tuple

from .core import AnalysisResult, Finding

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"

BASELINE_VERSION = 1


class BaselineError(Exception):
    """Unreadable/invalid baseline file (exit code 2 territory)."""


def load_baseline(path: Path) -> Set[Tuple[str, str, str]]:
    try:
        doc = json.loads(path.read_text(encoding="utf-8"))
    except (OSError, json.JSONDecodeError) as e:
        raise BaselineError(f"cannot read baseline {path}: {e}") from e
    if not isinstance(doc, dict) or "findings" not in doc:
        raise BaselineError(
            f"baseline {path} must be an object with a 'findings' list")
    keys: Set[Tuple[str, str, str]] = set()
    for entry in doc["findings"]:
        try:
            keys.add((entry["path"], entry["rule"], entry["message"]))
        except (TypeError, KeyError) as e:
            raise BaselineError(
                f"baseline {path}: malformed entry {entry!r}") from e
    return keys


def new_findings(result: AnalysisResult,
                 baseline: Set[Tuple[str, str, str]]) -> List[Finding]:
    return [f for f in result.findings if f.baseline_key() not in baseline]


def render_baseline(result: AnalysisResult) -> str:
    """A baseline document accepting the current findings (for
    bootstrapping a ratchet on a tree with pre-existing findings)."""
    doc = {
        "version": BASELINE_VERSION,
        "findings": [
            {"path": f.path, "rule": f.rule, "message": f.message}
            for f in result.findings
        ],
    }
    return json.dumps(doc, sort_keys=True, indent=2) + "\n"
