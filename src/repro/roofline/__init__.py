from repro.roofline.analysis import RooflineReport, analyze_compiled
from repro.roofline.hardware import TPU_V5E
from repro.roofline.kernels import (KernelTraffic, fed_reduce_traffic,
                                    fed_reduce_separate_traffic)

__all__ = ["RooflineReport", "analyze_compiled", "TPU_V5E",
           "KernelTraffic", "fed_reduce_traffic",
           "fed_reduce_separate_traffic"]
