"""Allowlist fixture: the same wall-clock call under an ``obs/`` path.

The tracer's whole job is measuring host wall time, so REPRO004 must
stay silent here even though the call would be flagged under
``runtime/``.
"""

import time


def span_start():
    return time.perf_counter()
