"""GOOD fixture: the same math, but every param op runs under a trace.

Exercises all three traced-scope resolutions: a ``@jax.jit`` decorator,
a function wrapped by ``jax.vmap(...)``, and a helper whose only call
site is jitted (the call-graph rule).  Also pins the count/size name
exclusions: ``n_params`` arithmetic is host bookkeeping, not array math.
"""

import jax
import jax.numpy as jnp

SCALE = 127.0


def _scaled(delta):
    # no decorator — traced because its only call site is jitted
    return delta * SCALE


@jax.jit
def tree_roundtrip(delta):
    return jnp.round(_scaled(delta)) / SCALE


def _leaf_op(delta):
    # traced because it is handed to jax.vmap below
    return delta * SCALE


@jax.jit
def lane_roundtrip(deltas):
    return jax.vmap(_leaf_op)(deltas)


def report(n_params):
    # count-flavored names are host ints, not parameter arrays
    return n_params * 4 + 1
