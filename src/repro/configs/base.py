"""Model configuration system.

A single ``ModelConfig`` dataclass describes every architecture family the
framework supports (dense / MoE / hybrid-recurrent / ssm / audio enc-dec /
VLM).  Each layer is described by a ``LayerSpec`` (sequence mixer + ffn kind),
so heterogeneous block patterns (RecurrentGemma's 1:2 RG-LRU:attention,
xLSTM's sLSTM/mLSTM alternation, Gemma-2's local/global alternation) are
first-class rather than special-cased.

Configs are *static* pytree-free dataclasses: they are hashable and can be
closed over by jit'd functions without retracing hazards.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer-level specs
# ---------------------------------------------------------------------------

# Sequence-mixer kinds.
MIX_ATTN = "attn"          # (optionally windowed) self attention
MIX_RGLRU = "rglru"        # RecurrentGemma RG-LRU recurrent block
MIX_MLSTM = "mlstm"        # xLSTM matrix-memory LSTM
MIX_SLSTM = "slstm"        # xLSTM scalar-memory LSTM

# Feed-forward kinds.
FFN_DENSE = "dense"        # gated (SwiGLU/GeGLU) MLP
FFN_MOE = "moe"            # top-k mixture of experts
FFN_NONE = "none"          # mixer-only block (e.g. xLSTM blocks)


@dataclass(frozen=True)
class LayerSpec:
    """One transformer block: a sequence mixer plus a feed-forward."""

    mixer: str = MIX_ATTN
    ffn: str = FFN_DENSE
    # Attention window (tokens). None = full causal attention.
    window: Optional[int] = None

    def __post_init__(self):
        assert self.mixer in (MIX_ATTN, MIX_RGLRU, MIX_MLSTM, MIX_SLSTM), self.mixer
        assert self.ffn in (FFN_DENSE, FFN_MOE, FFN_NONE), self.ffn


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    # load-balancing auxiliary loss coefficient (Switch-style)
    aux_loss_coef: float = 0.01
    router_jitter: float = 0.0


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for encoder-decoder models (seamless-m4t)."""

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    head_dim: int


@dataclass(frozen=True)
class FrontendConfig:
    """Stubbed modality frontend (audio conv-codec / ViT are NOT implemented;
    ``input_specs`` provides precomputed frame/patch embeddings)."""

    kind: str                 # "audio_frames" | "vision_patches"
    seq_len: int              # number of frames / patches
    feature_dim: int          # embedding dim delivered by the (stub) frontend


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    layers: Tuple[LayerSpec, ...] = ()
    moe: Optional[MoEConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[FrontendConfig] = None

    qkv_bias: bool = False
    o_bias: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    act: str = "silu"

    # RG-LRU / recurrent-block parameters (hybrid family)
    lru_width: Optional[int] = None
    conv1d_width: int = 4

    # xLSTM parameters (ssm family)
    xlstm_proj_factor: float = 2.0

    # Serving: window used when forcing a long-context sliding-window variant
    # onto a full-attention architecture (documented beyond-paper adaptation).
    long_context_window: int = 4096

    source: str = ""          # citation for the architecture

    # ------------------------------------------------------------------
    def __post_init__(self):
        if not self.layers:
            object.__setattr__(
                self, "layers", tuple(LayerSpec() for _ in range(self.n_layers))
            )
        assert len(self.layers) == self.n_layers, (
            f"{self.name}: len(layers)={len(self.layers)} != n_layers={self.n_layers}"
        )
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, "GQA requires n_heads % n_kv == 0"
        if any(l.ffn == FFN_MOE for l in self.layers):
            assert self.moe is not None, f"{self.name}: MoE layers need moe config"

    # ------------------------------------------------------------------
    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder is not None

    @property
    def uses_attention(self) -> bool:
        return any(l.mixer == MIX_ATTN for l in self.layers)

    @property
    def subquadratic(self) -> bool:
        """True iff no layer performs *full* (unwindowed) attention."""
        return all(l.mixer != MIX_ATTN or l.window is not None for l in self.layers)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        n = self.vocab_size * self.d_model  # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for spec in self.layers:
            n += self._mixer_params(spec)
            n += self._ffn_params(spec)
            n += 2 * self.d_model  # two rmsnorm scales
        n += self.d_model  # final norm
        if self.encoder is not None:
            e = self.encoder
            per_layer = (
                2 * e.d_model * e.n_heads * e.head_dim
                + 2 * e.d_model * e.n_kv_heads * e.head_dim
                + 3 * e.d_model * e.d_ff
                + 2 * e.d_model
            )
            n += e.n_layers * per_layer + e.d_model
            # decoder cross-attention (one per decoder layer)
            n += self.n_layers * (
                2 * self.d_model * self.n_heads * self.head_dim
                + 2 * e.d_model * self.n_kv_heads * self.head_dim
                + self.d_model
            )
        return n

    def _mixer_params(self, spec: LayerSpec) -> int:
        d, hd = self.d_model, self.head_dim
        if spec.mixer == MIX_ATTN:
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            bias = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
            return q + kv + o + bias
        if spec.mixer == MIX_RGLRU:
            w = self.lru_width or d
            # in/out proj (x2 for gate branch), conv1d, RG-LRU gates
            return 2 * d * w + w * d + self.conv1d_width * w + 3 * w
        if spec.mixer in (MIX_MLSTM, MIX_SLSTM):
            w = int(d * self.xlstm_proj_factor)
            # up-proj (x2), qkv-like projections, gates, down-proj
            return 2 * d * w + 3 * w * w // max(self.n_heads, 1) + 6 * w + w * d
        raise ValueError(spec.mixer)

    def _ffn_params(self, spec: LayerSpec) -> int:
        d = self.d_model
        if spec.ffn == FFN_DENSE:
            return 3 * d * self.d_ff
        if spec.ffn == FFN_MOE:
            m = self.moe
            return m.n_experts * 3 * d * m.d_ff_expert + d * m.n_experts
        return 0

    def active_param_count(self) -> int:
        """Params touched per token (MoE counts only top-k experts)."""
        n = self.param_count()
        if self.moe is None:
            return n
        dead = 0
        for spec in self.layers:
            if spec.ffn == FFN_MOE:
                m = self.moe
                dead += (m.n_experts - m.top_k) * 3 * self.d_model * m.d_ff_expert
        return n - dead


# ---------------------------------------------------------------------------
# Pattern helpers
# ---------------------------------------------------------------------------

def uniform_layers(n: int, mixer: str = MIX_ATTN, ffn: str = FFN_DENSE,
                   window: Optional[int] = None) -> Tuple[LayerSpec, ...]:
    return tuple(LayerSpec(mixer=mixer, ffn=ffn, window=window) for _ in range(n))


def cycled_layers(n: int, pattern: Tuple[LayerSpec, ...]) -> Tuple[LayerSpec, ...]:
    return tuple(pattern[i % len(pattern)] for i in range(n))


# ---------------------------------------------------------------------------
# Reduced (smoke-test) variants
# ---------------------------------------------------------------------------

def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 128,
            vocab: int = 512) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests (2 layers, d_model<=512,
    <=4 experts) that preserves every structural feature of the config."""
    assert d_model <= 512
    scale = d_model / cfg.d_model
    n_heads = max(2, min(cfg.n_heads, 4))
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    head_dim = max(8, d_model // n_heads)
    # preserve the layer pattern, cycled down to n_layers
    layers = tuple(
        dataclasses.replace(cfg.layers[i % cfg.n_layers],
                            window=None if cfg.layers[i % cfg.n_layers].window is None
                            else min(cfg.layers[i % cfg.n_layers].window, 64))
        for i in range(n_layers)
    )
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(
            n_experts=min(4, cfg.moe.n_experts),
            top_k=min(2, cfg.moe.top_k),
            d_ff_expert=max(16, int(cfg.moe.d_ff_expert * scale)),
            aux_loss_coef=cfg.moe.aux_loss_coef,
        )
    encoder = None
    if cfg.encoder is not None:
        encoder = EncoderConfig(
            n_layers=2, d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
            d_ff=max(32, int(cfg.encoder.d_ff * scale)), head_dim=head_dim,
        )
    frontend = None
    if cfg.frontend is not None:
        frontend = FrontendConfig(kind=cfg.frontend.kind, seq_len=16,
                                  feature_dim=d_model)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=max(32, int(cfg.d_ff * scale)),
        vocab_size=vocab,
        layers=layers,
        moe=moe,
        encoder=encoder,
        frontend=frontend,
        lru_width=None if cfg.lru_width is None else d_model,
        long_context_window=64,
    )
