"""Logical sharding context.

Models are written against *logical* axis names ("batch", "seq", "embed",
"heads", "kv", "expert", "ff").  The distribution layer activates a mesh and a
logical->mesh translation; outside any context ``logical_constraint`` is the
identity, so the same model code runs in single-device tests and in the
256/512-chip dry-run unchanged.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

_state = threading.local()


def _ctx() -> Optional[dict]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def activation_rules(mesh: Mesh, rules: Dict[str, MeshAxes]):
    """Activate logical->mesh translation for ``logical_constraint`` calls."""
    prev = _ctx()
    _state.ctx = {"mesh": mesh, "rules": dict(rules)}
    try:
        yield
    finally:
        _state.ctx = prev


def current_mesh() -> Optional[Mesh]:
    ctx = _ctx()
    return None if ctx is None else ctx["mesh"]


def _translate(rules: Dict[str, MeshAxes], names: Sequence[Optional[str]],
               used: set) -> P:
    axes = []
    for name in names:
        mesh_ax = rules.get(name) if name is not None else None
        if mesh_ax is None:
            axes.append(None)
            continue
        # never assign the same mesh axis to two tensor dims
        if isinstance(mesh_ax, tuple):
            mesh_ax = tuple(a for a in mesh_ax if a not in used)
            mesh_ax = mesh_ax if mesh_ax else None
        elif mesh_ax in used:
            mesh_ax = None
        if mesh_ax is not None:
            for a in (mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)):
                used.add(a)
        axes.append(mesh_ax)
    return P(*axes)


def logical_constraint(x, names: Sequence[Optional[str]]):
    """Constrain ``x`` (rank == len(names)) to the active logical sharding.

    No-op when no context is active (unit tests, single device)."""
    ctx = _ctx()
    if ctx is None:
        return x
    spec = _translate(ctx["rules"], names, set())
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx["mesh"], spec))


def param_sharding_rules(rules: Dict[str, MeshAxes], names: Sequence[Optional[str]]) -> P:
    """Translate logical names to a PartitionSpec (for in_shardings)."""
    return _translate(dict(rules), names, set())
