"""Command-line driver: ``python -m repro.analysis`` / ``repro-lint`` /
``tools/lint.py``.

Exit codes: 0 — no findings beyond the baseline; 1 — new findings (the
ratchet fires); 2 — usage or internal error (unreadable baseline,
unparsable source, no files).
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional

from .baseline import (BaselineError, DEFAULT_BASELINE, load_baseline,
                       new_findings, render_baseline)
from .core import analyze_paths
from .report import to_json, to_text

EXIT_CLEAN = 0
EXIT_FINDINGS = 1
EXIT_ERROR = 2


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="Parity-and-determinism static analysis for the "
                    "FedTune reproduction (rules REPRO001–REPRO007).")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories to scan "
                         "(default: src/repro under the current directory)")
    ap.add_argument("--format", choices=("text", "json"), default="text",
                    help="report format (default: text)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline JSON of accepted findings "
                         "(default: the packaged empty baseline)")
    ap.add_argument("--write-baseline", type=Path, default=None,
                    metavar="PATH",
                    help="write a baseline accepting the current findings "
                         "to PATH and exit 0")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="include justified suppressions in text output")
    ap.add_argument("--output", type=Path, default=None,
                    help="write the report to PATH as well as stdout")
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    paths = [Path(p) for p in args.paths] if args.paths else None
    if paths is None:
        default = Path("src") / "repro"
        if not default.is_dir():
            print("error: no paths given and ./src/repro does not exist",
                  file=sys.stderr)
            return EXIT_ERROR
        paths = [default]
    missing = [p for p in paths if not p.exists()]
    if missing:
        print(f"error: no such path(s): "
              f"{', '.join(str(p) for p in missing)}", file=sys.stderr)
        return EXIT_ERROR

    result = analyze_paths(paths)
    if result.n_files == 0:
        print("error: no Python files found under the given paths",
              file=sys.stderr)
        return EXIT_ERROR

    if args.write_baseline is not None:
        args.write_baseline.write_text(render_baseline(result),
                                       encoding="utf-8")
        print(f"wrote baseline with {len(result.findings)} finding(s) to "
              f"{args.write_baseline}", file=sys.stderr)
        return EXIT_CLEAN

    baseline_path = args.baseline or DEFAULT_BASELINE
    try:
        baseline = load_baseline(baseline_path)
    except BaselineError as e:
        print(f"error: {e}", file=sys.stderr)
        return EXIT_ERROR

    fresh = new_findings(result, baseline)
    if args.format == "json":
        report = to_json(result, new_findings=fresh)
    else:
        report = to_text(result, new_findings=fresh,
                         show_suppressed=args.show_suppressed)
    sys.stdout.write(report)
    if args.output is not None:
        args.output.write_text(report, encoding="utf-8")

    if result.errors:
        return EXIT_ERROR
    return EXIT_FINDINGS if fresh else EXIT_CLEAN


if __name__ == "__main__":
    raise SystemExit(main())
