"""GOOD fixture: named exception types, or broad-but-re-raising."""


def load(path):
    try:
        return open(path).read()
    except (OSError, UnicodeDecodeError):
        return None


def run(fn):
    try:
        return fn()
    except Exception as e:
        # broad is fine when the handler re-raises with context
        raise RuntimeError("wrapped for context") from e
