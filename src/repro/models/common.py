"""Shared primitives: norms, initializers, RoPE, soft-cap, activations."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.ctx import logical_constraint


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in: int | None = None):
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[0]
    scale = jnp.sqrt(1.0 / max(fan_in, 1))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rmsnorm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layernorm(x, weight, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight + bias).astype(dtype)


def softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                      # (..., S, 1, D/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# logical sharding shortcuts for common activation layouts
# ---------------------------------------------------------------------------

def shard_bse(x):   # (batch, seq, embed)
    return logical_constraint(x, ("batch", "seq", "embed"))


def shard_bshd(x):  # (batch, seq, heads, head_dim)
    return logical_constraint(x, ("batch", None, "heads", None))


def shard_bsv(x):   # (batch, seq, vocab)
    return logical_constraint(x, ("batch", "seq", "vocab"))
