"""Paper Fig. 5 / Table 2: system overhead versus model complexity.

Part A reproduces Table 2's model characteristics (ResNet-10/18/26/34
params + FLOPs).  Part B measures cost-to-target-accuracy across a model
complexity sweep (MLP widths at reduced scale; ResNets with ``--full``)."""

from __future__ import annotations

import jax

from benchmarks.common import BenchSettings, emit, run_fl
from repro.configs.paper_models import (MLPConfig, RESNET10, RESNET18,
                                        RESNET26, RESNET34)
from repro.models import build_model


def main(settings: BenchSettings):
    # Part A: Table 2 characteristics
    for cfg in (RESNET10, RESNET18, RESNET26, RESNET34):
        m = build_model(cfg)
        n = sum(p.size for p in jax.tree.leaves(
            m.init(jax.random.PRNGKey(0))))
        emit(f"table2/{cfg.name}", 0.0,
             f"params={n};flops={m.flops_per_example:.3g}")

    # Part B: overhead-to-accuracy vs complexity
    widths = (16, 48, 128) if not settings.full else (32, 128, 512)
    for w in widths:
        cfg = MLPConfig(name=f"mlp_w{w}", in_dim=28 * 28, hidden=(w,),
                        n_classes=16)
        model = build_model(cfg)
        res = run_fl("emnist", settings, model=model, m=2, e=1.0)
        c = res.total_cost
        emit(f"fig5/width={w}", res.wall * 1e6,
             f"rounds={res.rounds};acc={res.final_accuracy:.3f};"
             f"CompT={c.comp_t:.3g};TransT={c.trans_t:.3g};"
             f"CompL={c.comp_l:.3g};TransL={c.trans_l:.3g}")
