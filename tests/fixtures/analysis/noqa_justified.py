"""Suppression fixture: a justified noqa suppresses its finding."""


def probe(fn):
    try:
        return fn()
    except Exception:  # noqa: REPRO007 -- third-party probe may raise anything; failure just means "feature absent"
        return None
