"""Analytic roofline terms per (architecture x input shape).

Why analytic: XLA *CPU* ``cost_analysis()`` counts each ``while``-loop body
ONCE, so with scan-over-layers / flash scans / CE chunk scans the reported
FLOPs under-count by the trip counts (validated in EXPERIMENTS.md §Roofline
against an unrolled compile).  The analytic model below reproduces what the
compiled program actually executes — including deliberate overcompute
(dense-MoE E/k inflation, unskipped masked attention chunks, remat) — and is
cross-checked against the HLO-parsed collective op *kinds*.

All quantities are per-device on the single-pod (16,16) mesh.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import (FFN_DENSE, FFN_MOE, MIX_ATTN, MIX_MLSTM,
                                MIX_RGLRU, MIX_SLSTM, ModelConfig)
from repro.configs.shapes import InputShape
from repro.roofline.hardware import TPU_V5E, Chip

BF16 = 2
F32 = 4


@dataclass
class AnalyticReport:
    flops: float          # per device
    hbm_bytes: float      # per device
    coll_bytes: float     # per device
    # decomposition for the perf log
    flops_ideal: float    # without remat/dense-MoE/masked-chunk waste
    detail: dict

    def terms(self, chip: Chip = TPU_V5E):
        return {
            "compute": self.flops / chip.peak_flops_bf16,
            "memory": self.hbm_bytes / chip.hbm_bandwidth,
            "collective": self.coll_bytes / (
                chip.ici_links_per_chip * chip.ici_link_bandwidth),
        }

    def bottleneck(self, chip: Chip = TPU_V5E) -> str:
        t = self.terms(chip)
        return max(t, key=t.get)


def _layer_flops(cfg: ModelConfig, spec, tokens: int, ctx: int,
                 moe_dense: bool):
    """Forward FLOPs of one layer over ``tokens`` tokens with attention
    context ``ctx`` (= kv length actually computed against)."""
    d = cfg.d_model
    f = 0.0
    if spec.mixer == MIX_ATTN:
        h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        f += 2 * tokens * d * (h + 2 * kh) * hd      # qkv proj
        f += 2 * tokens * h * hd * d                 # out proj
        f += 4 * tokens * ctx * h * hd               # qk^T + pv
    elif spec.mixer == MIX_RGLRU:
        w = cfg.lru_width or d
        f += 2 * tokens * d * w * 3                  # in/gate/out projections
        f += tokens * w * (2 * cfg.conv1d_width + 12)  # conv + gates + scan
    elif spec.mixer in (MIX_MLSTM, MIX_SLSTM):
        w = int(d * cfg.xlstm_proj_factor) if spec.mixer == MIX_MLSTM else d
        hd = w // cfg.n_heads
        f += 2 * tokens * d * w * 3                  # up/z/down projections
        if spec.mixer == MIX_MLSTM:
            f += 2 * tokens * w * hd * 3             # per-head q/k/v proj
            chunk = 128
            f += 4 * tokens * chunk * w              # within-chunk quadratic
            f += 2 * (tokens / chunk) * cfg.n_heads * hd * hd * 2  # states
        else:
            f += 2 * tokens * d * hd * 4             # recurrent R_gate
    if spec.ffn == FFN_DENSE:
        f += 2 * tokens * d * cfg.d_ff * 3
    elif spec.ffn == FFN_MOE:
        experts = cfg.moe.n_experts if moe_dense else cfg.moe.top_k
        f += 2 * tokens * d * cfg.moe.d_ff_expert * 3 * experts
        f += 2 * tokens * d * cfg.moe.n_experts      # router
    return f


def _param_bytes(cfg: ModelConfig) -> float:
    return cfg.param_count() * BF16


def analyze(cfg: ModelConfig, shape: InputShape, *, n_devices: int = 256,
            data_axis: int = 16, model_axis: int = 16,
            moe_dense: bool = True, remat: bool = True,
            causal_skip: bool = False) -> AnalyticReport:
    b, s = shape.global_batch, shape.seq_len
    kind = shape.kind
    p_bytes = _param_bytes(cfg)

    if kind == "decode":
        tokens = b                        # ONE new token per sequence
        force_window = (not cfg.subquadratic) and s > 65536
    else:
        tokens = b * s
        force_window = False

    def ctx_for(spec):
        if kind == "decode":
            w = spec.window if spec.window is not None else (
                cfg.long_context_window if force_window else s)
            return min(w if w else s, s)
        # train/prefill blocked fallback computes every chunk (masked):
        full = s if not causal_skip else s / 2
        if spec.window is not None and causal_skip:
            return min(spec.window, s)
        return full

    def ctx_ideal(spec):
        if kind == "decode":
            return ctx_for(spec)   # the SW serving policy is semantic, not waste
        w = spec.window or s
        return min(w, s) / (2 if spec.window is None else 1)

    fwd = sum(_layer_flops(cfg, spec, tokens, ctx_for(spec), moe_dense)
              for spec in cfg.layers)
    fwd_ideal = sum(_layer_flops(cfg, spec, tokens, ctx_ideal(spec), False)
                    for spec in cfg.layers)
    # encoder (audio enc-dec): frontend frames
    if cfg.encoder is not None and cfg.frontend is not None:
        e = cfg.encoder
        etok = (b if kind != "decode" else b) * cfg.frontend.seq_len \
            if kind != "decode" else 0
        if kind != "decode":
            enc = etok * (2 * e.d_model * (e.n_heads + 2 * e.n_kv_heads)
                          * e.head_dim + 2 * e.n_heads * e.head_dim * e.d_model
                          + 6 * e.d_model * e.d_ff) \
                + 4 * etok * cfg.frontend.seq_len * e.n_heads * e.head_dim
            fwd += enc
            fwd_ideal += enc
    # unembed / CE
    head = 2 * tokens * cfg.d_model * cfg.vocab_size
    fwd += head
    fwd_ideal += head

    if kind == "train":
        mult = 4.0 if remat else 3.0      # fwd + 2x bwd (+ remat refwd)
        flops = mult * fwd
        flops_ideal = 3.0 * fwd_ideal
    else:
        flops = fwd
        flops_ideal = fwd_ideal

    # ---------------- HBM bytes (per device) ----------------
    act_unit = tokens / data_axis * cfg.d_model * BF16
    n_layers = cfg.n_layers
    if kind == "train":
        # FSDP: every device streams ALL gathered weights fwd+bwd+remat
        w_traffic = 3.0 * p_bytes
        opt_traffic = 4.0 * p_bytes / n_devices * (F32 / BF16)
        act_traffic = n_layers * act_unit * 12 * (2 if remat else 1)
        hbm = w_traffic + opt_traffic + act_traffic
    elif kind == "prefill":
        hbm = p_bytes + n_layers * act_unit * 8
        # KV cache write
        hbm += (cfg.n_layers * tokens / data_axis * 2
                * cfg.n_kv_heads * cfg.head_dim * BF16)
    else:
        # decode: read all weights once + read the whole KV cache / states
        cache_tokens = sum(
            min(spec.window or (cfg.long_context_window if force_window
                                else s), s)
            for spec in cfg.layers if spec.mixer == MIX_ATTN)
        cache_bytes = (b * cache_tokens * 2 * cfg.n_kv_heads
                       * cfg.head_dim * BF16) / n_devices
        hbm = p_bytes / n_devices * (1 if kind == "decode" else 1) \
            + cache_bytes + p_bytes / n_devices
        # every device holds p/n but READS weights via collectives; count
        # the local share twice (read + resident)
        hbm = p_bytes / n_devices * 2 + cache_bytes

    # ---------------- collective bytes (per device) ----------------
    if kind == "train":
        # FSDP all-gather (fwd + bwd remat) + grad reduce-scatter (f32)
        coll = 2.0 * p_bytes + p_bytes * (F32 / BF16)
        # sequence-parallel gathers + TP reduces per layer (fwd+bwd)
        coll += n_layers * act_unit * 4
        # FedAvg weighted grad psum IS the reduce-scatter above (counted)
    elif kind == "prefill":
        coll = p_bytes + n_layers * act_unit * 2
    else:
        # weight gathers dominate decode on 2D-sharded params
        coll = p_bytes / data_axis  # all-gather over data axis share
        coll += b / max(data_axis, 1) * cfg.d_model * BF16 * n_layers * 2

    return AnalyticReport(
        flops=flops / n_devices,
        hbm_bytes=hbm if kind == "train" else hbm,
        coll_bytes=coll,
        flops_ideal=flops_ideal / n_devices,
        detail={"fwd": fwd, "param_bytes": p_bytes, "tokens": tokens},
    )
