"""The synthetic federated data must reproduce the paper's three FL data
properties: massively distributed, unbalanced, non-IID."""

import numpy as np

from repro.data import emnist_like, speech_command_like, cifar100_like
from repro.data.synthetic import DataSpec, make_dataset


def test_massively_distributed_and_unbalanced():
    ds = speech_command_like(reduced=True)
    sizes = ds.client_sizes
    assert len(sizes) >= 100
    # unbalanced: wide spread like the paper's Fig. 2a (1 .. ~316)
    assert sizes.min() >= 1 and sizes.max() <= 316
    assert sizes.max() / max(np.median(sizes), 1) > 3


def test_full_scale_matches_paper_counts():
    ds = speech_command_like()
    assert ds.n_clients == 2112
    assert ds.spec.n_test_clients == 506
    assert ds.spec.n_classes == 35
    assert ds.spec.shape == (32, 32, 1)


def test_non_iid_label_skew():
    ds = emnist_like(reduced=True)
    n_classes = ds.spec.n_classes
    uniform = np.full(n_classes, 1.0 / n_classes)
    kls = []
    for cid in range(20):
        _, y = ds.client_data(cid)
        if len(y) < 10:
            continue
        p = np.bincount(y, minlength=n_classes) / len(y)
        nz = p > 0
        kls.append(np.sum(p[nz] * np.log(p[nz] / uniform[nz])))
    assert np.mean(kls) > 0.3, "client label dists should diverge from uniform"


def test_deterministic_lazy_materialization():
    ds = emnist_like(reduced=True)
    x1, y1 = ds.client_data(7)
    x2, y2 = ds.client_data(7)
    assert np.array_equal(x1, x2) and np.array_equal(y1, y2)
    x3, _ = ds.client_data(8)
    assert x3.shape[1:] == x1.shape[1:]


def test_learnable_structure():
    """A linear probe on pooled data must beat chance (features carry
    class signal, so FL training can actually improve accuracy)."""
    ds = emnist_like(reduced=True)
    xs, ys = [], []
    for cid in range(60):
        x, y = ds.client_data(cid)
        xs.append(x.reshape(len(y), -1))
        ys.append(y)
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    # class-mean classifier
    classes = np.unique(y)
    means = np.stack([x[y == c].mean(0) for c in classes])
    pred = classes[np.argmin(
        ((x[:, None, :] - means[None]) ** 2).sum(-1), axis=1)]
    acc = (pred == y).mean()
    assert acc > 3.0 / ds.spec.n_classes, f"probe acc {acc:.3f} ~ chance"


def test_cifar_like_fixed_sizes():
    ds = cifar100_like(reduced=True)
    assert (ds.client_sizes == 50).all()


def test_test_data_pooling():
    ds = emnist_like(reduced=True)
    x, y = ds.test_data(max_points=256)
    assert len(x) == len(y) <= 256
    assert x.dtype == np.float32


def test_test_data_cache_grows_for_larger_requests():
    """Regression: a first small test_data call must not permanently
    truncate the pooled test set for later, larger requests."""
    ds = emnist_like(reduced=True)
    x_small, y_small = ds.test_data(max_points=32)
    assert len(y_small) == 32
    x_big, y_big = ds.test_data(max_points=512)
    assert len(y_big) == 512
    # determinism: the small request is a prefix of the regenerated set
    np.testing.assert_array_equal(x_small, x_big[:32])
    np.testing.assert_array_equal(y_small, y_big[:32])
    # shrinking again serves from cache without truncating it
    _, y_mid = ds.test_data(max_points=128)
    assert len(y_mid) == 128
    _, y_big2 = ds.test_data(max_points=512)
    assert len(y_big2) == 512


def test_test_data_exhaustion_is_cached():
    """Requests beyond the whole held-out pool return everything there is,
    and don't regenerate on every call."""
    ds = make_dataset(DataSpec(
        name="tiny_test_pool", n_classes=4, shape=(8,), n_train_clients=4,
        n_test_clients=2, size_log_mean=1.0, size_log_std=0.1, seed=3))
    x1, y1 = ds.test_data(max_points=10_000)
    assert ds._test_exhausted and len(y1) < 10_000
    cached = ds._test_cache
    x2, _ = ds.test_data(max_points=20_000)
    assert ds._test_cache is cached     # no regeneration
    np.testing.assert_array_equal(x1, x2)
