"""Paper Table 4: FedTune vs fixed hyper-parameters for all 15 training
preferences (FedAdagrad aggregation in the paper; configurable)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import (BenchSettings, emit, fedtune_for, improvement,
                               run_fl)
from repro.core.preferences import PAPER_PREFERENCES


def main(settings: BenchSettings, aggregator: str = "fedadagrad",
         dataset: str = "speech_command", penalty: float = 10.0):
    # speech_command (the paper's Table 4 dataset) needs more rounds to
    # converge, giving FedTune enough accuracy-gated decisions to matter.
    gains = []
    base_by_seed = {}
    for seed in range(settings.seeds):
        base = run_fl(dataset, settings, aggregator=aggregator, seed=seed)
        base_by_seed[seed] = base
        c = base.total_cost
        emit(f"table4/{aggregator}/baseline/seed{seed}", base.wall * 1e6,
             f"rounds={base.rounds};acc={base.final_accuracy:.3f};"
             f"CompT={c.comp_t:.3g};TransT={c.trans_t:.3g};"
             f"CompL={c.comp_l:.3g};TransL={c.trans_l:.3g}")
    for pref in PAPER_PREFERENCES:
        per_seed = []
        for seed in range(settings.seeds):
            tuner = fedtune_for(pref, settings.m0, settings.e0,
                                penalty=penalty)
            res = run_fl(dataset, settings, tuner=tuner,
                         aggregator=aggregator, seed=seed)
            base = base_by_seed[seed]
            # compare at the common achieved accuracy via cost normalization:
            # both runs stop at target or max_rounds; guard unequal accuracy
            gain = improvement(pref, base.total_cost, res.total_cost)
            per_seed.append(gain)
            emit(f"table4/{aggregator}/{pref}/seed{seed}", res.wall * 1e6,
                 f"gain={gain:+.2f}%;rounds={res.rounds};"
                 f"acc={res.final_accuracy:.3f};M={res.final_m};"
                 f"E={res.final_e:g};decisions={tuner.decisions}")
        gains.append(np.mean(per_seed))
        emit(f"table4/{aggregator}/{pref}/mean", 0.0,
             f"gain={np.mean(per_seed):+.2f}%;std={np.std(per_seed):.2f}")
    emit(f"table4/{aggregator}/OVERALL", 0.0,
         f"mean_gain={np.mean(gains):+.2f}%;"
         f"positive={sum(g > 0 for g in gains)}/{len(gains)}")
    return float(np.mean(gains))
