"""repro.analysis — parity-and-determinism static analysis.

The parity tests pin the bit-exactness contract *empirically*: they
catch a violation only after it's written and only on the inputs they
run.  This package enforces the same house rules *statically* — an AST
pass with a jit-scope model (decorators + a lightweight intra-repo call
graph decide what runs under ``jax.jit``/``vmap``/``shard_map``/
``scan``), seven repo-specific rules (REPRO001–REPRO007), justified
``# noqa`` suppressions, deterministic text/JSON reports and a baseline
ratchet for CI.

Entry points: ``python -m repro.analysis``, the ``repro-lint`` console
script, or ``tools/lint.py``.  Rule catalog: docs/ANALYSIS.md.

Deliberately dependency-free (stdlib ``ast`` only — no jax import), so
the lint job runs anywhere Python does.
"""

from .baseline import DEFAULT_BASELINE, load_baseline, new_findings
from .core import (AnalysisResult, FileContext, Finding, Rule, Suppression,
                   all_rules, analyze_paths, register)
from .report import to_json, to_text

__all__ = [
    "AnalysisResult", "DEFAULT_BASELINE", "FileContext", "Finding", "Rule",
    "Suppression", "all_rules", "analyze_paths", "load_baseline",
    "new_findings", "register", "to_json", "to_text",
]
