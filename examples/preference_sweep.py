"""Sweep application preferences (the paper's Fig. 7 trace view): shows how
FedTune steers (M, E) differently per training preference.

    PYTHONPATH=src python examples/preference_sweep.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.paper_models import MLPConfig
from repro.core import CostModel, FedTune, FedTuneConfig, Preference
from repro.core.tuner import HyperParams
from repro.data import emnist_like
from repro.federated import FLConfig, FLServer, get_aggregator
from repro.models import build_model
from repro.optim.optimizers import get_optimizer

PREFS = {
    "CompT-only (a=1)": Preference(1, 0, 0, 0),
    "TransT-only (b=1)": Preference(0, 1, 0, 0),
    "CompL-only (g=1)": Preference(0, 0, 1, 0),
    "TransL-only (d=1)": Preference(0, 0, 0, 1),
    "balanced": Preference(0.25, 0.25, 0.25, 0.25),
}


def main():
    dataset = emnist_like(reduced=True)
    model = build_model(MLPConfig(name="mlp", in_dim=784, hidden=(48,),
                                  n_classes=16))
    n_params = sum(p.size for p in jax.tree.leaves(
        model.init(jax.random.PRNGKey(0))))

    print(f"{'preference':22s} {'M trace':28s} {'E trace':28s} final")
    for label, pref in PREFS.items():
        tuner = FedTune(FedTuneConfig(preference=pref), HyperParams(5, 2))
        server = FLServer(
            model, dataset, get_aggregator("fedavg"),
            get_optimizer("sgd", 0.03, momentum=0.9),
            CostModel(flops_per_example=2 * n_params, param_count=n_params),
            FLConfig(m=5, e=2, batch_size=10, target_accuracy=0.55,
                     max_rounds=80),
            tuner=tuner)
        res = server.run()
        ms = [t["m_next"] for t in tuner.trace][:8]
        es = [t["e_next"] for t in tuner.trace][:8]
        print(f"{label:22s} {str(ms):28s} {str(es):28s} "
              f"M={res.final_m} E={res.final_e:g} acc={res.final_accuracy:.2f}")


if __name__ == "__main__":
    main()
