"""GOOD fixture: stochasticity from seeds, time from the virtual clock."""

import numpy as np


def virtual_round(queue, seed):
    rng = np.random.default_rng(seed)   # seeded: deterministic
    now = queue.now                     # the event queue's virtual time
    return now + rng.uniform()
