"""Regenerate reduced-dataset versions of the paper's Tables 4/5/6 with the
sweep engine.

The paper's headline numbers are grids: FedTune vs a FixedTuner baseline
across 15 preference vectors (Table 4), three datasets (Table 5), and five
aggregation methods (Table 6).  This example expands the corresponding
(reduced-scale) grids, runs every trial concurrently through the
vectorized trials-as-an-axis engine, and prints the paper-style
mean +- std overhead-reduction tables.  Results land in a JSONL store, so
a re-run only computes what is missing — bump ``--seeds`` and re-invoke to
tighten the error bars without redoing finished trials.

Usage:
  PYTHONPATH=src:. python examples/paper_tables.py                # Table 4 (subset)
  PYTHONPATH=src:. python examples/paper_tables.py --table 5
  PYTHONPATH=src:. python examples/paper_tables.py --table 6 --seeds 3
  PYTHONPATH=src:. python examples/paper_tables.py --prefs all --rounds 30
"""

from __future__ import annotations

import argparse
import time

from repro.experiments import (ResultStore, SweepSpec, TrialSpec,
                               paper_table, parse_preferences, run_sweep)


def build_sweep(table: int, prefs: str, seeds: int, rounds: int,
                target: float) -> SweepSpec:
    base = TrialSpec(rounds=rounds, target_accuracy=target, batch_size=10,
                     eval_points=512)
    seed_axis = tuple(range(seeds))
    if table == 4:      # preferences x FedAvg on speech-command-like
        return SweepSpec(datasets=("speech_command",),
                         aggregators=("fedavg",),
                         preferences=parse_preferences(prefs),
                         seeds=seed_axis, base=base)
    if table == 5:      # datasets under the balanced preference
        return SweepSpec(datasets=("speech_command", "emnist", "cifar100"),
                         aggregators=("fedavg",),
                         preferences=parse_preferences("14"),
                         seeds=seed_axis, base=base)
    if table == 6:      # aggregation methods on speech-command-like
        return SweepSpec(datasets=("speech_command",),
                         aggregators=("fedavg", "fednova", "fedadagrad",
                                      "fedadam", "fedyogi"),
                         preferences=parse_preferences("14"),
                         seeds=seed_axis, base=base)
    raise ValueError(f"unknown table {table}; valid tables: 4, 5, 6")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--table", type=int, default=4, choices=(4, 5, 6))
    ap.add_argument("--prefs", default="0,1,4,14",
                    help="Table 4 preference axis: 'all', paper indices, "
                         "or ';'-separated quads")
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--target", type=float, default=0.5)
    ap.add_argument("--out", default="runs/paper_tables.jsonl")
    ap.add_argument("--pack", default="batched",
                    choices=("batched", "sharded"))
    args = ap.parse_args()

    sweep = build_sweep(args.table, args.prefs, args.seeds, args.rounds,
                        args.target)
    specs = sweep.expand()
    store = ResultStore(args.out)
    done = store.completed_keys()
    pending = [s for s in specs if s.key() not in done]
    print(f"table {args.table}: {len(specs)} trials "
          f"({len(specs) - len(pending)} already done)", flush=True)
    t0 = time.perf_counter()
    run_sweep(pending, store=store, engine="vectorized", pack=args.pack)
    print(f"ran {len(pending)} trial(s) in {time.perf_counter() - t0:.1f}s\n")
    print(paper_table(store.load(),
                      title=f"Paper Table {args.table} "
                            "(reduced-scale reproduction)"))


if __name__ == "__main__":
    main()
