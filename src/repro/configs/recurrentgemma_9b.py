"""recurrentgemma-9b — 38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000.
RG-LRU + local attention in a 1:2 (attn : recurrent) pattern.  [arXiv:2402.19427]"""

from repro.configs.base import (FFN_DENSE, LayerSpec, MIX_ATTN, MIX_RGLRU,
                                ModelConfig, cycled_layers)

# Griffin pattern: two RG-LRU blocks then one local-attention block.
_PATTERN = (
    LayerSpec(mixer=MIX_RGLRU, ffn=FFN_DENSE),
    LayerSpec(mixer=MIX_RGLRU, ffn=FFN_DENSE),
    LayerSpec(mixer=MIX_ATTN, ffn=FFN_DENSE, window=2048),
)

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    layers=cycled_layers(38, _PATTERN),
    lru_width=4096,
    conv1d_width=4,
    tie_embeddings=True,
    source="arXiv:2402.19427",
)
