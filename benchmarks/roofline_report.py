"""Roofline table from the dry-run artifacts (experiments/dryrun/*.json)."""

from __future__ import annotations

import json
import pathlib

from benchmarks.common import BenchSettings, emit

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def main(settings: BenchSettings):
    files = sorted(DRYRUN_DIR.glob("*.json")) if DRYRUN_DIR.exists() else []
    if not files:
        emit("roofline/NO_DRYRUN_DATA", 0.0,
             "run: PYTHONPATH=src python -m repro.launch.dryrun --all")
        return
    n_ok = n_fail = 0
    for f in files:
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            n_fail += 1
            emit(f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}", 0.0,
                 f"FAILED:{rec.get('error', '?')[:80]}")
            continue
        n_ok += 1
        emit(f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
             rec.get("t_compile_s", 0.0) * 1e6,
             f"comp_ms={rec['t_compute'] * 1e3:.3f};"
             f"mem_ms={rec['t_memory'] * 1e3:.3f};"
             f"coll_ms={rec['t_collective'] * 1e3:.3f};"
             f"bottleneck={rec['bottleneck']};"
             f"peak_GiB={rec['peak_memory_bytes'] / 2**30:.2f}")
    emit("roofline/SUMMARY", 0.0, f"ok={n_ok};fail={n_fail}")
