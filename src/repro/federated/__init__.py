from repro.federated.aggregation import get_aggregator
from repro.federated.client import local_train
from repro.federated.evaluation import Evaluator, StackedEvaluator
from repro.federated.server import FLConfig, FLServer

__all__ = ["get_aggregator", "local_train", "FLConfig", "FLServer",
           "Evaluator", "StackedEvaluator"]
