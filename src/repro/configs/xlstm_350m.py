"""xlstm-350m — 24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304.
Alternating sLSTM / mLSTM blocks (block-internal projections; no separate FFN).
[arXiv:2405.04517]"""

from repro.configs.base import (FFN_NONE, LayerSpec, MIX_MLSTM, MIX_SLSTM,
                                ModelConfig, cycled_layers)

# xLSTM[7:1]-style stacks interleave mLSTM-heavy patterns with sLSTM blocks;
# we use the paper's 1:1 alternation variant for the 350M scale.
_PATTERN = (
    LayerSpec(mixer=MIX_MLSTM, ffn=FFN_NONE),
    LayerSpec(mixer=MIX_SLSTM, ffn=FFN_NONE),
)

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50_304,
    layers=cycled_layers(24, _PATTERN),
    xlstm_proj_factor=2.0,
    tie_embeddings=True,
    source="arXiv:2405.04517",
)
