"""REPRO007 — broad exception handlers that swallow bugs.

A bare ``except:`` or ``except Exception:`` around simulator code turns
a determinism bug (shape mismatch, missing attribute, tracer leak) into
a silently-different result — the exact failure mode the parity tests
exist to catch loudly.  Handlers that re-raise (``raise`` anywhere in
the body) keep the loud path and are exempt; everything else must name
the exception types it actually expects or justify the catch-all.
"""

from __future__ import annotations

import ast

from ..core import FileContext, Rule, register


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    if isinstance(t, ast.Name) and t.id in {"Exception", "BaseException"}:
        return True
    if isinstance(t, ast.Tuple):
        return any(isinstance(e, ast.Name)
                   and e.id in {"Exception", "BaseException"}
                   for e in t.elts)
    return False


def _reraises(handler: ast.ExceptHandler) -> bool:
    return any(isinstance(n, ast.Raise) for n in ast.walk(handler))


@register
class BroadExcept(Rule):
    id = "REPRO007"
    name = "broad-except-swallows-bugs"

    def check_file(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if _is_broad(node) and not _reraises(node):
                what = ("bare `except:`" if node.type is None
                        else "`except Exception`")
                ctx.add(node, self.id,
                        f"{what} swallows unexpected failures — name the "
                        "exception types this site actually expects, or "
                        "re-raise with context")
