"""``python -m repro.analysis`` — see cli.py for flags and exit codes."""

from .cli import main

raise SystemExit(main())
