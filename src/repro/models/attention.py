"""Attention: GQA + RoPE + (optional) sliding window + logit soft-cap.

Three execution paths:
  * ``naive_attention``   — materializes (S, T) scores; used for short seqs/tests.
  * ``blocked_attention`` — flash-style online-softmax double scan over q/kv
    chunks; pure-jnp analogue of ``kernels/flash_attention`` (the Pallas TPU
    kernel).  Memory-bounded, used for long-sequence train/prefill.
  * ``decode_attention``  — one query token against a (possibly ring-buffer)
    KV cache.

All paths share the same math; tests assert they agree.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.models.common import apply_rope, dense_init, shard_bshd, softcap

BLOCKED_SEQ_THRESHOLD = 2048  # switch naive -> blocked above this length
Q_CHUNK = 512
KV_CHUNK = 1024


def _pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (chunked scans need exact
    tiling; VLM prefixes make seq lengths like 4352 = 8 x 544)."""
    c = min(target, n)
    while n % c:
        c -= 1
    return c


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_attention_params(key, cfg: ModelConfig, *, d_in: Optional[int] = None,
                          n_heads=None, n_kv=None, head_dim=None, bias=None,
                          dtype=jnp.float32):
    d = d_in if d_in is not None else cfg.d_model
    h = n_heads if n_heads is not None else cfg.n_heads
    k = n_kv if n_kv is not None else cfg.n_kv_heads
    hd = head_dim if head_dim is not None else cfg.head_dim
    use_bias = cfg.qkv_bias if bias is None else bias
    keys = jax.random.split(key, 4)
    p = {
        "wq": dense_init(keys[0], (d, h, hd), dtype, fan_in=d),
        "wk": dense_init(keys[1], (d, k, hd), dtype, fan_in=d),
        "wv": dense_init(keys[2], (d, k, hd), dtype, fan_in=d),
        "wo": dense_init(keys[3], (h, hd, d), dtype, fan_in=h * hd),
    }
    if use_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((k, hd), dtype)
        p["bv"] = jnp.zeros((k, hd), dtype)
    return p


def _project_qkv(params, x, cfg: ModelConfig, positions, *, rope=True,
                 kv_input=None, kv_positions=None):
    """Returns q:(B,S,K,G,D), k,v:(B,T,K,D)."""
    kv_x = x if kv_input is None else kv_input
    q = jnp.einsum("bse,ehd->bshd", x, params["wq"])
    k = jnp.einsum("bte,ekd->btkd", kv_x, params["wk"])
    v = jnp.einsum("bte,ekd->btkd", kv_x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = shard_bshd(q)
    k = shard_bshd(k)
    v = shard_bshd(v)
    if rope:
        kv_pos = positions if kv_positions is None else kv_positions
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_pos, cfg.rope_theta)
    n_q = q.shape[2]
    n_kv = k.shape[2]
    g = n_q // n_kv
    q = q.reshape(q.shape[0], q.shape[1], n_kv, g, q.shape[3])
    return q, k, v


# ---------------------------------------------------------------------------
# naive path
# ---------------------------------------------------------------------------

def _mask(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    m = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), jnp.bool_)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def naive_attention(q, k, v, *, q_pos, k_pos, causal=True,
                    window: Optional[int] = None, cap: Optional[float] = None):
    """q: (B,S,K,G,D); k,v: (B,T,K,D) -> (B,S,K*G,D)."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = softcap(scores, cap)
    mask = _mask(q_pos, k_pos, causal=causal, window=window)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v)
    b, s, kh, g, d = out.shape
    return out.reshape(b, s, kh * g, d)


# ---------------------------------------------------------------------------
# blocked (flash-style) path with memory-efficient custom VJP
# ---------------------------------------------------------------------------

_flash_cache = {}


def blocked_attention(q, k, v, *, q_pos, k_pos, causal=True,
                      window: Optional[int] = None, cap: Optional[float] = None,
                      q_chunk=Q_CHUNK, kv_chunk=KV_CHUNK):
    """Flash attention (pure jnp, memory-efficient backward).

    Forward: online-softmax double scan saving only (out, logsumexp).
    Backward: custom VJP recomputing per-block probabilities (two passes:
    q-major for dq, kv-major for dk/dv) — residuals are O(B*S*H*D), never
    O(S^2).  This is the CPU/dry-run analogue of kernels/flash_attention.
    """
    key = (causal, window, cap, q_chunk, kv_chunk)
    if key not in _flash_cache:
        _flash_cache[key] = _make_flash(causal, window, cap, q_chunk, kv_chunk)
    return _flash_cache[key](q, k, v, q_pos, k_pos)


def _make_flash(causal, window, cap, q_chunk, kv_chunk):
    def fwd_impl(q, k, v, q_pos, k_pos):
        return _flash_forward(q, k, v, q_pos=q_pos, k_pos=k_pos,
                              causal=causal, window=window, cap=cap,
                              q_chunk=q_chunk, kv_chunk=kv_chunk)

    @jax.custom_vjp
    def flash(q, k, v, q_pos, k_pos):
        return fwd_impl(q, k, v, q_pos, k_pos)[0]

    def flash_fwd(q, k, v, q_pos, k_pos):
        out, lse = fwd_impl(q, k, v, q_pos, k_pos)
        return out, (q, k, v, q_pos, k_pos, out, lse)

    def flash_bwd(res, dout):
        q, k, v, q_pos, k_pos, out, lse = res
        dq, dk, dv = _flash_backward(
            q, k, v, out, lse, dout, q_pos=q_pos, k_pos=k_pos,
            causal=causal, window=window, cap=cap,
            q_chunk=q_chunk, kv_chunk=kv_chunk)
        zero_q = np.zeros(q_pos.shape, jax.dtypes.float0)
        zero_k = np.zeros(k_pos.shape, jax.dtypes.float0)
        return dq, dk, dv, zero_q, zero_k

    flash.defvjp(flash_fwd, flash_bwd)
    return flash


def _scores(q_blk, k_blk, qp, kp, *, causal, window, cap, scale):
    """q_blk: (B,qc,K,G,D), k_blk: (B,kc,K,D) -> capped+masked scores
    (B,K,G,qc,kc) in f32, plus the mask."""
    s = jnp.einsum("bqkgd,btkd->bkgqt", q_blk.astype(jnp.float32),
                   k_blk.astype(jnp.float32)) * scale
    dcap = None
    if cap is not None:
        t = jnp.tanh(s / cap)
        s = cap * t
        dcap = 1.0 - t * t          # d(capped)/d(raw)
    msk = _mask(qp, kp, causal=causal, window=window)
    s = jnp.where(msk[None, None, None], s, -1e30)
    return s, msk, dcap


def _flash_forward(q, k, v, *, q_pos, k_pos, causal, window, cap,
                   q_chunk, kv_chunk):
    b, s_len, kh, g, d = q.shape
    t = k.shape[1]
    qc, kc = _pick_chunk(s_len, q_chunk), _pick_chunk(t, kv_chunk)
    nq, nk = s_len // qc, t // kc
    scale = d ** -0.5

    qs = q.reshape(b, nq, qc, kh, g, d).transpose(1, 0, 2, 3, 4, 5)
    qps = q_pos.reshape(nq, qc)
    ks = k.reshape(b, nk, kc, kh, d).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nk, kc, kh, d).transpose(1, 0, 2, 3, 4)
    kps = k_pos.reshape(nk, kc)

    def q_step(_, q_in):
        q_blk, qp = q_in

        def kv_step(carry, kv_in):
            m_run, l_run, acc = carry
            k_blk, v_blk, kp = kv_in
            sc, _, _ = _scores(q_blk, k_blk, qp, kp, causal=causal,
                               window=window, cap=cap, scale=scale)
            m_new = jnp.maximum(m_run, sc.max(axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p_blk = jnp.exp(sc - m_new[..., None])
            l_new = l_run * alpha + p_blk.sum(axis=-1)
            pv = jnp.einsum("bkgqt,btkd->bkgqd", p_blk,
                            v_blk.astype(jnp.float32))
            acc = acc * alpha[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kh, g, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kh, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kh, g, qc, d), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, kps))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        lse = m_f + jnp.log(jnp.maximum(l_f, 1e-30))
        return None, (out.transpose(0, 3, 1, 2, 4), lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, (qs, qps))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s_len, kh, g, d)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(b, kh, g, s_len)
    return out.reshape(b, s_len, kh * g, d).astype(v.dtype), lse


def _flash_backward(q, k, v, out, lse, dout, *, q_pos, k_pos, causal, window,
                    cap, q_chunk, kv_chunk):
    b, s_len, kh, g, d = q.shape
    t = k.shape[1]
    qc, kc = _pick_chunk(s_len, q_chunk), _pick_chunk(t, kv_chunk)
    nq, nk = s_len // qc, t // kc
    scale = d ** -0.5

    out = out.reshape(b, s_len, kh, g, d)
    dout = dout.reshape(b, s_len, kh, g, d).astype(jnp.float32)
    # D_i = rowsum(dout * out)
    delta = jnp.einsum("bskgd,bskgd->bkgs", dout,
                       out.astype(jnp.float32))          # (B,K,G,S)

    qs = q.reshape(b, nq, qc, kh, g, d).transpose(1, 0, 2, 3, 4, 5)
    dos = dout.reshape(b, nq, qc, kh, g, d).transpose(1, 0, 2, 3, 4, 5)
    qps = q_pos.reshape(nq, qc)
    lses = lse.reshape(b, kh, g, nq, qc).transpose(3, 0, 1, 2, 4)
    deltas = delta.reshape(b, kh, g, nq, qc).transpose(3, 0, 1, 2, 4)
    ks = k.reshape(b, nk, kc, kh, d).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nk, kc, kh, d).transpose(1, 0, 2, 3, 4)
    kps = k_pos.reshape(nk, kc)

    def ds_block(q_blk, k_blk, v_blk, qp, kp, lse_blk, do_blk, dl_blk):
        """Recompute p and dS for one (q, kv) block pair."""
        sc, msk, dcap = _scores(q_blk, k_blk, qp, kp, causal=causal,
                                window=window, cap=cap, scale=scale)
        p = jnp.exp(sc - lse_blk[..., None])             # (B,K,G,qc,kc)
        dp = jnp.einsum("bqkgd,btkd->bkgqt", do_blk,
                        v_blk.astype(jnp.float32))
        ds = p * (dp - dl_blk[..., None])
        if dcap is not None:
            ds = ds * dcap
        ds = jnp.where(msk[None, None, None], ds, 0.0)
        return p, ds

    # pass 1: q-major -> dq
    def dq_step(_, q_in):
        q_blk, qp, lse_blk, do_blk, dl_blk = q_in

        def kv_inner(dq_acc, kv_in):
            k_blk, v_blk, kp = kv_in
            _, ds = ds_block(q_blk, k_blk, v_blk, qp, kp, lse_blk, do_blk,
                             dl_blk)
            dq_acc = dq_acc + jnp.einsum("bkgqt,btkd->bqkgd", ds,
                                         k_blk.astype(jnp.float32)) * scale
            return dq_acc, None

        dq0 = jnp.zeros((b, qc, kh, g, d), jnp.float32)
        dq_blk, _ = jax.lax.scan(kv_inner, dq0, (ks, vs, kps))
        return None, dq_blk

    _, dqs = jax.lax.scan(dq_step, None, (qs, qps, lses, dos, deltas))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s_len, kh, g, d)

    # pass 2: kv-major -> dk, dv
    def dkv_step(_, kv_in):
        k_blk, v_blk, kp = kv_in

        def q_inner(carry, q_in):
            dk_acc, dv_acc = carry
            q_blk, qp, lse_blk, do_blk, dl_blk = q_in
            p, ds = ds_block(q_blk, k_blk, v_blk, qp, kp, lse_blk, do_blk,
                             dl_blk)
            dv_acc = dv_acc + jnp.einsum("bkgqt,bqkgd->btkd", p, do_blk)
            dk_acc = dk_acc + jnp.einsum("bkgqt,bqkgd->btkd", ds,
                                         q_blk.astype(jnp.float32)) * scale
            return (dk_acc, dv_acc), None

        z = jnp.zeros((b, kc, kh, d), jnp.float32)
        (dk_blk, dv_blk), _ = jax.lax.scan(
            q_inner, (z, z), (qs, qps, lses, dos, deltas))
        return None, (dk_blk, dv_blk)

    _, (dks, dvs) = jax.lax.scan(dkv_step, None, (ks, vs, kps))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, t, kh, d)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, t, kh, d)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def _blocked_attention_fwd_only(q, k, v, *, q_pos, k_pos, causal=True,
                                window: Optional[int] = None,
                                cap: Optional[float] = None,
                                q_chunk=Q_CHUNK, kv_chunk=KV_CHUNK):
    """Old all-in-one forward (kept for prefill where no grad is needed)."""
    b, s, kh, g, d = q.shape
    t = k.shape[1]
    qc = _pick_chunk(s, q_chunk)
    kc = _pick_chunk(t, kv_chunk)
    nq, nk = s // qc, t // kc
    scale = d ** -0.5

    qs = q.reshape(b, nq, qc, kh, g, d).transpose(1, 0, 2, 3, 4, 5)
    qps = q_pos.reshape(nq, qc)
    ks = k.reshape(b, nk, kc, kh, d).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nk, kc, kh, d).transpose(1, 0, 2, 3, 4)
    kps = k_pos.reshape(nk, kc)

    def q_step(_, q_in):
        q_blk, qp = q_in                              # (B,qc,K,G,D), (qc,)

        def kv_step(carry, kv_in):
            m_run, l_run, acc = carry
            k_blk, v_blk, kp = kv_in
            sc = jnp.einsum("bqkgd,btkd->bkgqt", q_blk.astype(jnp.float32),
                            k_blk.astype(jnp.float32)) * scale
            sc = softcap(sc, cap)
            msk = _mask(qp, kp, causal=causal, window=window)
            sc = jnp.where(msk[None, None, None], sc, -1e30)
            m_new = jnp.maximum(m_run, sc.max(axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p_blk = jnp.exp(sc - m_new[..., None])
            l_new = l_run * alpha + p_blk.sum(axis=-1)
            pv = jnp.einsum("bkgqt,btkd->bkgqd", p_blk, v_blk.astype(jnp.float32))
            acc = acc * alpha[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kh, g, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kh, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kh, g, qc, d), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, kps))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]   # (B,K,G,qc,D)
        return None, out.transpose(0, 3, 1, 2, 4)         # (B,qc,K,G,D)

    _, outs = jax.lax.scan(q_step, None, (qs, qps))       # (nq,B,qc,K,G,D)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, kh, g, d)
    return out.reshape(b, s, kh * g, d).astype(v.dtype)


# ---------------------------------------------------------------------------
# full-sequence attention entry point (train / prefill)
# ---------------------------------------------------------------------------

def attention(params, cfg: ModelConfig, spec: LayerSpec, x, positions, *,
              causal=True, kv_input=None, kv_positions=None, rope=True,
              use_kernel: bool = True):
    """Self (or cross-) attention over a full sequence. x: (B,S,E)."""
    q, k, v = _project_qkv(params, x, cfg, positions, rope=rope,
                           kv_input=kv_input, kv_positions=kv_positions)
    k_pos = positions if kv_positions is None else kv_positions
    s, t = q.shape[1], k.shape[1]
    if use_kernel and max(s, t) > BLOCKED_SEQ_THRESHOLD:
        out = blocked_attention(q, k, v, q_pos=positions, k_pos=k_pos,
                                causal=causal, window=spec.window,
                                cap=cfg.attn_softcap)
    else:
        out = naive_attention(q, k, v, q_pos=positions, k_pos=k_pos,
                              causal=causal, window=spec.window,
                              cap=cfg.attn_softcap)
    return jnp.einsum("bshd,hde->bse", out, params["wo"])


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array          # (B, C, Kh, D)
    v: jax.Array          # (B, C, Kh, D)
    slot_pos: jax.Array   # (C,) global position stored in each slot, -1 empty


def init_kv_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int,
                  *, decode_window: Optional[int] = None, dtype=jnp.float32,
                  n_kv=None, head_dim=None) -> KVCache:
    window = spec.window if spec.window is not None else decode_window
    c = max_len if window is None else min(window, max_len)
    kh = n_kv if n_kv is not None else cfg.n_kv_heads
    hd = head_dim if head_dim is not None else cfg.head_dim
    return KVCache(
        k=jnp.zeros((batch, c, kh, hd), dtype),
        v=jnp.zeros((batch, c, kh, hd), dtype),
        slot_pos=jnp.full((c,), -1, jnp.int32),
    )


def prefill_into_cache(params, cfg: ModelConfig, spec: LayerSpec, x, positions,
                       cache: KVCache, *, use_kernel=True):
    """Run full-seq attention AND populate the cache with the (windowed) tail."""
    q, k, v = _project_qkv(params, x, cfg, positions)
    s = q.shape[1]
    if use_kernel and s > BLOCKED_SEQ_THRESHOLD:
        out = blocked_attention(q, k, v, q_pos=positions, k_pos=positions,
                                window=spec.window, cap=cfg.attn_softcap)
    else:
        out = naive_attention(q, k, v, q_pos=positions, k_pos=positions,
                              window=spec.window, cap=cfg.attn_softcap)
    c = cache.k.shape[1]
    if c > s:  # cache has spare room: fill the first s slots
        pad = c - s
        padk = jnp.zeros((k.shape[0], pad) + k.shape[2:], cache.k.dtype)
        new_cache = KVCache(
            k=jnp.concatenate([k.astype(cache.k.dtype), padk], axis=1),
            v=jnp.concatenate([v.astype(cache.v.dtype), padk], axis=1),
            slot_pos=jnp.concatenate(
                [positions.astype(jnp.int32), jnp.full((pad,), -1, jnp.int32)]),
        )
    else:
        # keep the last ``c`` tokens, laid out ring-style (slot = pos % c)
        tail_k, tail_v, tail_pos = k[:, -c:], v[:, -c:], positions[-c:]
        slots = tail_pos % c
        order = jnp.argsort(slots)
        new_cache = KVCache(
            k=tail_k[:, order].astype(cache.k.dtype),
            v=tail_v[:, order].astype(cache.v.dtype),
            slot_pos=tail_pos[order].astype(jnp.int32),
        )
    return jnp.einsum("bshd,hde->bse", out, params["wo"]), new_cache


def decode_attention(params, cfg: ModelConfig, spec: LayerSpec, x, pos,
                     cache: KVCache):
    """One-token decode. x: (B,1,E); pos: scalar global position."""
    positions = jnp.asarray(pos, jnp.int32)[None]
    q, k_new, v_new = _project_qkv(params, x, cfg, positions)
    c = cache.k.shape[1]
    slot = jnp.asarray(pos % c, jnp.int32)
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, slot, 0, 0))
    slot_pos = jax.lax.dynamic_update_slice(cache.slot_pos,
                                            positions, (slot,))
    scale = q.shape[-1] ** -0.5
    sc = jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale     # (B,K,G,1,C)
    sc = softcap(sc, cfg.attn_softcap)
    window = spec.window
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        valid &= slot_pos > pos - window
    sc = jnp.where(valid[None, None, None, None, :], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v)
    b, s, kh, g, d = out.shape
    out = out.reshape(b, s, kh * g, d)
    y = jnp.einsum("bshd,hde->bse", out, params["wo"])
    return y, KVCache(k=k, v=v, slot_pos=slot_pos)
