"""Docs integrity: the files the docs subsystem promises exist, their
internal links resolve (tools/check_docs_links.py), and the architecture
page's module references point at real code — so the paper-to-code map
cannot silently rot as the tree moves."""

import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CHECKER = os.path.join(REPO, "tools", "check_docs_links.py")


def test_docs_exist_and_are_linked_from_readme():
    for rel in ("docs/ARCHITECTURE.md", "docs/REPRODUCING.md"):
        assert os.path.exists(os.path.join(REPO, rel)), rel
    readme = open(os.path.join(REPO, "README.md"), encoding="utf-8").read()
    assert "docs/ARCHITECTURE.md" in readme
    assert "docs/REPRODUCING.md" in readme


def test_internal_doc_links_resolve():
    proc = subprocess.run([sys.executable, CHECKER, REPO],
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr or proc.stdout


def test_link_checker_catches_breakage(tmp_path):
    (tmp_path / "README.md").write_text("see [missing](docs/nope.md) "
                                        "and [ok](ok.md)")
    (tmp_path / "ok.md").write_text("x")
    proc = subprocess.run([sys.executable, CHECKER, str(tmp_path)],
                          capture_output=True, text=True)
    assert proc.returncode == 1
    assert "nope.md" in proc.stderr


def test_architecture_module_references_exist():
    """Every `src/...` path or repo-relative module mentioned in the layer
    map's backtick tables must exist on disk."""
    text = open(os.path.join(REPO, "docs", "ARCHITECTURE.md"),
                encoding="utf-8").read()
    refs = set(re.findall(r"`(src/[\w/]+(?:\.py)?)`", text))
    refs |= {f"src/repro/{m}" for m in
             re.findall(r"`([a-z]+(?:/[a-z_]+\.py)?)/?`", text)
             if "/" in m and m.split("/")[0] in
             ("core", "federated", "runtime", "experiments", "launch",
              "kernels", "data", "models")}
    assert refs, "expected module references in ARCHITECTURE.md"
    for ref in sorted(refs):
        assert os.path.exists(os.path.join(REPO, ref)), ref
