"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.fed_aggregate import fed_aggregate
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("m,n", [(1, 256), (4, 1000), (16, 8192), (50, 4097)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fed_aggregate_sweep(m, n, dtype):
    ks = jax.random.split(KEY, 3)
    w = jax.random.uniform(ks[0], (m,), jnp.float32)
    w = w / w.sum()
    d = jax.random.normal(ks[1], (m, n)).astype(dtype)
    base = jax.random.normal(ks[2], (n,)).astype(dtype)
    got = fed_aggregate(w, d, base, interpret=True)
    want = ref.fed_aggregate_ref(w, d, base)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_fed_aggregate_is_weighted_mean():
    # aggregating identical deltas with normalized weights is identity
    d = jnp.ones((5, 100)) * 3.0
    w = jnp.full((5,), 0.2)
    got = fed_aggregate(w, d, interpret=True)
    np.testing.assert_allclose(np.asarray(got), 3.0, rtol=1e-6)


@pytest.mark.parametrize("b,h,kh,s,d", [
    (1, 2, 1, 128, 32), (2, 4, 2, 256, 64), (1, 4, 4, 256, 128),
])
@pytest.mark.parametrize("window,cap", [
    (None, None), (64, None), (None, 50.0), (96, 30.0),
])
def test_flash_attention_sweep(b, h, kh, s, d, window, cap):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, kh, s, d))
    v = jax.random.normal(ks[2], (b, kh, s, d))
    got = flash_attention(q, k, v, window=window, cap=cap,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtype(dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(dtype)
    got = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,t,w", [(1, 128, 128), (2, 256, 128),
                                   (4, 128, 512), (3, 192, 384)])
def test_rglru_scan_sweep(b, t, w):
    ks = jax.random.split(KEY, 2)
    a = jax.random.uniform(ks[0], (b, t, w), minval=0.5, maxval=0.999)
    x = jax.random.normal(ks[1], (b, t, w)) * 0.1
    got = rglru_scan(a, x, block_b=1, block_w=128, chunk_t=64, interpret=True)
    want = ref.rglru_scan_ref(a, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_rglru_scan_decay_property():
    """With b=0 everywhere, h stays 0; with a=0, h_t = b_t."""
    a = jnp.full((1, 64, 128), 0.9)
    z = jnp.zeros((1, 64, 128))
    out = rglru_scan(a, z, chunk_t=32, block_b=1, block_w=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out), 0.0)
    b = jax.random.normal(KEY, (1, 64, 128))
    out2 = rglru_scan(jnp.zeros_like(b), b, chunk_t=32, block_b=1,
                      block_w=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(b), rtol=1e-6)
