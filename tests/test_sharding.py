"""Partition-rule unit tests (no multi-device mesh needed)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.launch.steps import param_struct
from repro.sharding import specs as sh


class FakeMesh:
    """Minimal mesh stand-in exposing .shape for fit_spec."""

    def __init__(self, **axes):
        self.shape = axes


def test_fit_spec_drops_non_dividing_axes():
    mesh = FakeMesh(data=16, model=16)
    assert sh.fit_spec(P("data", "model"), (32, 4), mesh) == P("data", None)
    assert sh.fit_spec(P("data",), (7,), mesh) == P(None)
    assert sh.fit_spec(P(("data", "model")), (256,), mesh) == \
        P(("data", "model"))
    # partial tuple fit: 16 divides, 256 doesn't
    assert sh.fit_spec(P(("data", "model")), (16,), mesh) == P("data")


def test_param_specs_train_rules():
    cfg = reduced(get_config("qwen2-7b"))
    struct = param_struct(cfg, stacked=False)
    rules = sh.train_rules(False)
    specs = sh.param_specs(struct, rules)
    l0 = specs["layers"][0]
    assert l0["mixer"]["wq"] == P("data", "model", None)
    assert l0["ffn"]["w_gate"] == P("data", "model")
    assert l0["ffn"]["w_down"] == P("model", "data")
    assert l0["ln1"] == P()
    assert specs["embed"] == P("model", "data")


def test_param_specs_stacked_get_leading_none():
    cfg = get_config("gemma2-2b")
    struct = param_struct(cfg, stacked=True)
    rules = sh.train_rules(False)
    specs = sh.param_specs(struct, rules)
    st0 = specs["stacked"][0]
    assert st0["mixer"]["wq"] == P(None, "data", "model", None)


def test_moe_expert_weights_sharded_as_ep():
    cfg = get_config("dbrx-132b")
    struct = param_struct(cfg, stacked=True)
    specs = sh.param_specs(struct, sh.train_rules(False))
    st0 = specs["stacked"][0]
    assert st0["ffn"]["we_gate"] == P(None, "model", "data", None)


def test_decode_rules_seq_shard_for_tiny_batch():
    r = sh.decode_rules(False, shard_seq=True)
    assert r["batch"] is None
    assert r["cache_seq"] == ("data", "model")
    r2 = sh.decode_rules(False, shard_seq=False)
    assert r2["cache_seq"] == "model"


def test_multipod_batch_spans_pod_and_data():
    r = sh.train_rules(True)
    assert r["batch"] == ("pod", "data")


def test_logical_constraint_is_identity_outside_context():
    from repro.sharding.ctx import logical_constraint
    x = jnp.ones((4, 4))
    assert logical_constraint(x, ("batch", "embed")) is x
