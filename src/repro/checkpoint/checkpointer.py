"""Minimal npz-based pytree checkpointing (no orbax in this container).

Flattens the pytree with path-derived keys; restores into the same
treedef.  Works for params, optimizer state, and FL server state.
"""

from __future__ import annotations

import json
import pathlib
from typing import Any

import jax
import numpy as np


def _key(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        elif hasattr(e, "name"):
            parts.append(str(e.name))
        else:
            parts.append(str(e))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Any, *, step: int = 0,
                    metadata: dict | None = None):
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    leaves = {}
    jax.tree_util.tree_map_with_path(
        lambda p, x: leaves.setdefault(_key(p), np.asarray(x)), tree)
    np.savez(path.with_suffix(".npz"), **leaves)
    meta = {"step": step, **(metadata or {})}
    path.with_suffix(".json").write_text(json.dumps(meta))
    return str(path.with_suffix(".npz"))


def load_checkpoint(path: str, like: Any):
    """Restore into the structure of ``like`` (a template pytree)."""
    path = pathlib.Path(path)
    data = np.load(path.with_suffix(".npz"))
    restored = jax.tree_util.tree_map_with_path(
        lambda p, x: jax.numpy.asarray(data[_key(p)]), like)
    meta = {}
    if path.with_suffix(".json").exists():
        meta = json.loads(path.with_suffix(".json").read_text())
    return restored, meta
