"""Paper Fig. 8 / Fig. 9: the penalty mechanism.  Runs the degraded
preferences with D=1 (no penalty) vs D=10 (full FedTune)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import (BenchSettings, emit, fedtune_for, improvement,
                               run_fl)
from repro.core.preferences import Preference

# the three preferences the paper reports as degraded without penalty
DEGRADED = (
    Preference(0.0, 0.5, 0.5, 0.0),
    Preference(0.0, 0.5, 0.0, 0.5),
    Preference(1 / 3, 1 / 3, 0.0, 1 / 3),
)


def main(settings: BenchSettings):
    base = run_fl("emnist", settings, aggregator="fedavg")
    for d_factor in (1.0, 10.0):
        gains = []
        for pref in DEGRADED:
            tuner = fedtune_for(pref, settings.m0, settings.e0,
                                penalty=d_factor)
            res = run_fl("emnist", settings, tuner=tuner,
                         aggregator="fedavg")
            g = improvement(pref, base.total_cost, res.total_cost)
            gains.append(g)
            emit(f"fig8/D={d_factor:g}/{pref}", res.wall * 1e6,
                 f"gain={g:+.2f}%")
        emit(f"fig9/D={d_factor:g}", 0.0,
             f"mean_gain={np.mean(gains):+.2f}%;std={np.std(gains):.2f}")
