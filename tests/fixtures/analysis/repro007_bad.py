"""BAD fixture: broad handlers that swallow bugs.  REPRO007 fires on
both the ``except Exception`` and the bare ``except``."""


def load(path):
    try:
        return open(path).read()
    except Exception:        # REPRO007: swallows everything
        return None


def parse(text):
    try:
        return int(text)
    except:                  # REPRO007: bare except
        return 0
