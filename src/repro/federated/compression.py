"""Upload compression (beyond-paper): int8-quantized client deltas.

Clients upload quantized (theta_k - theta) instead of full-precision
parameters, cutting the paper's TransL by ~4x on the upload half of each
round; the server dequantizes before aggregation.  This composes with
FedTune: the controller sees the reduced TransL through the cost model's
``upload_factor`` and steers (M, E) accordingly.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

# bytes(transmitted)/bytes(f32) for the upload half of a round
FACTORS = {None: 1.0, "none": 1.0, "int8": 0.25 + 1e-3}


def compress_delta(global_params: Any, client_params: Any,
                   method: str = "int8") -> Any:
    """Simulate the quantize->transmit->dequantize round trip and return the
    client params the SERVER reconstructs."""
    if method in (None, "none"):
        return client_params

    def roundtrip(g, c):
        delta = (c - g).astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(delta)) / 127.0, 1e-12)
        q = jnp.clip(jnp.round(delta / scale), -127, 127).astype(jnp.int8)
        return (g + (q.astype(jnp.float32) * scale).astype(g.dtype))

    return jax.tree.map(roundtrip, global_params, client_params)


def upload_factor(method: str | None) -> float:
    try:
        return FACTORS[method]
    except KeyError:
        valid = ", ".join(repr(k) for k in FACTORS)
        raise ValueError(
            f"unknown compression method {method!r}; valid methods: {valid}"
        ) from None
