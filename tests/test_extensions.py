"""Beyond-paper extensions: participant selection, upload compression,
adaptive-step FedTune."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costs import CostModel
from repro.core.fedtune import FedTune, FedTuneConfig
from repro.core.preferences import Preference
from repro.core.tuner import HyperParams
from repro.federated.compression import compress_delta, upload_factor
from repro.federated.selection import get_selector


def test_selectors_return_unique_valid_ids():
    rng = np.random.default_rng(0)
    sizes = rng.integers(1, 100, 64)
    for name in ("random", "guided", "smallest"):
        sel = get_selector(name, 64, rng, client_sizes=sizes)
        ids = sel.select(10)
        assert len(np.unique(ids)) == len(ids)
        assert ids.min() >= 0 and ids.max() < 64


def test_guided_prefers_high_loss_clients():
    rng = np.random.default_rng(0)
    sel = get_selector("guided", 20, rng)
    for cid in range(20):
        sel.update(cid, loss=10.0 if cid < 3 else 0.01, n_examples=10)
    picks = [set(sel.select(5)) for _ in range(10)]
    hits = sum(len({0, 1, 2} & p) for p in picks) / 10
    assert hits >= 2.5, "guided selection should exploit high-loss clients"


def test_smallest_selector_bounds_straggler():
    rng = np.random.default_rng(0)
    sizes = np.arange(1, 65)
    sel = get_selector("smallest", 64, rng, client_sizes=sizes)
    ids = sel.select(8)
    assert sizes[ids].max() <= 16  # picks from the small half


def test_int8_compression_roundtrip_close():
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (64, 32))}
    c = {"w": g["w"] + 0.01 * jax.random.normal(key, (64, 32))}
    rec = compress_delta(g, c, "int8")
    err = float(jnp.abs(rec["w"] - c["w"]).max())
    scale = float(jnp.abs(c["w"] - g["w"]).max())
    assert err <= scale / 100  # 127-level quantization of the delta


def test_upload_factor_reduces_translocost():
    cm_full = CostModel(1e6, 1e5)
    cm_comp = CostModel(1e6, 1e5)
    r1 = cm_full.add_round([10] * 5, 1.0, upload_factor=1.0)
    r2 = cm_comp.add_round([10] * 5, 1.0,
                           upload_factor=upload_factor("int8"))
    assert r2.trans_l < 0.7 * r1.trans_l
    assert r2.comp_l == r1.comp_l


def test_adaptive_step_fedtune_moves_faster():
    pref = Preference(0.0, 0.0, 1.0, 0.0)
    plain = FedTune(FedTuneConfig(preference=pref), HyperParams(20, 20))
    adaptive = FedTune(FedTuneConfig(preference=pref, adaptive_step=True),
                       HyperParams(20, 20))
    from repro.core.costs import SystemCost
    acc = 0.0
    hp_p = hp_a = HyperParams(20, 20)
    for r in range(12):
        acc += 0.02
        cost_p = SystemCost(1, 1, float(hp_p.m * hp_p.e) * 100, 1)
        cost_a = SystemCost(1, 1, float(hp_a.m * hp_a.e) * 100, 1)
        hp_p = plain.on_round(r, acc, cost_p, cost_p, hp_p)
        hp_a = adaptive.on_round(r, acc, cost_a, cost_a, hp_a)
    assert hp_a.m + hp_a.e <= hp_p.m + hp_p.e
