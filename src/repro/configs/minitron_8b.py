"""minitron-8b — 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
Width/depth-pruned Nemotron-4.  [arXiv:2407.14679]"""

from repro.configs.base import ModelConfig, uniform_layers

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab_size=256_000,
    layers=uniform_layers(32),
    tie_embeddings=False,
    source="arXiv:2407.14679",
)
