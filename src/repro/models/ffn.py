"""Feed-forward layers: gated dense MLP and top-k Mixture-of-Experts.

The MoE uses capacity-based scatter dispatch (TPU-native): tokens are routed
to per-expert buffers of fixed capacity via cumsum-position one-hot logic, the
expert matmuls run as a single batched einsum over the expert dim (shardable
as expert parallelism), and outputs are gathered back and combined with router
weights.  Overflowing tokens are dropped (standard Switch-style), and a
load-balance auxiliary loss is returned.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.common import activation, dense_init
from repro.sharding.ctx import logical_constraint

CAPACITY_FACTOR = 1.25

# MoE execution strategy:
#   "dispatch" — capacity-based scatter dispatch (exact FLOPs, token drops).
#     Right on hosts and small meshes; GSPMD lowers the scatter poorly at
#     256-way SPMD (replicates the dispatch buffer), so:
#   "dense"    — masked dense-expert compute (top-k semantics preserved
#     exactly, NO drops, E/k x FLOP overcompute).  GSPMD-friendly: pure
#     einsums, experts sharded over "model", tokens over "data".  Used by
#     the distributed train step; the overcompute shows up honestly in the
#     roofline useful-FLOPs ratio.  See DESIGN.md (hardware adaptation).
#   "hierarchical" — §Perf H1: per-data-shard local scatter dispatch
#     (vmapped over shard rows so the scatter is batched and partitionable),
#     expert einsums at exact capacity FLOPs (1.25x active, vs E/k x dense).
_MOE_IMPL = "dispatch"
_MOE_ROWS = 16            # data-shard rows for the hierarchical impl


import contextlib


@contextlib.contextmanager
def moe_impl(kind: str, rows: int = 16):
    global _MOE_IMPL, _MOE_ROWS
    assert kind in ("dispatch", "dense", "hierarchical")
    prev, prev_rows = _MOE_IMPL, _MOE_ROWS
    _MOE_IMPL = kind
    _MOE_ROWS = rows
    try:
        yield
    finally:
        _MOE_IMPL = prev
        _MOE_ROWS = prev_rows


# ---------------------------------------------------------------------------
# dense gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp_params(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp(params, x, act_name: str = "silu"):
    act = activation(act_name)
    h = act(jnp.einsum("bse,ef->bsf", x, params["w_gate"]))
    h = h * jnp.einsum("bse,ef->bsf", x, params["w_up"])
    h = logical_constraint(h, ("batch", None, "ff"))
    return jnp.einsum("bsf,fe->bse", h, params["w_down"])


# ---------------------------------------------------------------------------
# mixture of experts
# ---------------------------------------------------------------------------

def init_moe_params(key, d_model: int, moe: MoEConfig, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    e, f = moe.n_experts, moe.d_ff_expert
    return {
        "router": dense_init(k1, (d_model, e), dtype),
        "we_gate": dense_init(k2, (e, d_model, f), dtype),
        "we_up": dense_init(k3, (e, d_model, f), dtype),
        "we_down": dense_init(k4, (e, f, d_model), dtype, fan_in=f),
    }


def _shard_expert_buf(x):  # (E, C, d): experts -> model, capacity -> data
    return logical_constraint(x, ("expert", "moe_capacity", "embed"))


def _route(params, xf, moe: MoEConfig):
    """Router shared by both MoE impls. xf: (T, d)."""
    e, k = moe.n_experts, moe.top_k
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)             # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss over the top-1 assignment fractions.
    top1_onehot = jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32)
    frac_tokens = top1_onehot.mean(axis=0)
    frac_probs = probs.mean(axis=0)
    aux = moe.aux_loss_coef * e * jnp.sum(frac_tokens * frac_probs)
    return gate_vals, expert_ids, aux


def moe_ffn(params, x, moe: MoEConfig, act_name: str = "silu",
            capacity_factor: float = CAPACITY_FACTOR
            ) -> Tuple[jax.Array, jax.Array]:
    if _MOE_IMPL == "dense":
        return moe_ffn_dense(params, x, moe, act_name)
    if _MOE_IMPL == "hierarchical":
        return moe_ffn_hierarchical(params, x, moe, act_name,
                                    rows=_MOE_ROWS,
                                    capacity_factor=capacity_factor)
    return moe_ffn_dispatch(params, x, moe, act_name, capacity_factor)


def moe_ffn_hierarchical(params, x, moe: MoEConfig, act_name: str = "silu",
                         *, rows: int = 16,
                         capacity_factor: float = CAPACITY_FACTOR
                         ) -> Tuple[jax.Array, jax.Array]:
    """§Perf H1: capacity dispatch with the scatter BATCHED over data-shard
    rows.  Each row dispatches its own tokens into (E, C_row, d) buffers —
    a batched scatter GSPMD can partition on the row dim — then a single
    expert einsum runs at exact capacity FLOPs (~1.25x active params)."""
    b, s, d = x.shape
    e, k = moe.n_experts, moe.top_k
    t = b * s
    if t % rows or t // rows < e:
        return moe_ffn_dense(params, x, moe, act_name)
    xf = x.reshape(t, d)
    gate_vals, expert_ids, aux = _route(params, xf, moe)
    tr = t // rows
    capacity = int(max(1, capacity_factor * tr * k / e))
    capacity = (capacity + 7) // 8 * 8

    xr = xf.reshape(rows, tr, d)
    ids_r = expert_ids.reshape(rows, tr, k)
    gv_r = gate_vals.reshape(rows, tr, k)

    def dispatch_row(xrow, ids):
        flat_ids = ids.reshape(-1)                          # (tr*k,)
        onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)
        pos_all = jnp.cumsum(onehot, axis=0) - onehot
        pos = jnp.take_along_axis(pos_all, flat_ids[:, None], 1)[:, 0]
        keep = pos < capacity
        dest = jnp.where(keep, flat_ids * capacity + pos, e * capacity - 1)
        xk = jnp.repeat(xrow[:, None, :], k, axis=1).reshape(tr * k, d)
        xk = jnp.where(keep[:, None], xk, jnp.zeros((1, d), xk.dtype))
        buf = jnp.zeros((e * capacity, d), xrow.dtype).at[dest].add(xk)
        return buf.reshape(e, capacity, d), keep, dest

    bufs, keeps, dests = jax.vmap(dispatch_row)(xr, ids_r)  # (R,E,C,d)
    bufs = logical_constraint(bufs, ("moe_tokens", "expert", None, None))

    act = activation(act_name)
    h = act(jnp.einsum("recd,edf->recf", bufs, params["we_gate"]))
    h = h * jnp.einsum("recd,edf->recf", bufs, params["we_up"])
    h = logical_constraint(h, ("moe_tokens", "expert", None, None))
    out_buf = jnp.einsum("recf,efd->recd", h, params["we_down"])
    out_buf = logical_constraint(out_buf, ("moe_tokens", "expert", None, None))

    def combine_row(ob, keep, dest, gv):
        flat = ob.reshape(e * capacity, d)
        gathered = jnp.where(keep[:, None], flat[dest],
                             jnp.zeros((1, d), flat.dtype))
        return (gathered.reshape(tr, k, d)
                * gv[..., None].astype(flat.dtype)).sum(axis=1)

    y = jax.vmap(combine_row)(out_buf, keeps, dests, gv_r)
    return y.reshape(b, s, d), aux.astype(x.dtype)


def moe_ffn_dense(params, x, moe: MoEConfig, act_name: str = "silu"
                  ) -> Tuple[jax.Array, jax.Array]:
    """Masked dense-expert MoE: every expert sees every token; the top-k
    combine mask zeroes the rest.  Numerically = capacity-infinite top-k."""
    b, s, d = x.shape
    e, k = moe.n_experts, moe.top_k
    t = b * s
    xf = x.reshape(t, d)
    gate_vals, expert_ids, aux = _route(params, xf, moe)
    # combine weights (T, E) via one-hot sum over the k slots
    combine = (jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)
               * gate_vals[..., None]).sum(axis=1)              # (T, E)
    combine = logical_constraint(combine, ("moe_tokens", "expert"))
    act = activation(act_name)
    h = act(jnp.einsum("td,edf->etf", xf, params["we_gate"]))
    h = h * jnp.einsum("td,edf->etf", xf, params["we_up"])
    h = logical_constraint(h, ("expert", "moe_tokens", None))
    y_e = jnp.einsum("etf,efd->etd", h, params["we_down"])
    y_e = logical_constraint(y_e, ("expert", "moe_tokens", None))
    y = jnp.einsum("etd,te->td", y_e, combine.astype(y_e.dtype))
    return y.reshape(b, s, d), aux.astype(x.dtype)


def moe_ffn_dispatch(params, x, moe: MoEConfig, act_name: str = "silu",
                     capacity_factor: float = CAPACITY_FACTOR
                     ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    b, s, d = x.shape
    e, k = moe.n_experts, moe.top_k
    t = b * s
    xf = x.reshape(t, d)
    gate_vals, expert_ids, aux = _route(params, xf, moe)

    capacity = int(max(1, capacity_factor * t * k / e))
    # pad capacity to a lane-friendly multiple of 8
    capacity = (capacity + 7) // 8 * 8

    # position of each (token, slot) within its expert queue
    flat_ids = expert_ids.reshape(-1)                           # (T*k,)
    onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)       # (T*k, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - onehot)       # (T*k, E)
    pos = jnp.take_along_axis(pos_in_expert, flat_ids[:, None], axis=1)[:, 0]
    keep = pos < capacity
    dest = jnp.where(keep, flat_ids * capacity + pos, e * capacity)  # overflow bin

    # scatter-add tokens into expert buffers; dropped tokens are zeroed and
    # land (harmlessly, additively) in the last slot.  Explicit sharding
    # constraints keep GSPMD from replicating the flat dispatch buffers.
    xk = jnp.repeat(xf[:, None, :], k, axis=1).reshape(t * k, d)
    xk = jnp.where(keep[:, None], xk, jnp.zeros((1, d), xk.dtype))
    xk = logical_constraint(xk, ("moe_tokens", None))
    dest_c = jnp.minimum(dest, e * capacity - 1)
    buf = jnp.zeros((e * capacity, d), x.dtype).at[dest_c].add(xk)
    buf = buf.reshape(e, capacity, d)
    buf = _shard_expert_buf(buf)

    act = activation(act_name)
    h = act(jnp.einsum("ecd,edf->ecf", buf, params["we_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["we_up"])
    h = logical_constraint(h, ("expert", "moe_capacity", None))
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["we_down"])
    out_buf = _shard_expert_buf(out_buf)

    # gather back to (T*k, d); dropped tokens contribute zero
    out_flat = out_buf.reshape(e * capacity, d)
    gathered = jnp.where(
        keep[:, None],
        out_flat[jnp.minimum(dest, e * capacity - 1)],
        jnp.zeros((1, d), out_flat.dtype))
    gathered = logical_constraint(gathered, ("moe_tokens", None))
    combined = (gathered.reshape(t, k, d)
                * gate_vals[..., None].astype(out_flat.dtype)).sum(axis=1)
    return combined.reshape(b, s, d), aux.astype(x.dtype)
