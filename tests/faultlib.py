"""Deterministic fault scripting for the chaos tests (tests only).

A ``FaultPlan`` scripts WHAT goes wrong and WHEN, entirely on the virtual
clock and the scheduler's macro-step counter, so every chaos interleaving
is a pure function of its seed:

  * client failures — either a hazard rate (the production stateless-hash
    model, reached through ``TrialSpec.failure_rate``) or an exact script
    installed as ``Fleet.failure_fn`` ("client c's dispatches hard-fail
    while t is inside [lo, hi), for its first k attempts");
  * fleet churn — a ``ChurnSchedule`` spec string (``"period:rate"``);
  * coordinator kills — a sequence of per-incarnation macro-step budgets
    after which the serving daemon dies mid-drain (the same
    ``drain(max_steps=...)`` break the CLI's ``--kill-after-steps`` uses,
    which deliberately skips the final boundary snapshot).

``serve_with_kills`` is the harness: it drains one queue through as many
scheduler incarnations as the plan has kills, restoring each successor
from the two-slot snapshot, and returns the final store rows for parity
asserts against a single uninterrupted serve.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field, replace
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.experiments import ResultStore, TrialSpec
from repro.experiments.scheduler import TrialQueue, TrialScheduler
from repro.runtime.profiles import Fleet


# ---------------------------------------------------------------------------
# scripted per-client failure windows (engine-level tests)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FailureWindow:
    """Client ``cid`` hard-fails any dispatch whose failure check lands in
    ``[lo, hi)`` virtual seconds, but only for attempts < ``max_attempt``
    (so a retry can be scripted to succeed)."""
    cid: int
    lo: float = 0.0
    hi: float = np.inf
    max_attempt: int = 10**9

    def matches(self, cid: int, t: float, attempt: int) -> bool:
        return (cid == self.cid and self.lo <= t < self.hi
                and attempt < self.max_attempt)


def scripted_failure_fn(windows: Sequence[FailureWindow]):
    """A ``Fleet.failure_fn`` that fails exactly the scripted windows."""
    ws = tuple(windows)

    def fn(cid: int, t: float, attempt: int) -> bool:
        return any(w.matches(cid, t, attempt) for w in ws)

    return fn


def install_failures(fleet: Fleet, windows: Sequence[FailureWindow]) -> Fleet:
    """Mutate ``fleet`` in place to fail exactly the scripted windows
    (``failure_fn`` overrides any hazard array) and return it."""
    fleet.failure_fn = scripted_failure_fn(windows)
    return fleet


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------

_CHURN_MENU = (None, "8:0.2", "15:0.3", "12:0.4:2", "20:0.15")


@dataclass(frozen=True)
class FaultPlan:
    """One seeded chaos scenario over a served queue.

    ``kill_steps`` are PER-INCARNATION macro-step budgets: ``(3, 5)``
    means the first coordinator dies after 3 macro-steps, its restored
    successor dies after 5 more, and the third incarnation drains to
    completion.  A zero budget is skipped (a coordinator that dies before
    stepping never wrote a newer snapshot, so it is indistinguishable
    from the previous kill)."""
    failure_rate: float = 0.0
    churn: Optional[str] = None
    kill_steps: Tuple[int, ...] = ()
    snapshot_every: int = 1
    seed: int = 0

    @classmethod
    def random(cls, seed: int, *, max_kills: int = 3,
               max_budget: int = 8) -> "FaultPlan":
        """A plan drawn deterministically from ``seed`` — the fallback
        "strategy" when hypothesis is unavailable, and the scenario
        decoder when it is (hypothesis supplies the seed)."""
        rng = np.random.default_rng(seed)
        rate = float(rng.choice([0.0, 0.05, 0.1, 0.2, 0.3]))
        churn = _CHURN_MENU[int(rng.integers(len(_CHURN_MENU)))]
        n_kills = int(rng.integers(0, max_kills + 1))
        kills = tuple(int(k) for k in rng.integers(1, max_budget + 1,
                                                   size=n_kills))
        every = int(rng.choice([1, 1, 2, 3]))
        return cls(failure_rate=rate, churn=churn, kill_steps=kills,
                   snapshot_every=every, seed=seed)

    def perturb(self, spec: TrialSpec) -> TrialSpec:
        """The spec with this plan's failure/churn knobs applied."""
        return replace(spec, failure_rate=self.failure_rate,
                       churn=self.churn)


# ---------------------------------------------------------------------------
# the kill/restore harness
# ---------------------------------------------------------------------------

@dataclass
class ChaosOutcome:
    """What one ``serve_with_kills`` run produced."""
    store: ResultStore
    sched: TrialScheduler                 # the final incarnation
    incarnations: int = 1
    duplicates_suppressed: int = 0
    rows: List[dict] = field(default_factory=list)
    steps_executed: List[int] = field(default_factory=list)  # per incarnation

    def rows_sans_wall(self) -> List[dict]:
        """Store rows with the volatile wall-clock field dropped — the
        bit-parity comparison unit."""
        out = []
        for d in self.rows:
            d = dict(d)
            d.pop("wall", None)
            out.append(d)
        return out


def _read_rows(path: str) -> List[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def serve_with_kills(specs: Sequence[TrialSpec], plan: FaultPlan,
                     tmp_path, *, max_lanes: int = 3,
                     pack: str = "batched") -> ChaosOutcome:
    """Drain ``specs`` through ``len(plan.kill_steps) + 1`` scheduler
    incarnations, each successor restored from the two-slot snapshot the
    previous one left at its last boundary.  The store accumulates across
    incarnations exactly as the JSONL file would across real daemon
    restarts."""
    store = ResultStore(str(tmp_path / f"chaos_{plan.seed}.jsonl"))
    snap = str(tmp_path / f"chaos_{plan.seed}.snap")
    sched = TrialScheduler(
        TrialQueue(specs=list(specs), completed=store.completed_keys()),
        max_lanes=max_lanes, store=store, pack=pack,
        snapshot_path=snap, snapshot_every=plan.snapshot_every)
    executed: List[int] = []
    dead: List[TrialScheduler] = []      # incarnations that were killed
    for budget in plan.kill_steps:
        if budget <= 0:
            continue
        before = sched.stats.steps
        sched.drain(max_steps=budget)
        executed.append(sched.stats.steps - before)
        if not sched.pool.n_live and not sched.queue:
            break            # fully drained (final snapshot written)
        # the coordinator dies HERE — no final snapshot was written for a
        # max_steps exit, so the successor replays from the last boundary
        dead.append(sched)
        sched = TrialScheduler.restore(snap, store=store, pack=pack,
                                       snapshot_every=plan.snapshot_every)
        for key in store.completed_keys():
            sched.queue.mark_done(key)
    before = sched.stats.steps
    sched.drain()
    executed.append(sched.stats.steps - before)
    dupes = (sum(s.duplicates_suppressed for s in dead)
             + sched.duplicates_suppressed)
    return ChaosOutcome(store=store, sched=sched,
                        incarnations=len(dead) + 1,
                        duplicates_suppressed=dupes,
                        rows=_read_rows(store.path),
                        steps_executed=executed)


def serve_uninterrupted(specs: Sequence[TrialSpec], tmp_path, *,
                        max_lanes: int = 3, pack: str = "batched",
                        tag: str = "ref") -> ChaosOutcome:
    """The fault-free-coordinator reference: same queue, same lane count,
    no kills, no snapshots (snapshots must be write-only observers)."""
    store = ResultStore(str(tmp_path / f"{tag}.jsonl"))
    sched = TrialScheduler(
        TrialQueue(specs=list(specs), completed=store.completed_keys()),
        max_lanes=max_lanes, store=store, pack=pack)
    sched.drain()
    return ChaosOutcome(store=store, sched=sched,
                        rows=_read_rows(store.path))
