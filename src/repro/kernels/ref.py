"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def fed_aggregate_ref(weights, deltas, base=None):
    """weights: (M,), deltas: (M, N) -> (N,). Optionally adds ``base``."""
    out = jnp.einsum("m,mn->n", weights.astype(jnp.float32),
                     deltas.astype(jnp.float32))
    if base is not None:
        out = out + base.astype(jnp.float32)
    return out.astype(deltas.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window: Optional[int] = None,
                        cap: Optional[float] = None):
    """q: (B, H, S, D); k, v: (B, Kh, T, D) with H % Kh == 0 -> (B, H, S, D)."""
    b, h, s, d = q.shape
    kh, t = k.shape[1], k.shape[2]
    g = h // kh
    qr = q.reshape(b, kh, g, s, d).astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qr, k.astype(jnp.float32))
    scores = scores * (d ** -0.5)
    if cap is not None:
        scores = cap * jnp.tanh(scores / cap)
    q_pos = jnp.arange(s)
    k_pos = jnp.arange(t)
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None] + (t - s)
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] + (t - s) - window
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(q.dtype)


def rglru_scan_ref(a, b, h0=None):
    """h_t = a_t * h_{t-1} + b_t over axis 1. a, b: (B, T, W)."""
    bsz, t, w = a.shape
    h = jnp.zeros((bsz, w), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    _, hs = jax.lax.scan(step, h, (a.astype(jnp.float32).transpose(1, 0, 2),
                                   b.astype(jnp.float32).transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2).astype(a.dtype)
