"""Rule modules — importing this package registers every rule.

Each module owns one rule id; add a module here (and to the import list)
to ship a new rule.  See docs/ANALYSIS.md for the catalog and the
how-to-add-a-rule walkthrough.
"""

from . import (  # noqa: F401 (imported for registration side effect)
    repro001_eager_param_math,
    repro002_unsorted_iteration,
    repro003_tracer_unsafe,
    repro004_wall_clock,
    repro005_obs_coverage,
    repro006_jit_cache,
    repro007_broad_except,
)
