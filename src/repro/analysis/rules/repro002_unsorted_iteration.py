"""REPRO002 — unsorted dict/set iteration feeding order-sensitive state.

The engine and runner are deterministic only because every hash-ordered
container on an order-critical path remembered to ``sorted(...)`` first
(``experiments/runner.py`` bucket packing and spec ordering are the
canonical survivors).  Dict insertion order is deterministic *within*
one process, but sets are salted per process, and both silently reorder
when someone refactors the insertion site — so any iteration over a
``.keys()/.values()/.items()`` view, a ``set(...)``, or a set literal
whose loop body consumes RNG, pushes events, or packs buckets must be
wrapped in ``sorted(...)`` or justified.
"""

from __future__ import annotations

import ast

from ..core import FileContext, Rule, register
from ..scopes import dotted_parts, final_name

# attribute calls inside the loop body that make order observable
SINK_METHODS = {"push", "requeue", "select", "choice", "shuffle",
                "permutation", "integers", "normal", "uniform",
                "standard_normal"}
# plain function calls with the same property (repo-specific order sinks)
SINK_FUNCS = {"materialize_streams", "client_batches", "bucket_by_steps",
              "select_clients"}


def _iterates_hash_order(it: ast.AST) -> bool:
    """True for d.keys()/.values()/.items(), set(...), or a set literal
    — NOT when already wrapped in sorted(...)."""
    if isinstance(it, ast.Call):
        name = final_name(it.func)
        if name in {"keys", "values", "items"} \
                and isinstance(it.func, ast.Attribute):
            return True
        if name == "set":
            return True
    return isinstance(it, (ast.Set, ast.SetComp))


def _body_has_order_sink(body) -> bool:
    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call):
                name = final_name(node.func)
                if isinstance(node.func, ast.Attribute) \
                        and name in SINK_METHODS:
                    return True
                if name in SINK_FUNCS:
                    return True
            # any touch of an rng object counts as RNG consumption
            if isinstance(node, (ast.Name, ast.Attribute)):
                if any("rng" in p.lower().split("_") or p == "rng"
                       for p in dotted_parts(node)):
                    return True
    return False


@register
class UnsortedIteration(Rule):
    id = "REPRO002"
    name = "unsorted-order-sensitive-iteration"

    def check_file(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.For, ast.AsyncFor)):
                continue
            if not _iterates_hash_order(node.iter):
                continue
            if not _body_has_order_sink(node.body):
                continue
            what = ("set" if isinstance(node.iter, (ast.Set, ast.SetComp))
                    or (isinstance(node.iter, ast.Call)
                        and final_name(node.iter.func) == "set")
                    else "dict view")
            ctx.add(node, self.id,
                    f"iteration over an unsorted {what} feeds an "
                    "order-sensitive operation (RNG/event-queue/bucket "
                    "packing) — wrap the iterable in sorted(...)")
