"""Tests for the event-driven heterogeneous runtime (repro.runtime):
virtual-clock determinism, sync-mode equivalence with the legacy FLServer
loop, staleness weighting, straggler cutoff, and batched-vs-sequential
client-training parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import MLPConfig
from repro.core import CostModel
from repro.data.synthetic import DataSpec, make_dataset
from repro.federated import FLConfig, FLServer, get_aggregator
from repro.federated.aggregation import (FedBuffAggregator,
                                         apply_async_update,
                                         staleness_weight)
from repro.federated.client import local_train
from repro.models import build_model
from repro.optim.optimizers import get_optimizer
from repro.runtime import (EventQueue, RuntimeConfig, VirtualClock,
                           batched_local_train, homogeneous_fleet,
                           sample_fleet)


def small_dataset(seed=1):
    return make_dataset(DataSpec(
        name="rt_test", n_classes=4, shape=(12,), n_train_clients=24,
        n_test_clients=8, size_log_mean=2.5, size_log_std=0.5, seed=seed))


def mk_server(*, rt=None, fleet=None, max_rounds=4, m=5, e=2.0,
              selection="random", compression=None):
    ds = small_dataset()
    model = build_model(MLPConfig(name="mlp_rt", in_dim=12, hidden=(16,),
                                  n_classes=4))
    n_params = sum(p.size for p in jax.tree.leaves(
        model.init(jax.random.PRNGKey(0))))
    return FLServer(
        model, ds, get_aggregator("fedavg"),
        get_optimizer("sgd", 0.05, momentum=0.9),
        CostModel(flops_per_example=2 * n_params, param_count=n_params),
        FLConfig(m=m, e=e, batch_size=4, target_accuracy=0.99,
                 max_rounds=max_rounds, eval_points=128,
                 selection=selection, compression=compression),
        fleet=fleet, runtime_config=rt)


# ---------------------------------------------------------------------------
# event queue / clock
# ---------------------------------------------------------------------------

def test_event_queue_orders_by_time_then_push_order():
    q = EventQueue()
    q.push(2.0, "arrival", client_id=1)
    q.push(1.0, "arrival", client_id=2)
    q.push(1.0, "dropout", client_id=3)   # same instant: push order wins
    popped = [q.pop() for _ in range(3)]
    assert [e.client_id for e in popped] == [2, 3, 1]
    assert [e.kind for e in popped] == ["arrival", "dropout", "arrival"]


def test_merged_event_queue_deterministic_tie_order():
    """The multi-trial queue's total order is (time, trial_ord, per-trial
    push seq): cross-trial ties at one instant break by the trial's stable
    ordinal, within-trial ties by push order — the same order the trial's
    standalone EventQueue would pop, so merged re-runs replay each trial's
    events identically."""
    from repro.runtime.events import MergedEventQueue, TrialQueueView
    q = MergedEventQueue()
    q.push(1, 2.0, "arrival", client_id=10)
    q.push(0, 2.0, "arrival", client_id=11)
    q.push(1, 2.0, "dropout", client_id=12)   # trial 1, pushed later
    q.push(0, 1.0, "arrival", client_id=13)
    popped = [q.pop() for _ in range(4)]
    assert [(e.time, e.trial_ord, e.client_id) for e in popped] == [
        (1.0, 0, 13), (2.0, 0, 11), (2.0, 1, 10), (2.0, 1, 12)]

    # requeue restores the exact original key (deferred events of a packed
    # trial must not change their place in the order)
    q.requeue(popped[1])
    q.requeue(popped[2])
    assert q.pop() is popped[1] and q.pop() is popped[2]

    # the per-trial facade answers per-trial emptiness, not global
    view0, view1 = TrialQueueView(q, 0), TrialQueueView(q, 1)
    assert not view0 and not view1
    view1.push(3.0, "arrival", client_id=7)
    assert not view0 and view1 and len(view1) == 1
    assert q.pop().client_id == 7


def test_virtual_clock_is_monotonic():
    c = VirtualClock()
    c.advance_to(3.0)
    c.advance_to(3.0)
    assert c.now == 3.0
    with pytest.raises(AssertionError):
        c.advance_to(1.0)


def test_fleet_sampling_deterministic_and_homogeneous_is_unit():
    a = sample_fleet("stragglers", 50, seed=7)
    b = sample_fleet("stragglers", 50, seed=7)
    np.testing.assert_array_equal(a.speed, b.speed)
    assert len(set(np.round(a.speed, 6))) > 1   # actually heterogeneous
    h = homogeneous_fleet(10)
    assert h.is_homogeneous()
    # unit fleet: virtual time IS the cost-model overhead
    assert h.comp_time(0, 123.0) == 123.0
    assert h.trans_time(0, 10.0, 5.0) == 15.0


# ---------------------------------------------------------------------------
# sync mode == legacy loop on a homogeneous profile
# ---------------------------------------------------------------------------

def test_sync_homogeneous_matches_legacy():
    legacy = mk_server().run_legacy()
    sync = mk_server().run()   # default: sync runtime over unit fleet
    acc_l = [h.accuracy for h in legacy.history]
    acc_s = [h.accuracy for h in sync.history]
    np.testing.assert_allclose(acc_l, acc_s, rtol=1e-6)
    np.testing.assert_allclose(np.array(legacy.total_cost.as_tuple()),
                               np.array(sync.total_cost.as_tuple()),
                               rtol=1e-9)
    assert sync.params is not None and legacy.params is not None
    for a, b in zip(jax.tree.leaves(legacy.params),
                    jax.tree.leaves(sync.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    # every client arrived, virtual clock advanced
    assert all(h.n_updates == min(5, 24) for h in sync.history)
    assert sync.sim_time > 0


def test_sync_runtime_determinism():
    rt = RuntimeConfig(mode="sync", deadline_quantile=0.6)
    fleet = sample_fleet("stragglers", 24, seed=3)
    a = mk_server(rt=rt, fleet=fleet).run()
    b = mk_server(rt=rt, fleet=fleet).run()
    assert [h.sim_time for h in a.history] == [h.sim_time for h in b.history]
    assert [h.accuracy for h in a.history] == [h.accuracy for h in b.history]
    assert [h.n_updates for h in a.history] == [h.n_updates for h in b.history]


def test_sync_straggler_cutoff_cuts_and_is_faster():
    fleet = sample_fleet("stragglers", 24, seed=3)
    full = mk_server(fleet=fleet,
                     rt=RuntimeConfig(mode="sync")).run()
    cut = mk_server(fleet=fleet,
                    rt=RuntimeConfig(mode="sync",
                                     deadline_quantile=0.5)).run()
    assert min(h.n_updates for h in cut.history) >= 1
    # the cutoff must actually exclude stragglers in at least one round...
    assert sum(h.n_updates for h in cut.history) < sum(
        h.n_updates for h in full.history)
    # ...and spend less virtual wall-clock (CompT critical path shrinks)
    assert cut.sim_time < full.sim_time
    assert cut.total_cost.comp_t < full.total_cost.comp_t


# ---------------------------------------------------------------------------
# async / buffered
# ---------------------------------------------------------------------------

def test_async_runtime_deterministic_and_progresses():
    rt = RuntimeConfig(mode="async")
    fleet = sample_fleet("stragglers", 24, seed=3)
    a = mk_server(rt=rt, fleet=fleet, max_rounds=8).run()
    b = mk_server(rt=rt, fleet=fleet, max_rounds=8).run()
    assert a.rounds == 8
    assert [h.sim_time for h in a.history] == [h.sim_time for h in b.history]
    assert [h.accuracy for h in a.history] == [h.accuracy for h in b.history]
    # virtual time is strictly increasing over aggregations
    times = [h.sim_time for h in a.history]
    assert all(t2 >= t1 for t1, t2 in zip(times, times[1:]))
    assert a.total_cost.comp_l > 0 and a.total_cost.comp_t > 0


def test_buffered_runtime_flushes_every_k():
    k = 3
    rt = RuntimeConfig(mode="buffered", buffer_k=k)
    res = mk_server(rt=rt, fleet=sample_fleet("mild", 24, seed=3),
                    max_rounds=5).run()
    assert res.rounds >= 1
    assert all(h.n_updates == k for h in res.history)


# ---------------------------------------------------------------------------
# staleness weighting
# ---------------------------------------------------------------------------

def test_staleness_weight_properties():
    assert staleness_weight(0) == 1.0
    ws = [staleness_weight(s, alpha=0.5) for s in range(6)]
    assert all(w2 < w1 for w1, w2 in zip(ws, ws[1:]))   # monotone decay
    assert staleness_weight(3, kind="constant") == 1.0
    assert staleness_weight(1, alpha=0.5, kind="hinge") == 1.0   # b = 2
    assert staleness_weight(5, alpha=0.5, kind="hinge") < 1.0
    assert staleness_weight(8, alpha=0.5) == pytest.approx(1.0 / 3.0)


def test_fedbuff_flush_is_staleness_discounted_average():
    base = {"w": jnp.zeros((4,), jnp.float32)}
    d1 = {"w": jnp.ones((4,), jnp.float32)}
    d2 = {"w": 3.0 * jnp.ones((4,), jnp.float32)}
    buf = FedBuffAggregator(buffer_k=2, staleness_alpha=0.5)
    buf.add(d1, staleness=0)     # weight 1
    buf.add(d2, staleness=3)     # weight 0.5
    assert buf.full
    out = buf.flush(base)
    w1, w2 = 1.0, (1 + 3) ** -0.5
    expect = (w1 * 1.0 + w2 * 3.0) / 2          # divide by K, not sum(w)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.full(4, expect), rtol=1e-6)
    assert len(buf) == 0         # buffer cleared
    # the discount is absolute: a uniformly stale buffer steps SMALLER
    # than a uniformly fresh one (normalizing by sum(w) would cancel it)
    fresh, stale = (FedBuffAggregator(buffer_k=2, staleness_alpha=0.5)
                    for _ in range(2))
    for b, s in ((fresh, 0), (stale, 8)):
        b.add(d1, staleness=s)
        b.add(d1, staleness=s)
    assert float(stale.flush(base)["w"][0]) < float(fresh.flush(base)["w"][0])


def test_apply_async_update_mixes_toward_client():
    g = {"w": jnp.zeros((3,), jnp.float32)}
    c = {"w": jnp.ones((3,), jnp.float32)}
    fresh = apply_async_update(g, c, mix=0.6, staleness=0)
    np.testing.assert_allclose(np.asarray(fresh["w"]), np.full(3, 0.6),
                               rtol=1e-6)
    stale = apply_async_update(g, c, mix=0.6, staleness=8, alpha=0.5)
    np.testing.assert_allclose(np.asarray(stale["w"]),
                               np.full(3, 0.6 / 3.0), rtol=1e-6)


# ---------------------------------------------------------------------------
# timed cost accounting
# ---------------------------------------------------------------------------

def test_add_timed_round_accumulates():
    cm = CostModel(flops_per_example=1e6, param_count=1e5)
    r = cm.add_timed_round(comp_time=10.0, trans_time=2.0,
                           comp_load=100.0, trans_load=20.0)
    assert (r.comp_t, r.trans_t, r.comp_l, r.trans_l) == (10.0, 2.0,
                                                          100.0, 20.0)
    cm.add_timed_round(comp_time=5.0, trans_time=1.0,
                       comp_load=50.0, trans_load=10.0)
    assert cm.total.comp_t == 15.0 and cm.total.comp_l == 150.0
    assert cm.rounds == 2


# ---------------------------------------------------------------------------
# batched client execution
# ---------------------------------------------------------------------------

def test_batched_matches_sequential_local_training():
    srv = mk_server()
    params = srv.model.init(jax.random.PRNGKey(0))
    cids = [0, 3, 7, 11, 15]
    data = [srv.dataset.client_data(c) for c in cids]
    rng_seq = np.random.default_rng(42)
    rng_bat = np.random.default_rng(42)
    seq = [local_train(srv.model, params, x, y, passes=2.0, batch_size=4,
                       optimizer=srv.optimizer, rng=rng_seq)
           for x, y in data]
    bat = batched_local_train(srv.model, params, data, passes=2.0,
                              batch_size=4, optimizer=srv.optimizer,
                              rng=rng_bat, client_ids=cids)
    for s, b, cid in zip(seq, bat, cids):
        assert b.client_id == cid
        assert s.n_steps == b.n_steps
        assert s.last_loss == pytest.approx(b.last_loss, rel=1e-5)
        for ls, lb in zip(jax.tree.leaves(s.params),
                          jax.tree.leaves(b.params)):
            np.testing.assert_allclose(np.asarray(ls), np.asarray(lb),
                                       atol=1e-5)


def test_batched_sync_runtime_matches_sequential_sync():
    seq = mk_server(rt=RuntimeConfig(mode="sync", batched=False)).run()
    bat = mk_server(rt=RuntimeConfig(mode="sync", batched=True)).run()
    np.testing.assert_allclose([h.accuracy for h in seq.history],
                               [h.accuracy for h in bat.history], atol=1e-5)
    np.testing.assert_allclose(np.array(seq.total_cost.as_tuple()),
                               np.array(bat.total_cost.as_tuple()),
                               rtol=1e-9)


def test_batched_compressed_matches_sequential_and_stays_batched():
    """Upload compression is a lane transform inside the batched cohort:
    the batched backend no longer falls back to the sequential client
    loop, and its rounds match the sequential path's compressed rounds."""
    from repro.runtime.engine import EventDrivenRuntime
    bat_srv = mk_server(rt=RuntimeConfig(mode="sync", client_exec="batched"),
                        compression="int8")
    eng = EventDrivenRuntime(bat_srv, fleet=bat_srv.fleet,
                             config=bat_srv.runtime_config)
    assert eng.client_exec == "batched"
    seq = mk_server(rt=RuntimeConfig(mode="sync"), compression="int8").run()
    bat = bat_srv.run()
    np.testing.assert_allclose([h.accuracy for h in seq.history],
                               [h.accuracy for h in bat.history], atol=1e-5)
    np.testing.assert_allclose(np.array(seq.total_cost.as_tuple()),
                               np.array(bat.total_cost.as_tuple()),
                               rtol=1e-9)


def test_fedprox_batched_parity():
    srv = mk_server()
    params = srv.model.init(jax.random.PRNGKey(0))
    data = [srv.dataset.client_data(c) for c in (2, 5)]
    rng_a, rng_b = (np.random.default_rng(9) for _ in range(2))
    seq = [local_train(srv.model, params, x, y, passes=1.0, batch_size=4,
                       optimizer=srv.optimizer, rng=rng_a, prox_mu=0.1)
           for x, y in data]
    bat = batched_local_train(srv.model, params, data, passes=1.0,
                              batch_size=4, optimizer=srv.optimizer,
                              rng=rng_b, prox_mu=0.1)
    for s, b in zip(seq, bat):
        for ls, lb in zip(jax.tree.leaves(s.params),
                          jax.tree.leaves(b.params)):
            np.testing.assert_allclose(np.asarray(ls), np.asarray(lb),
                                       atol=1e-5)


# ---------------------------------------------------------------------------
# deadline-aware selection
# ---------------------------------------------------------------------------

def test_deadline_selector_prefers_fast_clients():
    fleet = sample_fleet("stragglers", 24, seed=3)
    srv = mk_server(fleet=fleet, selection="deadline", m=6)
    est = srv.selector.est_times   # download + compute + upload per client
    assert len(est) == 24 and np.all(est > 0)
    cohort = srv.selector.select(6)
    assert len(set(int(c) for c in cohort)) == 6
    # the exploit portion must rank among the fastest clients
    fast_set = set(np.argsort(est)[:8].tolist())
    exploit = [int(c) for c in cohort[:5]]   # epsilon=0.1 -> 5 exploit of 6
    assert set(exploit) <= fast_set


def test_async_deadline_selection_uses_multiple_clients():
    # regression: deterministic rankers must not collapse async concurrency
    # to a single repeatedly-dispatched client
    rt = RuntimeConfig(mode="async")
    fleet = sample_fleet("stragglers", 24, seed=3)
    srv = mk_server(rt=rt, fleet=fleet, max_rounds=8, selection="deadline")
    seen = []
    orig = srv._client_update

    def spy(params, cid, e):
        seen.append(cid)
        return orig(params, cid, e)

    srv._client_update = spy
    srv.run()
    assert len(set(seen)) > 1, f"only client(s) {set(seen)} ever trained"


# ---------------------------------------------------------------------------
# VirtualFleet: derived (hash-drawn) client state, memory independent of K
# ---------------------------------------------------------------------------

def test_virtual_fleet_draws_independent_of_population_size():
    """Client cid's device parameters depend only on (seed, cid) — never on
    how many other clients exist — so a cohort drawn from a K=10^6 fleet
    equals the same cids drawn from a K=10^3 fleet, bit for bit.  (This is
    the property a (K,)-array rng sample CANNOT have, and why VirtualFleet
    scales to million-client populations with cohort-sized memory.)"""
    from repro.runtime import virtual_fleet
    small = virtual_fleet("mobile", 1_000, seed=5)
    huge = virtual_fleet("mobile", 1_000_000, seed=5)
    cids = np.array([0, 1, 17, 999])
    np.testing.assert_array_equal(small.speeds(cids), huge.speeds(cids))
    np.testing.assert_array_equal(small.bws(cids), huge.bws(cids))


def test_virtual_fleet_scalar_index_matches_bulk():
    """The (K,)-array-shaped lazy views (``fleet.speed[cid]``…) answer the
    exact bulk draw, so engine code indexing one cid at a time agrees with
    the vectorized cost path."""
    from repro.runtime import virtual_fleet
    vf = virtual_fleet("stragglers", 10_000, seed=2)
    for cid in (0, 77, 9_999):
        assert vf.speed[cid] == vf.speeds(np.array([cid]))[0]
        assert vf.up_bw[cid] == vf.bws(np.array([cid]))[0]
        assert vf.down_bw[cid] == vf.bws(np.array([cid]))[0]
    assert len(vf.speed) == 10_000
    assert vf.availability[3] == vf.profile.availability
    assert vf.dropout[3] == vf.profile.dropout


def test_virtual_fleet_materialize_roundtrip():
    """materialize() builds the array-backed Fleet with the same per-cid
    draws, and both fleets answer fails()/time queries identically."""
    from repro.runtime import virtual_fleet
    vf = virtual_fleet("mobile", 200, seed=9)
    fl = vf.materialize()
    cids = np.arange(200)
    np.testing.assert_array_equal(fl.speed, vf.speeds(cids))
    np.testing.assert_array_equal(fl.up_bw, vf.bws(cids))
    np.testing.assert_array_equal(fl.availability, vf.availability[cids])
    assert vf.has_failures() == fl.has_failures()
    for cid in (0, 13, 199):
        for t in (0.0, 1.5, 333.25):
            assert vf.fails(cid, t) == fl.fails(cid, t)
            assert vf.comp_time(cid, 1000.0) == fl.comp_time(cid, 1000.0)
            assert vf.trans_time(cid, 10.0, 5.0) == fl.trans_time(
                cid, 10.0, 5.0)


@pytest.mark.parametrize("make", ["sampled", "virtual"])
def test_est_round_times_bulk_matches_scalar(make):
    """The vectorized est_round_times (what FLServer.__init__ consumes) is
    elementwise bit-identical to the scalar est_round_time loop it
    replaced — for both fleet flavors."""
    from repro.runtime import virtual_fleet
    if make == "sampled":
        fleet = sample_fleet("stragglers", 50, seed=7)
    else:
        fleet = virtual_fleet("stragglers", 50, seed=7)
    cids = np.arange(50)
    sizes = np.linspace(5, 200, 50)
    bulk = fleet.est_round_times(cids, sizes, 2.0, 100.0, 10.0, 5.0)
    for i, cid in enumerate(cids):
        assert bulk[i] == fleet.est_round_time(int(cid), float(sizes[i]),
                                               2.0, 100.0, 10.0, 5.0)


def test_virtual_fleet_engine_parity_with_materialized():
    """A full sync-runtime FL run over a VirtualFleet == the same run over
    its materialized Fleet: same accuracies, costs, and virtual clock."""
    from repro.runtime import virtual_fleet
    vf = virtual_fleet("stragglers", 24, seed=3)
    rt = RuntimeConfig(mode="sync", deadline_quantile=0.8)
    a = mk_server(rt=rt, fleet=vf, selection="deadline").run()
    b = mk_server(rt=rt, fleet=vf.materialize(), selection="deadline").run()
    assert [h.accuracy for h in a.history] == [h.accuracy for h in b.history]
    assert [h.sim_time for h in a.history] == [h.sim_time for h in b.history]
    assert [h.n_updates for h in a.history] == [h.n_updates
                                                for h in b.history]
    np.testing.assert_array_equal(np.array(a.total_cost.as_tuple()),
                                  np.array(b.total_cost.as_tuple()))
