import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: ``.lower().compile()`` every (architecture x input
shape x mesh) combination on the production meshes, and record memory /
cost / roofline data.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod|multipod|both]

Results are appended as JSON files under experiments/dryrun/.
"""

import argparse      # noqa: E402
import json          # noqa: E402
import pathlib       # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCH_NAMES, get_config  # noqa: E402
from repro.configs.shapes import SHAPES, get_shape  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import step_for_shape  # noqa: E402
from repro.roofline.analysis import analyze_compiled  # noqa: E402

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# Per-arch production train-step knobs: local_passes = E-style gradient
# accumulation microbatches (paper's E maps here); chosen so the per-device
# activation live-set fits v5e HBM (16 GB).  Recorded in EXPERIMENTS.md.
TRAIN_KWARGS = {
    "dbrx-132b": {"microbatches": 8},
    "command-r-35b": {"microbatches": 4},
    "minitron-8b": {"microbatches": 2},
    "qwen2-7b": {"microbatches": 2},
    "recurrentgemma-9b": {"microbatches": 2},
}

# The multi-pod mesh halves the per-device batch but pays extra cross-pod
# buffers; these combos need one more 2x microbatch split to stay <16 GB.
TRAIN_KWARGS_MULTIPOD = {
    "dbrx-132b": {"microbatches": 8},   # mb_size must stay divisible by 32 slices
    "command-r-35b": {"microbatches": 4},
    "minitron-8b": {"microbatches": 4},
    "qwen2-7b": {"microbatches": 4},
    "recurrentgemma-9b": {"microbatches": 4},
    "gemma2-2b": {"microbatches": 2},
}


def model_flops_estimate(cfg, shape) -> float:
    """6*N*D for train (fwd+bwd), 2*N*D for prefill, 2*N per token decode;
    N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


class DryRunError(RuntimeError):
    """One (arch, shape, mesh) combo failed to lower or compile.

    A failure here is a bug in our sharding or configs — never an
    expected condition — so ``run_one`` records and saves the failing
    record for the report tooling, then re-raises with the combo
    context chained to the original exception instead of swallowing
    it.  ``main``'s sweep catches exactly this type per combo so one
    broken arch doesn't hide failures in the rest."""


def run_one(arch: str, shape_name: str, multi_pod: bool, *,
            verbose: bool = True, save: bool = True,
            step_kwargs=None) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_dev = 512 if multi_pod else 256
    t0 = time.perf_counter()
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "status": "ok"}
    if step_kwargs is None and shape.kind == "train":
        step_kwargs = (TRAIN_KWARGS_MULTIPOD if multi_pod
                       else TRAIN_KWARGS).get(arch, {})
    try:
        jit_fn, structs = step_for_shape(cfg, mesh, shape,
                                         multi_pod=multi_pod,
                                         **(step_kwargs or {}))
        with mesh:
            lowered = jit_fn.lower(*structs)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        rep = analyze_compiled(
            compiled, arch=arch, shape=shape_name, mesh=mesh_name,
            n_devices=n_dev, model_flops=model_flops_estimate(cfg, shape))
        record.update(json.loads(rep.to_json()))
        record["t_lower_s"] = round(t_lower, 2)
        record["t_compile_s"] = round(t_compile, 2)
        try:
            record["memory_analysis"] = {
                "argument_size": mem.argument_size_in_bytes,
                "output_size": mem.output_size_in_bytes,
                "temp_size": mem.temp_size_in_bytes,
                "alias_size": mem.alias_size_in_bytes,
                "generated_code_size": mem.generated_code_size_in_bytes,
            }
        except (AttributeError, TypeError):
            # older jaxlibs expose a partial MemoryAnalysis surface
            record["memory_analysis"] = str(mem)
        if verbose:
            print(f"[OK ] {rep.row()}  (lower {t_lower:.1f}s "
                  f"compile {t_compile:.1f}s)", flush=True)
            print(f"      memory: args={mem.argument_size_in_bytes/2**30:.2f}"
                  f"GiB temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
                  f"out={mem.output_size_in_bytes/2**30:.2f}GiB", flush=True)
    except Exception as e:  # a failure here is a bug in our sharding
        record["status"] = "fail"
        record["error"] = f"{type(e).__name__}: {e}"
        if verbose:
            print(f"[FAIL] {arch} {shape_name} {mesh_name}: "
                  f"{record['error'][:500]}", flush=True)
            traceback.print_exc()
        _save_record(record, arch, shape_name, mesh_name, save)
        raise DryRunError(
            f"{arch} {shape_name} {mesh_name} failed to lower/compile: "
            f"{record['error'][:300]}") from e
    _save_record(record, arch, shape_name, mesh_name, save)
    return record


def _save_record(record: dict, arch: str, shape_name: str, mesh_name: str,
                 save: bool):
    if not save:
        return
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    fname = OUT_DIR / f"{arch}__{shape_name}__{mesh_name}.json"
    fname.write_text(json.dumps(record, indent=1, default=float))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="pod")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    archs = ARCH_NAMES if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = {"pod": (False,), "multipod": (True,),
              "both": (False, True)}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                try:
                    run_one(arch, shape_name, mp)
                except DryRunError:
                    # recorded, saved and printed by run_one; keep
                    # sweeping so one broken arch doesn't mask the rest
                    n_fail += 1
    print(f"\ndry-run complete; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
