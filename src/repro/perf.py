"""Lightweight wall-clock phase counters.

The sweep engines interleave three kinds of work per round/macro-step:
client training (the packed cohort dispatches), evaluation (the stacked
accuracy dispatches), and host-side orchestration (planning, rng streams,
aggregation bookkeeping).  ``benchmarks/sweep_engine.py`` splits its BENCH
timings into ``train_s`` / ``eval_s`` / ``other_s`` through these counters
so a perf win in one phase (e.g. eval amortization) is visible instead of
being averaged away in the total.

Counters accumulate host wall-clock around the timed block.  JAX dispatch
is asynchronous, so a phase's device time is attributed to the phase that
eventually blocks on its results — both training and evaluation blocks end
in host conversions (``np.asarray`` / ``float``), which keeps the split
honest at benchmark granularity.  Not thread-safe; the sweep engines are
single-threaded.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict

_seconds: Dict[str, float] = {}
_calls: Dict[str, int] = {}


def add(name: str, seconds: float):
    _seconds[name] = _seconds.get(name, 0.0) + seconds
    _calls[name] = _calls.get(name, 0) + 1


@contextmanager
def timed(name: str):
    """Accumulate the block's wall-clock under ``name``."""
    t0 = time.perf_counter()
    try:
        yield
    finally:
        add(name, time.perf_counter() - t0)


def seconds(name: str) -> float:
    return _seconds.get(name, 0.0)


def calls(name: str) -> int:
    return _calls.get(name, 0)


def snapshot() -> Dict[str, float]:
    return dict(_seconds)


def reset():
    _seconds.clear()
    _calls.clear()
