"""Server-side aggregation algorithms.

All aggregators consume per-participant results
  ClientUpdate(params, n_examples, n_steps)
and produce the new global params.  The weighted sums run through the
fused ``fed_reduce`` kernel path (Pallas on TPU, jnp reference elsewhere)
on flattened parameter vectors, as a single-segment (T=1) call — which is
exactly what makes the multi-trial sweep engines' ONE-dispatch packed
reduce bit-identical per lane to this standalone path (the fold over a
lane's rows is invariant to what else is packed; see kernels/ref.py).

FedAvg passes RAW example counts with ``normalize=True`` so the weight
normalization happens inside the kernel with the same op sequence the
fused multi-trial reduce uses; host-side pre-normalization would differ
by an ulp and break the vectorized-vs-standalone parity pins.

Implemented: FedAvg [McMahan'17], FedNova [Wang'20], and the adaptive
server optimizers FedAdagrad / FedAdam / FedYogi [Reddi'21].  FedProx is a
*client-side* proximal term (see federated/client.py) aggregated by FedAvg.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kernel_ops


class ClientUpdate(NamedTuple):
    params: Any        # client's local params after E passes
    n_examples: int
    n_steps: int       # local optimizer steps actually taken (tau_k)
    last_loss: float = 0.0  # final local loss (guided selection signal)
    client_id: int = -1     # which client produced it (runtime bookkeeping)


def _flatten(params):
    leaves, treedef = jax.tree.flatten(params)
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1) for l in leaves])
    return flat, (treedef, shapes, sizes)


def _unflatten(flat, meta):
    treedef, shapes, sizes = meta
    out = []
    off = 0
    for shape, size in zip(shapes, sizes):
        out.append(flat[off:off + size].reshape(shape))
        off += size
    return jax.tree.unflatten(treedef, out)


def _weighted_combine(weights: np.ndarray, param_list: List[Any],
                      base: Optional[Any] = None, *,
                      normalize: bool = False):
    """sum_k w_k * params_k (+ base), one fused fed_reduce call (T=1)."""
    flats = []
    meta = None
    for p in param_list:
        f, meta = _flatten(p)
        flats.append(f)
    rows = jnp.stack(flats)                       # (M, N)
    w = jnp.asarray(weights, jnp.float32)
    seg = jnp.zeros(rows.shape[0], jnp.int32)
    base_flat = _flatten(base)[0][None, :] if base is not None else None
    out = kernel_ops.fed_reduce(w, rows, seg, 1, base_flat,
                                normalize=normalize)
    return _unflatten(out[0], meta)


# ---------------------------------------------------------------------------
# aggregators
# ---------------------------------------------------------------------------

class Aggregator:
    name = "base"

    def __call__(self, global_params, updates: List[ClientUpdate]):
        raise NotImplementedError


class FedAvg(Aggregator):
    name = "fedavg"

    def __call__(self, global_params, updates):
        # raw counts; the n_k / sum(n) division runs inside fed_reduce so
        # the fused multi-trial engines normalize with the same op sequence
        w = np.array([u.n_examples for u in updates], np.float32)
        return _weighted_combine(w, [u.params for u in updates],
                                 normalize=True)


class FedNova(Aggregator):
    """Normalized averaging: re-weights client *deltas* by their local step
    counts tau_k so heterogeneous E does not bias the update direction."""
    name = "fednova"

    def __call__(self, global_params, updates):
        n = float(sum(u.n_examples for u in updates))
        p = np.array([u.n_examples / n for u in updates], np.float32)
        tau = np.array([max(u.n_steps, 1) for u in updates], np.float32)
        tau_eff = float((p * tau).sum())
        # delta_k = (theta_k - theta) / tau_k ; theta' = theta + tau_eff * sum p_k d_k
        deltas = [
            jax.tree.map(lambda a, b: (a - b), u.params, global_params)  # noqa: REPRO001 -- aggregators run on the shared host path of every engine; jitting would change FMA contraction vs the pinned parity
            for u in updates
        ]
        w = (p / tau) * tau_eff
        return _weighted_combine(w.astype(np.float32), deltas,
                                 base=global_params)


@dataclass
class _AdaptiveServer(Aggregator):
    """Reddi et al. adaptive server optimizers over the pseudo-gradient
    Delta = sum_k p_k (theta_k - theta)."""
    lr: float = 0.1
    b1: float = 0.0
    tau: float = 1e-3
    name = "adaptive"

    def __post_init__(self):
        self._m = None
        self._v = None

    def _second_moment(self, v, d2):
        raise NotImplementedError

    def __call__(self, global_params, updates):
        n = float(sum(u.n_examples for u in updates))
        w = np.array([u.n_examples / n for u in updates], np.float32)
        deltas = [jax.tree.map(lambda a, b: a - b, u.params, global_params)  # noqa: REPRO001 -- aggregators run on the shared host path of every engine; jitting would change FMA contraction vs the pinned parity
                  for u in updates]
        delta = _weighted_combine(w, deltas)
        if self._m is None:
            self._m = jax.tree.map(jnp.zeros_like, delta)
            self._v = jax.tree.map(
                lambda x: jnp.full_like(x, self.tau ** 2), delta)  # noqa: REPRO001 -- scalar tau**2 fill at state init; identical on every engine
        self._m = jax.tree.map(lambda m, d: self.b1 * m + (1 - self.b1) * d,  # noqa: REPRO001 -- server-optimizer state update on the shared host path; parity-pinned as-is
                               self._m, delta)
        self._v = jax.tree.map(self._second_moment, self._v,
                               jax.tree.map(lambda d: d * d, delta))  # noqa: REPRO001 -- server-optimizer state update on the shared host path; parity-pinned as-is
        return jax.tree.map(
            lambda t, m, v: t + self.lr * m / (jnp.sqrt(v) + self.tau),  # noqa: REPRO001 -- adaptive-server step on the shared host path of every engine; parity-pinned as-is
            global_params, self._m, self._v)


class FedAdagrad(_AdaptiveServer):
    name = "fedadagrad"

    def _second_moment(self, v, d2):
        return v + d2


class FedAdam(_AdaptiveServer):
    name = "fedadam"
    b2: float = 0.99

    def _second_moment(self, v, d2):
        return 0.99 * v + 0.01 * d2


class FedYogi(_AdaptiveServer):
    name = "fedyogi"

    def _second_moment(self, v, d2):
        return v - 0.01 * jnp.sign(v - d2) * d2


# ---------------------------------------------------------------------------
# staleness-aware aggregation (async / buffered runtimes)
# ---------------------------------------------------------------------------

def staleness_weight(staleness: float, alpha: float = 0.5,
                     kind: str = "polynomial") -> float:
    """Down-weighting of stale updates s(tau) in [0, 1].

    polynomial — FedAsync's s(tau) = (1 + tau)^-alpha (default).
    constant   — no discounting.
    hinge      — full weight up to ``b = 1/alpha`` versions, then harmonic
                 decay 1 / (1 + alpha * (tau - b)).
    """
    s = max(float(staleness), 0.0)
    if kind == "constant":
        return 1.0
    if kind == "polynomial":
        return float((1.0 + s) ** (-alpha))
    if kind == "hinge":
        b = 1.0 / max(alpha, 1e-9)
        return 1.0 if s <= b else float(1.0 / (1.0 + alpha * (s - b)))
    raise KeyError(f"unknown staleness kind {kind!r}")


class FedBuffAggregator:
    """FedBuff [Nguyen'22]: the server buffers K client *deltas* (each taken
    against the params the client was dispatched with) and applies their
    staleness-discounted average ``(server_lr / K) * sum_i s(tau_i) d_i``
    in one shot through the ``fed_reduce`` kernel.  The discount is
    ABSOLUTE (divide by K, not by the weight sum): a buffer of uniformly
    stale updates takes a proportionally smaller step, as in the cited
    FedAsync/FedBuff scaling.  Unlike the synchronous ``Aggregator``s this
    object is fed deltas incrementally by the event-driven runtime."""

    name = "fedbuff"

    def __init__(self, buffer_k: int = 8, server_lr: float = 1.0,
                 staleness_alpha: float = 0.5,
                 staleness_kind: str = "polynomial"):
        self.buffer_k = buffer_k
        self.server_lr = server_lr
        self.staleness_alpha = staleness_alpha
        self.staleness_kind = staleness_kind
        self._deltas: List[Any] = []
        self._weights: List[float] = []

    def __len__(self) -> int:
        return len(self._deltas)

    @property
    def full(self) -> bool:
        return len(self._deltas) >= self.buffer_k

    def add(self, delta, staleness: int = 0):
        self._deltas.append(delta)
        self._weights.append(staleness_weight(
            staleness, self.staleness_alpha, self.staleness_kind))

    def flush(self, global_params):
        """Apply the buffered deltas; returns new params and clears."""
        assert self._deltas, "flush() on an empty buffer"
        w = np.asarray(self._weights, np.float32)
        w = (w / len(w)) * self.server_lr
        out = _weighted_combine(w, self._deltas, base=global_params)
        self._deltas, self._weights = [], []
        return out


@jax.jit
def _async_mix(a, global_params, client_params):
    scaled_base = jax.tree.map(lambda p: p * (1.0 - a), global_params)
    flat_c, meta = _flatten(client_params)
    flat_b, _ = _flatten(scaled_base)
    w = jnp.reshape(a, (1,)).astype(jnp.float32)
    return _unflatten(kernel_ops.fed_aggregate(w, flat_c[None, :], flat_b),
                      meta)


def apply_async_update(global_params, client_params, *, mix: float,
                       staleness: int, alpha: float = 0.5,
                       kind: str = "polynomial"):
    """FedAsync [Xie'19] model mixing: theta <- (1-a) theta + a theta_k with
    a = mix * s(staleness).  Runs through the fed_aggregate kernel inside a
    single jitted call (cached per parameter tree structure/shape by jit,
    with ``a`` traced) — async runtimes call this on EVERY arrival, so the
    eager flatten/scale/combine chain it replaces (~15 dispatches) was a
    per-arrival hot spot for both the standalone event loop and the
    vectorized event sweep."""
    a = float(np.clip(mix * staleness_weight(staleness, alpha, kind),
                      0.0, 1.0))
    return _async_mix(a, global_params, client_params)


AGGREGATORS = {
    "fedavg": FedAvg,
    "fedprox": FedAvg,     # proximal term lives client-side
    "fednova": FedNova,
    "fedadagrad": FedAdagrad,
    "fedadam": FedAdam,
    "fedyogi": FedYogi,
}


def get_aggregator(name: str, **kw) -> Aggregator:
    try:
        cls = AGGREGATORS[name]
    except KeyError:
        valid = ", ".join(sorted(AGGREGATORS))
        raise ValueError(f"unknown aggregator {name!r}; valid aggregators: "
                         f"{valid}") from None
    return cls(**kw)
