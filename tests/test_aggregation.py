"""Aggregation algorithms: FedAvg/FedNova/adaptive server optimizers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.federated.aggregation import (ClientUpdate, FedAdagrad, FedAvg,
                                         FedNova, get_aggregator)

KEY = jax.random.PRNGKey(0)


def params_like(scale):
    return {"w": jnp.full((8, 4), scale), "b": jnp.full((4,), scale / 2)}


def test_fedavg_weighted_mean():
    updates = [
        ClientUpdate(params_like(1.0), n_examples=10, n_steps=2),
        ClientUpdate(params_like(3.0), n_examples=30, n_steps=2),
    ]
    out = FedAvg()(params_like(0.0), updates)
    # weighted mean: (10*1 + 30*3)/40 = 2.5
    np.testing.assert_allclose(np.asarray(out["w"]), 2.5, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out["b"]), 1.25, rtol=1e-6)


def test_fednova_equals_fedavg_when_steps_equal():
    g = params_like(0.0)
    updates = [
        ClientUpdate(params_like(1.0), n_examples=10, n_steps=5),
        ClientUpdate(params_like(3.0), n_examples=30, n_steps=5),
    ]
    avg = FedAvg()(g, updates)
    nova = FedNova()(g, updates)
    np.testing.assert_allclose(np.asarray(avg["w"]), np.asarray(nova["w"]),
                               rtol=1e-5)


def test_fednova_normalizes_heterogeneous_steps():
    g = params_like(0.0)
    # same delta magnitude but one client took 10x the steps: FedNova must
    # down-weight its per-step contribution
    updates = [
        ClientUpdate(params_like(2.0), n_examples=10, n_steps=1),
        ClientUpdate(params_like(2.0), n_examples=10, n_steps=10),
    ]
    nova = FedNova()(g, updates)
    avg = FedAvg()(g, updates)
    assert float(nova["w"].mean()) != pytest.approx(float(avg["w"].mean()))


def test_adaptive_aggregators_move_toward_clients():
    for name in ("fedadagrad", "fedadam", "fedyogi"):
        agg = get_aggregator(name, lr=0.1)
        g = params_like(0.0)
        updates = [ClientUpdate(params_like(1.0), 10, 1)]
        out = agg(g, updates)
        assert float(out["w"].mean()) > 0, name
        out2 = agg(out, [ClientUpdate(params_like(1.0), 10, 1)])
        assert float(out2["w"].mean()) > float(out["w"].mean()), name


def test_aggregation_via_kernel_matches_tree_math():
    """The flattened fed_aggregate path must equal per-leaf arithmetic."""
    ks = jax.random.split(KEY, 4)
    mk = lambda k: {"a": jax.random.normal(k, (16,)),
                    "b": jax.random.normal(k, (3, 5))}
    updates = [ClientUpdate(mk(ks[0]), 5, 1), ClientUpdate(mk(ks[1]), 15, 1)]
    out = FedAvg()(mk(ks[2]), updates)
    w = np.array([5 / 20, 15 / 20])
    for leaf in ("a", "b"):
        want = w[0] * updates[0].params[leaf] + w[1] * updates[1].params[leaf]
        np.testing.assert_allclose(np.asarray(out[leaf]),
                                   np.asarray(want), rtol=1e-5, atol=1e-6)
