"""Property tests for the system-overhead model (paper eqs. 2-5)."""

import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.costs import CostModel, SystemCost
from repro.core.preferences import Preference


@given(
    sizes=st.lists(st.integers(1, 316), min_size=1, max_size=50),
    e=st.floats(0.5, 20),
    flops=st.floats(1e6, 1e8),
    params=st.floats(1e4, 1e6),
)
@settings(max_examples=50, deadline=None)
def test_round_cost_formulas(sizes, e, flops, params):
    cm = CostModel(flops_per_example=flops, param_count=params)
    r = cm.add_round(sizes, e)
    c1 = flops * cm.backward_multiplier
    assert math.isclose(r.comp_t, c1 * e * max(sizes), rel_tol=1e-9)
    assert math.isclose(r.comp_l, c1 * e * sum(sizes), rel_tol=1e-9)
    assert math.isclose(r.trans_t, params, rel_tol=1e-9)
    assert math.isclose(r.trans_l, params * len(sizes), rel_tol=1e-9)


@given(rounds=st.lists(
    st.tuples(st.lists(st.integers(1, 300), min_size=1, max_size=30),
              st.floats(0.5, 10)),
    min_size=1, max_size=20))
@settings(max_examples=30, deadline=None)
def test_accumulation_is_additive(rounds):
    cm = CostModel(flops_per_example=1e6, param_count=1e5)
    per_round = [cm.add_round(s, e) for s, e in rounds]
    for name in ("comp_t", "trans_t", "comp_l", "trans_l"):
        assert math.isclose(
            getattr(cm.total, name),
            sum(getattr(r, name) for r in per_round), rel_tol=1e-9)
    assert cm.rounds == len(rounds)


def test_comparison_function_eq6():
    """I(S1,S2) < 0 iff S2 strictly dominates on the weighted terms."""
    base = SystemCost(100, 100, 100, 100)
    better = SystemCost(50, 100, 100, 100)
    pref = Preference(1, 0, 0, 0)
    assert better.weighted_relative_to(base, pref) < 0
    worse = SystemCost(150, 1, 1, 1)  # wins on unweighted terms only
    assert worse.weighted_relative_to(base, pref) > 0
    # equal-weight: symmetric trade cancels exactly
    pref2 = Preference(0.5, 0.5, 0.0, 0.0)
    mixed = SystemCost(150, 50, 100, 100)
    assert abs(mixed.weighted_relative_to(base, pref2)) < 1e-12


def test_monotonicity_in_m_and_e():
    """Structural Table-3 signs: with fixed R, CompL/TransL rise with M,
    CompT/CompL rise with E."""
    cm1 = CostModel(1e6, 1e5)
    cm2 = CostModel(1e6, 1e5)
    r_small = cm1.add_round([10] * 5, 1.0)    # M=5
    r_large = cm2.add_round([10] * 20, 1.0)   # M=20
    assert r_large.comp_l > r_small.comp_l
    assert r_large.trans_l > r_small.trans_l
    assert r_large.trans_t == r_small.trans_t  # per-round TransT constant
    cm3 = CostModel(1e6, 1e5)
    r_more_e = cm3.add_round([10] * 5, 4.0)
    assert r_more_e.comp_t > r_small.comp_t
    assert r_more_e.comp_l > r_small.comp_l
    assert r_more_e.trans_l == r_small.trans_l
