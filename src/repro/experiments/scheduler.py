"""Continuous-batching trial scheduler: paged lane allocation over the
sweep engines.

The fixed-set sweep (``run_vectorized``) packs T trials and lets lanes go
idle as trials hit their accuracy targets at different rounds — fine for a
one-shot grid, wrong for the serving shape the paper implies, where tuning
trials arrive as an open-ended *stream* (population-based tuning, adaptive
HPO, many tenants sweeping at once) and the hardware should stay full.
This module turns the sweep engines into a trial-serving daemon, borrowing
the page-table idiom of LLM serving (vLLM-style continuous batching):

  ``LanePool``       — the page table over the stacked trial axis: a fixed
                       capacity of lanes ("pages"), a min-index free list,
                       and a bidirectional lane<->trial-key mapping.  A
                       lane is allocated at admission and released the
                       moment its trial retires — never reused while held,
                       always the lowest free index, so allocation is
                       deterministic given the admission sequence.
  ``TrialQueue``     — the pending work: an in-order FIFO of ``TrialSpec``
                       seeded from a grid and/or fed by a watched JSONL
                       submissions file (one spec dict per line, appended
                       by any writer at any time).  Deduplicates by trial
                       key and skips keys already completed in the result
                       store (resume).
  ``TrialScheduler`` — the serving loop: admit from the queue into free
                       lanes, advance every live sync trial one packed
                       virtual round (``_sync_round_step``) and every live
                       async/buffered trial one merged-queue macro-step
                       (``_EventEngine.macro_step``), retire finished
                       (release the lane, stream the result to the store),
                       repeat.  ``drain()`` runs until queue and pool are
                       both empty.

Bit-parity contract (pinned in tests/test_scheduler.py): every trial
admitted through the scheduler is BIT-identical to an independent
``FLServer.run()`` — admission and retirement change *which* trials pack
together in a cohort, never a trial's own arithmetic, because each trial's
rngs and virtual clock are private and vmap lanes are computed
independently.  A trial admitted mid-flight starts its virtual clock at 0
exactly as a standalone run would; the pool's wall-clock interleaving is
not part of any trial's result.

Observability: ``admit``/``retire`` instant spans (wall clock, per-trial
track), a ``pool_occupancy`` gauge sampled every scheduler step, plus
``queue_depth`` and ``trials_admitted``/``trials_retired`` counters —
``tools/trace_report.py`` renders the drain from these.
"""

from __future__ import annotations

import heapq
import json
import os
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Union

from repro import obs
from repro.experiments.grid import TrialSpec, spec_from_dict
from repro.experiments.runner import (TrialResult, _EventEngine, _make_live,
                                      _resolve_sync_pack, _sync_round_step,
                                      _to_result)


class LanePool:
    """Page table over the stacked trial axis: ``capacity`` lanes, a
    min-index free list, and the lane<->trial-key mapping.

    Allocation invariants (property-tested in tests/test_scheduler.py):
    a lane is held by at most one trial and a trial holds at most one
    lane; ``alloc`` always hands out the LOWEST free index (deterministic
    given the admission/retirement sequence); ``release`` returns the
    lane to the free list immediately.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"LanePool capacity must be >= 1, "
                             f"got {capacity}")
        self.capacity = capacity
        self._free: List[int] = list(range(capacity))   # min-heap
        self._page: Dict[int, str] = {}                 # lane -> trial key
        self._lane: Dict[str, int] = {}                 # trial key -> lane

    @property
    def n_live(self) -> int:
        return len(self._page)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def occupancy(self) -> float:
        return len(self._page) / self.capacity

    def alloc(self, key: str) -> int:
        """Assign the lowest free lane to ``key``; raises when the pool is
        full or the key already holds a lane (double admission is a caller
        bug, not a condition to paper over)."""
        if key in self._lane:
            raise ValueError(f"trial {key!r} already holds lane "
                             f"{self._lane[key]}")
        if not self._free:
            raise ValueError(f"lane pool is full ({self.capacity} lanes); "
                             "check n_free before alloc")
        lane = heapq.heappop(self._free)
        self._page[lane] = key
        self._lane[key] = lane
        return lane

    def release(self, key: str) -> int:
        """Free the lane held by ``key`` (KeyError if it holds none) and
        return its index."""
        lane = self._lane.pop(key)
        del self._page[lane]
        heapq.heappush(self._free, lane)
        return lane

    def lane_of(self, key: str) -> Optional[int]:
        return self._lane.get(key)

    def key_of(self, lane: int) -> Optional[str]:
        return self._page.get(lane)

    def live_mask(self) -> List[bool]:
        """Per-lane occupancy, index == lane — the mask the pack/eval
        shapes are keyed off."""
        return [lane in self._page for lane in range(self.capacity)]

    def live_keys(self) -> List[str]:
        """Held trial keys in lane order (deterministic)."""
        return [self._page[lane] for lane in sorted(self._page)]


class TrialQueue:
    """Pending trials, admitted strictly in submission order.

    Seeded from an in-memory grid (``specs``) and/or fed from a watched
    JSONL submissions file: each ``poll()`` reads any COMPLETE new lines
    (a half-written tail is left for the next poll — same truncated-tail
    tolerance as the result store) and submits one spec per line.  A line
    is either a bare ``TrialSpec.to_dict()`` object or a record with a
    ``"spec"`` field (so result-store records can be piped back in as
    resubmissions).  Submissions deduplicate by trial key against
    everything ever queued AND against ``completed`` keys (the resume
    set); rejected submissions are counted, never fatal.
    """

    def __init__(self, specs: Sequence[TrialSpec] = (),
                 watch_path: Optional[str] = None,
                 completed: Iterable[str] = ()):
        self._pending: deque = deque()
        self._seen: set = set()          # every key ever queued
        self._done: set = set(completed)
        self.watch_path = watch_path
        self._watch_pos = 0
        self.n_submitted = 0
        self.n_skipped = 0               # dupes + already-completed
        for s in specs:
            self.submit(s)

    def __len__(self) -> int:
        return len(self._pending)

    def __bool__(self) -> bool:
        return bool(self._pending)

    def submit(self, spec: TrialSpec) -> bool:
        """Queue one trial; False (counted, not fatal) when its key was
        already queued or completed."""
        key = spec.key()
        if key in self._seen or key in self._done:
            self.n_skipped += 1
            return False
        spec.validate()
        self._seen.add(key)
        self._pending.append(spec)
        self.n_submitted += 1
        return True

    def pop(self) -> TrialSpec:
        return self._pending.popleft()

    def mark_done(self, key: str):
        self._done.add(key)

    def poll(self) -> int:
        """Read new complete lines from the watched submissions file and
        submit them; returns how many were accepted.  Byte-positional:
        only ever reads forward, so a writer appending concurrently is
        safe and a torn final line is retried next poll."""
        if self.watch_path is None or not os.path.exists(self.watch_path):
            return 0
        with open(self.watch_path, "rb") as f:
            f.seek(self._watch_pos)
            chunk = f.read()
        n = 0
        consumed = 0
        for raw in chunk.split(b"\n")[:-1]:   # complete lines only
            consumed += len(raw) + 1
            line = raw.decode("utf-8", errors="replace").strip()
            if not line:
                continue
            try:
                d = json.loads(line)
                if not isinstance(d, dict):
                    raise ValueError("submission line must be a JSON object")
                spec = spec_from_dict(d.get("spec") or d)
                if self.submit(spec):
                    n += 1
            except (ValueError, TypeError, KeyError) as e:
                # a malformed submission must not kill the daemon
                self.n_skipped += 1
                print(f"scheduler: skipping malformed submission line: {e}",
                      flush=True)
        self._watch_pos += consumed
        return n


@dataclass
class ServeStats:
    """One drain's bookkeeping: occupancy is averaged over scheduler
    steps, so a pool kept full by continuous admission scores ~1.0 where
    a fixed pack decays toward 1/capacity as trials finish."""
    admitted: int = 0
    retired: int = 0
    steps: int = 0
    occupancy_sum: float = 0.0
    admission_log: List[tuple] = field(default_factory=list)  # (key, lane)

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / self.steps if self.steps else 0.0


class TrialScheduler:
    """The serving loop: admit -> step every live trial -> retire.

    Sync trials advance one packed virtual round per scheduler step
    (``_sync_round_step``), async/buffered trials one merged-queue
    macro-step (``_EventEngine.macro_step``); both key their shapes
    off the pool's live set, never an initial T.  Retirement releases the
    lane and the freed slot is refilled from the queue at the top of the
    NEXT step — admission order is the queue order, regardless of which
    lanes freed when (property-tested).

    ``max_results`` stops the drain once AT LEAST that many trials have
    retired this invocation (a soft limit: the crossing step may retire
    one trial per live lane) — the CI smoke job uses it to simulate a
    killed daemon mid-drain; a fresh scheduler over the same store
    resumes past the retired keys.
    """

    def __init__(self, queue: TrialQueue, *, max_lanes: int = 4,
                 store=None, pack: str = "batched",
                 on_result: Optional[Callable[[TrialResult], None]] = None,
                 verbose: bool = False,
                 snapshot_path: Optional[str] = None,
                 snapshot_every: int = 1):
        self.queue = queue
        self.pool = LanePool(max_lanes)
        self.store = store
        self.on_result = on_result
        self.verbose = verbose
        self.snapshot_path = snapshot_path
        self.snapshot_every = max(1, int(snapshot_every))
        self._pack, self._mesh = _resolve_sync_pack(pack)
        self._ev = _EventEngine()
        self._sync_live: List = []
        self._event_live: List = []
        self._sync_steps = 0
        self.stats = ServeStats()
        self.results: List[TrialResult] = []
        self.duplicates_suppressed = 0
        self._sync_engine = f"serve-sync/{self._pack}"
        self._event_engine = "serve-events/batched"

    # -- admission ------------------------------------------------------
    def admit_pending(self) -> int:
        """Poll the watched submissions file, then admit queued trials
        into free lanes (queue order, lowest free lane first)."""
        self.queue.poll()
        n = 0
        while self.queue and self.pool.n_free:
            spec = self.queue.pop()
            lane = self.pool.alloc(spec.key())
            self.stats.admitted += 1
            self.stats.admission_log.append((spec.key(), lane))
            if obs.enabled():
                obs.registry.inc("trials_admitted")
                obs.record("admit", phase="admit", trial=spec.key(),
                           lane=lane, step=self.stats.steps,
                           queue_depth=len(self.queue))
            if spec.mode == "sync":
                self._sync_live.append(_make_live(spec))
            else:
                self._event_live.append(self._ev.admit(spec))
            if self.verbose:
                print(f"  serve: admit {spec.key()} -> lane {lane} "
                      f"({self.pool.n_live}/{self.pool.capacity} live)",
                      flush=True)
            n += 1
        return n

    # -- retirement -----------------------------------------------------
    def _retire(self, spec: TrialSpec, result: TrialResult):
        lane = self.pool.release(spec.key())
        self.queue.mark_done(spec.key())
        self.stats.retired += 1
        if obs.enabled():
            obs.registry.inc("trials_retired")
            obs.record("retire", phase="retire", trial=spec.key(),
                       lane=lane, step=self.stats.steps,
                       reached=result.reached, rounds=result.rounds)
        if self.store is not None:
            if self.store.is_completed(spec.key()):
                # restored-and-replayed macro-step: this trial retired
                # during the replayed step BEFORE the kill, so its row is
                # already in the store — appending again would duplicate it
                self.duplicates_suppressed += 1
            else:
                self.store.append(result.to_record())
        self.results.append(result)
        if self.on_result is not None:
            self.on_result(result)
        if self.verbose:
            print(f"  serve: retire {spec.key()} <- lane {lane} "
                  f"(reached={result.reached}, rounds={result.rounds})",
                  flush=True)

    # -- the loop -------------------------------------------------------
    def step(self):
        """One scheduler step: advance every live trial (sync trials by
        one packed round, event trials by one macro-step) and retire
        whatever finished.  Freed lanes refill at the next
        ``admit_pending`` call."""
        self.stats.steps += 1
        occ = self.pool.occupancy()
        self.stats.occupancy_sum += occ
        if obs.enabled():
            obs.registry.sample("pool_occupancy", occ,
                                step=self.stats.steps, engine="serve")
            obs.registry.sample("queue_depth", len(self.queue),
                                step=self.stats.steps)
        if self._sync_live:
            _sync_round_step(self._sync_live, pack=self._pack,
                             mesh=self._mesh, step_idx=self._sync_steps)
            self._sync_steps += 1
            for tr in [t for t in self._sync_live if t.done]:
                self._sync_live.remove(tr)
                self._retire(tr.spec, _to_result(tr, self._sync_engine))
        if self._event_live:
            ended: List = []
            self._ev.macro_step(self._event_live, ended.append)
            for tr in ended:
                self._event_live.remove(tr)
                res = TrialResult.from_flresult(
                    tr.spec, tr.eng.event_result(tr.st), tr.wall,
                    self._event_engine)
                self._retire(tr.spec, res)

    # -- crash-safe snapshots -------------------------------------------
    def snapshot(self, path: Optional[str] = None) -> Optional[str]:
        """Serialize the full scheduler state (live trials, merged event
        queue, lane table, trial queue, counters) at the current macro-
        step boundary through the hardened two-slot checkpointer.  Call
        only between steps — mid-step state (packed cohorts) is not
        serialized.  Returns the written npz path."""
        path = path or self.snapshot_path
        if path is None:
            return None
        from repro.experiments.snapshot import snapshot_scheduler
        with obs.span("snapshot", phase="snapshot", step=self.stats.steps,
                      n_live=self.pool.n_live):
            return snapshot_scheduler(self, path)

    @classmethod
    def restore(cls, path: str, *, store=None, pack: str = "batched",
                on_result: Optional[Callable[[TrialResult], None]] = None,
                watch_path: Optional[str] = None,
                verbose: bool = False,
                snapshot_every: int = 1) -> "TrialScheduler":
        """A scheduler resumed from the newest valid snapshot at ``path``:
        live trials replay the interrupted macro-step (at most one) and
        the drain continues bit-identically to an uninterrupted serve.
        The lane capacity comes from the snapshot.  Rows re-retired
        during the replay are suppressed against ``store``
        (``duplicates_suppressed`` counts them)."""
        from repro.experiments.snapshot import restore_scheduler
        queue = TrialQueue(watch_path=watch_path)
        sched = cls(queue, store=store, pack=pack,
                    on_result=on_result, verbose=verbose,
                    snapshot_path=path, snapshot_every=snapshot_every)
        with obs.span("restore", phase="snapshot"):
            restore_scheduler(sched, path)
        if verbose:
            print(f"  serve: restored {sched.pool.n_live} live trials at "
                  f"macro-step {sched.stats.steps} from {path}", flush=True)
        return sched

    def _maybe_snapshot(self):
        if (self.snapshot_path is not None
                and self.stats.steps % self.snapshot_every == 0):
            self.snapshot()

    def drain(self, max_results: Optional[int] = None,
              max_steps: Optional[int] = None) -> List[TrialResult]:
        """Admit + step until the queue and the pool are both empty (or
        ``max_results`` trials retired / ``max_steps`` macro-steps run
        this invocation — the kill-mid-drain hooks).  Returns every
        result retired by THIS call.  With ``snapshot_path`` set, a
        snapshot is written before every ``snapshot_every``-th step and
        once after the drain completes — a kill at any instant loses at
        most the macro-steps since the last boundary snapshot.  A
        ``max_steps`` exit IS the simulated kill, so it deliberately
        skips the final snapshot (resume must replay from the last
        boundary, exactly as after a real crash)."""
        n0 = len(self.results)
        steps0 = self.stats.steps
        killed = False
        while True:
            if max_results is not None and len(self.results) - n0 >= max_results:
                break
            if max_steps is not None and self.stats.steps - steps0 >= max_steps:
                killed = True      # simulated crash: no final snapshot
                break
            self.admit_pending()
            if not self._sync_live and not self._event_live:
                break
            self._maybe_snapshot()
            self.step()
        if not killed:
            self.snapshot()  # final boundary (no-op without snapshot_path)
        return self.results[n0:]


def serve(trials: Union[TrialQueue, Sequence[TrialSpec]], *,
          max_lanes: int = 4, store=None, pack: str = "batched",
          on_result: Optional[Callable[[TrialResult], None]] = None,
          max_results: Optional[int] = None,
          max_steps: Optional[int] = None,
          snapshot_path: Optional[str] = None,
          snapshot_every: int = 1,
          verbose: bool = False) -> List[TrialResult]:
    """Drain ``trials`` (a ``TrialQueue`` or a plain spec list) through a
    continuous-batching ``TrialScheduler`` with ``max_lanes`` lanes.  With
    a spec list and a ``store``, already-completed keys are skipped
    (resume).  Results come back in retirement order; each is appended to
    the store as it retires.  ``snapshot_path`` arms boundary snapshots
    (see ``TrialScheduler.drain``)."""
    if not isinstance(trials, TrialQueue):
        completed = store.completed_keys() if store is not None else ()
        trials = TrialQueue(specs=trials, completed=completed)
    sched = TrialScheduler(trials, max_lanes=max_lanes, store=store,
                           pack=pack, on_result=on_result, verbose=verbose,
                           snapshot_path=snapshot_path,
                           snapshot_every=snapshot_every)
    return sched.drain(max_results=max_results, max_steps=max_steps)
