"""GOOD fixture: the house jit patterns REPRO006 must NOT flag.

Module-level construction compiles once; factories guarded by an
explicit ``*_cache`` memoize; static args stay hashable.
"""

import functools

import jax

_step_cache = {}


def make_step(fn):
    if fn not in _step_cache:
        _step_cache[fn] = jax.jit(fn)   # cached factory: compiles once
    return _step_cache[fn]


@functools.partial(jax.jit, static_argnums=(1,))
def scale(x, n):
    return x * n


encode = jax.jit(lambda x, n: x * n, static_argnums=(1,))


def run(x):
    return encode(x, 4)                 # hashable static arg: fine
