"""GOOD fixture: the same loops, order pinned or order-insensitive."""


def schedule(events_by_trial, queue):
    for _trial, evs in sorted(events_by_trial.items()):
        for ev in evs:
            queue.push(ev)


def jitter(cids, rng):
    for cid in sorted(set(cids)):
        yield cid, rng.uniform()


def totals(sizes_by_cid):
    # unsorted iteration is fine when the body is order-insensitive
    total = 0
    for n in sizes_by_cid.values():
        total += n
    return total
