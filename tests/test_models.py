"""Per-architecture smoke tests: a REDUCED same-family variant (2 layers,
d_model<=512, <=4 experts) runs one forward/train step on CPU with correct
output shapes and no NaNs — as required for every assigned architecture."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.configs.paper_models import MLP_EMNIST, RESNET10
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    batch = {
        "tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
    }
    if cfg.frontend is not None:
        batch["frontend"] = jax.random.normal(
            KEY, (b, cfg.frontend.seq_len, cfg.frontend.feature_dim))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)

    # forward: shapes
    logits = model.forward(params, batch["tokens"],
                           frontend=batch.get("frontend"), use_kernel=False)
    s_total = batch["tokens"].shape[1]
    if cfg.frontend is not None and cfg.frontend.kind == "vision_patches":
        s_total += cfg.frontend.seq_len
    assert logits.shape == (2, s_total, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    # one train step (loss + grad + sgd update): finite
    def loss(p):
        return model.loss_fn(p, batch)[0]

    l, g = jax.value_and_grad(loss)(params)
    assert jnp.isfinite(l)
    gnorm = sum(jnp.sum(x.astype(jnp.float32) ** 2)
                for x in jax.tree.leaves(g)) ** 0.5
    assert jnp.isfinite(gnorm) and gnorm > 0
    new_params = jax.tree.map(lambda p_, g_: p_ - 1e-2 * g_, params, g)
    l2 = loss(new_params)
    assert jnp.isfinite(l2)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    fe = None
    p_len = 0
    if cfg.frontend is not None:
        fe = jax.random.normal(KEY, (B, cfg.frontend.seq_len,
                                     cfg.frontend.feature_dim))
        if cfg.frontend.kind == "vision_patches":
            p_len = cfg.frontend.seq_len
    if cfg.moe is not None:
        # The training forward's capacity-based MoE dispatch drops tokens as
        # a function of batch composition (Switch-style overflow — for the
        # dbrx seed the LAST token overflows a hot expert, a 0.45 logit
        # shift), so the serving path (prefill/decode) is deliberately
        # drop-free.  Compare against the drop-free (capacity-infinite
        # masked-dense) forward, the semantics serving implements.
        from repro.models import ffn as ffn_mod
        with ffn_mod.moe_impl("dense"):
            full = model.forward(params, tokens, frontend=fe,
                                 use_kernel=False)
    else:
        full = model.forward(params, tokens, frontend=fe, use_kernel=False)
    cache = model.init_cache(B, max_len=p_len + S + 4)
    _, cache = model.prefill(params, tokens[:, :S - 1], cache, frontend=fe,
                             use_kernel=False)
    dec, _ = model.decode_step(params, tokens[:, S - 1],
                               jnp.int32(p_len + S - 1), cache)
    err = float(jnp.abs(dec - full[:, -1]).max())
    assert err < 5e-3, f"{arch}: decode/forward mismatch {err}"


def test_paper_models_smoke():
    for cfg, shape in ((RESNET10, (4, 32, 32, 1)), (MLP_EMNIST, (4, 784))):
        m = build_model(cfg)
        params = m.init(KEY)
        x = jax.random.normal(KEY, shape)
        y = jax.random.randint(KEY, (4,), 0, cfg.n_classes)
        loss, metrics = m.loss_fn(params, {"x": x, "y": y})
        assert jnp.isfinite(loss)
        assert m.flops_per_example > 0


def test_long_context_window_ring_cache():
    """Full-attention arch under the sliding-window serving variant: cache
    stays at window size and decode still works at huge positions."""
    cfg = reduced(get_config("qwen2-7b"))
    model = build_model(cfg)
    params = model.init(KEY)
    B, W = 1, cfg.long_context_window
    cache = model.init_cache(B, max_len=1 << 19, decode_window=W)
    # attention layer caches must be ring buffers of size W
    from repro.models.attention import KVCache
    for st in cache["layers"]:
        if isinstance(st, KVCache):
            assert st.k.shape[1] == W
    tok = jnp.zeros((B,), jnp.int32)
    logits, cache = model.decode_step(params, tok, jnp.int32((1 << 19) - 1),
                                      cache)
    assert jnp.isfinite(logits).all()
