from repro.kernels.ops import (fed_aggregate, fed_reduce,  # noqa: F401
                               flash_attention, rglru_scan)
