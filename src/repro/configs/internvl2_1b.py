"""internvl2-1b — 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
InternViT vision encoder + projector is a STUB: ``input_specs`` provides
precomputed patch embeddings; we implement the InternLM2/Qwen2-style language
backbone.  [arXiv:2404.16821]"""

from repro.configs.base import FrontendConfig, ModelConfig, uniform_layers

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151_655,
    layers=uniform_layers(24),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    frontend=FrontendConfig(kind="vision_patches", seq_len=256, feature_dim=896),
    tie_embeddings=True,
    source="arXiv:2404.16821",
)
