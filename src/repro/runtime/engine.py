"""Event-driven FL runtime: sync / async / buffered execution over a
heterogeneous device fleet on a virtual clock.

The engine separates *what* is computed (client local training, aggregation,
FedTune decisions — all shared with the legacy ``FLServer`` loop) from *when*
results arrive (per-client simulated wall-clock from the fleet's device
profiles).  Three execution policies:

  sync     — rounds with a deadline: the server dispatches M clients, waits
             until an absolute deadline / completion quantile, aggregates
             whatever arrived, and cuts the stragglers.  With no deadline
             over a homogeneous fleet this IS the paper's loop (verified in
             tests/test_runtime.py).
  async    — FedAsync: every arrival is applied immediately with a
             staleness-discounted mixing rate; the server model version
             advances per update and M acts as the in-flight concurrency.
  buffered — FedBuff: arrivals accumulate staleness-weighted *deltas* into a
             K-slot buffer which is flushed through the ``fed_aggregate``
             Pallas kernel; M is the concurrency, K the buffer size.

Sync-mode client execution is a separate knob (``client_exec``): sequential
(one jitted micro-step loop per client), batched (whole cohort vmapped on
one device, batched.py), or sharded (cohort laid out over a ``clients``
mesh axis with on-device psum aggregation, sharded.py; auto-falls back to
batched on a single device).

Both the sync round and the async/buffered event loop are factored into
plan/apply/account/finish pieces (``plan_sync_round``/``account_sync_round``
and the ``EventLoopState`` methods) so the multi-trial sweep engine
(repro.experiments.runner) replays the exact same decisions and rng order
while replacing only the training step with packed cohorts.

Timing model (virtual seconds; unit-rate reference devices keep the numbers
in the same scale as the paper's eqs. 2-5): a dispatched client downloads
the model, computes ``E`` passes at its device speed, and uploads its update
(scaled by the compression factor).  Availability is sampled per dispatch
(unavailable clients are replaced), dropout per round (the work is done and
counted, but the update never arrives).  All stochasticity flows from two
seeded generators — the server rng (selection + batch order, shared with the
legacy loop) and a dedicated system rng (availability/dropout) — so a run is
bit-reproducible from its seeds.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import numpy as np

from repro import obs
from repro.core.tuner import HyperParams
from repro.federated.aggregation import (FedBuffAggregator,
                                         apply_async_update)
from repro.federated.compression import upload_factor
from repro.federated.evaluation import eval_due
from repro.federated.server import FLResult, FLServer, RoundRecord
from repro.runtime.events import (ARRIVAL, DROPOUT, FAILURE, EventQueue,
                                  VirtualClock)
from repro.runtime.profiles import Fleet, homogeneous_fleet


RUNTIME_MODES = ("sync", "async", "buffered")
CLIENT_EXECS = ("sequential", "batched", "sharded")


@dataclass
class RuntimeConfig:
    """The runtime's two orthogonal knobs and their mode-specific settings.

    ``mode`` picks WHEN results arrive (sync deadline rounds / FedAsync /
    FedBuff); ``client_exec`` picks HOW a sync round's local training
    executes (see the fallback matrix in docs/ARCHITECTURE.md).  Names are
    validated at construction — a sweep grid fails at expansion time, not
    rounds into trial 37.  Invariant pinned in tests/test_runtime.py:
    ``RuntimeConfig()`` (sync, no deadline) over a homogeneous fleet
    reproduces ``FLServer.run_legacy`` round for round, bit-exactly."""
    mode: str = "sync"                 # sync | async | buffered
    deadline: Optional[float] = None   # sync: absolute round deadline (virtual s)
    deadline_quantile: float = 1.0     # sync: cut stragglers above this
                                       # completion quantile (1.0 = wait for all)
    min_updates: int = 1               # sync: never aggregate fewer arrivals
    buffer_k: int = 8                  # buffered: updates per flush
    staleness_alpha: float = 0.5       # async/buffered: s(tau) exponent
    staleness_kind: str = "polynomial"
    async_mix: float = 0.6             # async: FedAsync mixing rate
    server_lr: float = 1.0             # buffered: flush scale
    batched: bool = False              # deprecated alias: client_exec="batched"
    client_exec: str = "sequential"    # sync client-execution backend:
                                       # sequential | batched | sharded
    system_seed: int = 0               # availability/dropout stream
    # failure policy (only exercised when the fleet has a failure model —
    # Fleet.has_failures() gates every code path, so fault-free runs stay
    # bit-identical to the pre-failure runtime):
    max_retries: int = 2               # retries after a hard-failed dispatch
    retry_backoff: float = 0.25        # virtual-time backoff before a retry,
                                       # as a fraction of the failed
                                       # attempt's comp+trans time (scale-
                                       # free: backoff tracks device speed)

    def __post_init__(self):
        # fail at construction time (e.g. sweep-grid expansion), not rounds
        # into a trial
        if self.mode not in RUNTIME_MODES:
            raise ValueError(
                f"unknown runtime mode {self.mode!r}; valid modes: "
                + ", ".join(RUNTIME_MODES))
        if self.client_exec not in CLIENT_EXECS:
            raise ValueError(
                f"unknown client_exec {self.client_exec!r}; valid backends: "
                + ", ".join(CLIENT_EXECS))
        if self.max_retries < 0 or self.retry_backoff < 0.0:
            raise ValueError(
                f"bad failure policy (max_retries={self.max_retries}, "
                f"retry_backoff={self.retry_backoff}); both must be >= 0")


class SyncRoundPlan(NamedTuple):
    """One sync round's participation decision, fixed BEFORE any training
    runs: who was dispatched, who made the deadline, and what the round
    costs in virtual time.  Produced by ``plan_sync_round`` — shared by the
    engine's own sync loop and the multi-trial sweep runner
    (repro.experiments.runner), which plans every live trial's round with
    this exact code before packing their cohorts together."""
    active: List[int]       # dispatched clients (post availability retries)
    sizes: List[int]        # their dataset sizes
    comp: List[float]       # per-client simulated compute time
    trans: List[float]      # per-client simulated transfer time
    included: List[int]     # indices into ``active`` that aggregate
    round_time: float       # virtual-clock advance for the round
    # failure/retry extension (PR 9) — empty tuples unless the fleet has a
    # failure model, so fault-free plans are unchanged:
    offsets: Tuple[float, ...] = ()       # per-slot dispatch delay (a retry
                                          # slot starts after its failed
                                          # predecessor's detection+backoff)
    failed: Tuple[int, ...] = ()          # indices into active that failed
    failed_trans: Tuple[float, ...] = ()  # their down-only transfer time
                                          # (the upload never happened)

    @property
    def train_cids(self) -> List[int]:
        return [self.active[i] for i in self.included]


@dataclass
class _InFlight:
    client_id: int
    params: Any            # global params snapshot at dispatch
    version: int           # server model version at dispatch
    e: float               # local passes the client was asked to run
    n_examples: int
    comp_time: float
    trans_time: float
    attempt: int = 0       # 0 = first dispatch; bumps per failure retry


@dataclass
class EventLoopState:
    """Host-side state of ONE async/buffered trial's event loop, factored
    out of ``_run_event_loop`` so the standalone engine and the vectorized
    multi-trial sweep runner (repro.experiments.runner) drive the SAME
    plan/apply/account/finish code — the async/buffered analogue of
    ``SyncRoundPlan``.

    Lifecycle per arrival event (the contract the sweep runner replays):

      1. ``plan_event``    — pop the in-flight record, charge its traffic/
                             compute loads; returns None for a dropout.
      2. (train)           — the client's local training from its dispatch
                             snapshot ``_InFlight.params``.  The standalone
                             loop runs ``FLServer._client_update``; the
                             sweep runner packs many trials' arrivals into
                             one vectorized cohort instead.
      3. ``apply_event``   — staleness-discounted FedAsync mixing or a
                             FedBuff buffer add (+flush when full).
      4. ``finish_event_round`` (only if an aggregation happened) —
                             cost accounting, evaluation, history record,
                             FedTune controller step, target check.
      5. ``fill_event_concurrency`` — top in-flight clients back up to M.

    All stochasticity (selection, availability, dropout, batch order) flows
    through the owning runtime's rngs in exactly this order, which is what
    makes a vectorized trial bit-identical to its standalone run."""
    hp: HyperParams
    params: Any                    # current global model
    buffer: FedBuffAggregator      # buffered mode's K-slot delta buffer
    version: int = 0               # server model version (increments per agg)
    inflight: Dict[int, _InFlight] = field(default_factory=dict)
    pend_comp: List[float] = field(default_factory=list)
    pend_trans: List[float] = field(default_factory=list)
    pend_comp_load: float = 0.0
    pend_trans_load: float = 0.0
    last_agg_clock: float = 0.0
    history: List[RoundRecord] = field(default_factory=list)
    accuracy: float = 0.0
    reached: bool = False
    # bookkeeping compared in the sweep parity tests (consumes no rng):
    dispatch_log: List[tuple] = field(default_factory=list)   # (t, cid, ver)
    staleness_log: List[int] = field(default_factory=list)    # per arrival


class EventDrivenRuntime:
    """Drives one FLServer's components under a virtual clock."""

    def __init__(self, server: FLServer, fleet: Optional[Fleet] = None,
                 config: Optional[RuntimeConfig] = None):
        self.srv = server
        self.rt = config or RuntimeConfig()
        self.fleet = fleet or homogeneous_fleet(server.dataset.n_clients)
        assert self.fleet.n_clients == server.dataset.n_clients
        self.sys_rng = np.random.default_rng(self.rt.system_seed)
        self.clock = VirtualClock()
        self.queue = EventQueue()
        # observability attribution: spans from this runtime carry this
        # label as their trial/track name.  The sweep runner overrides it
        # with the trial's spec key; standalone runs trace as "run".
        self.trace_label: str = "run"
        cm = server.cost_model
        self._c1 = cm.train_flops_per_example
        self._uf = upload_factor(server.config.compression)
        self._down, self._up = cm.traffic_halves(self._uf)
        self.client_exec = self._resolve_client_exec()

    def _resolve_client_exec(self) -> str:
        """Pick the sync-mode client-execution backend, falling back along
        sharded -> batched -> sequential when preconditions are missing."""
        rt, server = self.rt, self.srv
        mode = rt.client_exec
        if mode not in ("sequential", "batched", "sharded"):
            raise ValueError(f"unknown client_exec {mode!r}; valid: "
                             "sequential, batched, sharded")
        if rt.batched and mode == "sequential":
            mode = "batched"    # legacy flag
        if mode == "sequential":
            return mode
        if rt.mode != "sync":
            print(f"runtime: {mode} execution applies to the sync mode "
                  "(async/buffered train one arrival at a time); using "
                  "the sequential client loop", flush=True)
            return "sequential"
        if mode == "sharded" and jax.device_count() == 1:
            print("runtime: sharded execution needs a multi-device mesh "
                  "(jax.device_count() == 1, try XLA_FLAGS="
                  "--xla_force_host_platform_device_count=8); falling "
                  "back to batched", flush=True)
            return "batched"
        if mode == "sharded" and self.srv.aggregator.name != "fedavg":
            print("runtime: sharded execution fuses FedAvg aggregation on "
                  f"device; aggregator {self.srv.aggregator.name!r} needs "
                  "per-client updates — falling back to batched",
                  flush=True)
            return "batched"
        return mode

    # ------------------------------------------------------------------
    # timing primitives
    # ------------------------------------------------------------------
    def _comp_time(self, cid: int, n_examples: int, e: float) -> float:
        return self.fleet.comp_time(cid, self._c1 * e * n_examples)

    def _trans_time(self, cid: int) -> float:
        return self.fleet.trans_time(cid, self._down, self._up)

    def _available(self, cid: int) -> bool:
        a = float(self.fleet.availability[cid])
        return a >= 1.0 or self.sys_rng.random() < a

    def _drops(self, cid: int) -> bool:
        d = float(self.fleet.dropout[cid])
        return d > 0.0 and self.sys_rng.random() < d

    def _is_active(self, cid: int, t: float) -> bool:
        """Churn membership at virtual time ``t``.  Checked BEFORE any
        availability draw so inactive clients consume no rng — churn-free
        fleets short-circuit to True and the rng stream is untouched."""
        return self.fleet.is_active(cid, t)

    def _pick_replacement(self, tried: set, t: float) -> Optional[int]:
        """Select a fresh client for a failed slot's retry: not yet tried
        this round, active under churn, and passing an availability draw.
        Same bounded-retry shape as the sync availability loop; consumes
        the selector/server rng and the system rng ONLY on the gated
        failure path."""
        srv = self.srv
        for _ in range(5):
            if len(tried) >= srv.dataset.n_clients:
                return None
            k = min(srv.dataset.n_clients, len(tried) + 1)
            for cid in (int(c) for c in srv.selector.select(k)):
                if cid in tried:
                    continue
                tried.add(cid)
                if self._is_active(cid, t) and self._available(cid):
                    return cid
        return None

    # ------------------------------------------------------------------
    def run(self, params=None) -> FLResult:
        """Run the trial to target accuracy or the round budget under the
        configured mode; ``params`` defaults to a fresh seed-determined
        model init (identical to the legacy loop's)."""
        cfg = self.srv.config
        if params is None:
            params = self.srv.model.init(jax.random.PRNGKey(cfg.seed))
        if self.rt.mode == "sync":
            return self._run_sync(params)
        if self.rt.mode in ("async", "buffered"):
            return self._run_event_loop(params)
        raise KeyError(f"unknown runtime mode {self.rt.mode!r}")

    # ------------------------------------------------------------------
    # sync: deadline rounds with straggler cutoff
    # ------------------------------------------------------------------
    @obs.traced("plan_sync_round", phase="plan")
    def plan_sync_round(self, hp: HyperParams) -> SyncRoundPlan:
        """Decide one sync round's participation: selection (+ availability
        retries), per-client timing, dropout draws, and the deadline cut.
        Consumes the selector/server rng and the system rng exactly once per
        round — the single source of randomness ordering for the engine's
        sync loop AND the multi-trial sweep runner."""
        srv, rt = self.srv, self.rt
        t0 = self.clock.now
        if obs.enabled() and self.fleet.churn is not None:
            obs.registry.sample("fleet_size", self.fleet.n_active(t0))
        m = min(hp.m, srv.dataset.n_clients)
        participants = [int(c) for c in srv.selector.select(m)]
        active = [c for c in participants
                  if self._is_active(c, t0) and self._available(c)]
        # replace unavailable clients (bounded retries) so sync rounds
        # run at the same effective M as the async modes hold in flight
        tried = set(participants)
        for _ in range(5):
            if len(active) >= m or len(tried) >= srv.dataset.n_clients:
                break
            k = min(srv.dataset.n_clients, m + len(tried))
            for cid in (int(c) for c in srv.selector.select(k)):
                if len(active) >= m:
                    break
                if cid in tried:
                    continue
                tried.add(cid)
                if self._is_active(cid, t0) and self._available(cid):
                    active.append(cid)

        # inclusion is a pure function of fleet timing, client sizes,
        # and the dropout draws — decide it BEFORE training so cut
        # stragglers and dropouts cost only virtual time, not host
        # wall-clock (their simulated work is still charged below)
        sizes = [int(srv.dataset.client_sizes[c]) for c in active]
        comp = [self._comp_time(c, n, hp.e) for c, n in zip(active, sizes)]
        trans = [self._trans_time(c) for c in active]
        total = [c + t for c, t in zip(comp, trans)]
        survived = [not self._drops(c) for c in active]

        # hard failures + retry/reassignment (gated: zero rng draws and an
        # unchanged plan when the fleet has no failure model).  A failed
        # dispatch is detected at its would-be arrival (offset+comp+trans);
        # within the retry budget a FRESH client is selected and dispatched
        # after a backoff, its slot offset by the detection time — chained
        # failures walk the attempt counter until max_retries.  Like
        # dropouts, failed slots do not extend round_time themselves (only
        # through their replacements); their wasted work IS charged, in
        # account_sync_round.
        offsets = [0.0] * len(active)
        attempts = [0] * len(active)
        failed: List[int] = []
        failed_trans: List[float] = []
        if self.fleet.has_failures():
            i = 0
            while i < len(active):
                cid = active[i]
                if self.fleet.fails(cid, t0 + offsets[i], attempts[i]):
                    survived[i] = False
                    failed.append(i)
                    failed_trans.append(
                        self.fleet.trans_time(cid, self._down, 0.0))
                    detect = offsets[i] + comp[i] + trans[i]
                    if obs.enabled():
                        obs.registry.inc("client_failures")
                        obs.record("failure", phase="failure",
                                   trial=self.trace_label,
                                   virtual=(t0 + offsets[i], t0 + detect),
                                   cid=int(cid), attempt=attempts[i])
                    if attempts[i] < rt.max_retries:
                        backoff = rt.retry_backoff * (comp[i] + trans[i])
                        rep = self._pick_replacement(tried, t0)
                        if rep is not None:
                            n = int(srv.dataset.client_sizes[rep])
                            active.append(rep)
                            sizes.append(n)
                            comp.append(self._comp_time(rep, n, hp.e))
                            trans.append(self._trans_time(rep))
                            offsets.append(detect + backoff)
                            attempts.append(attempts[i] + 1)
                            survived.append(not self._drops(rep))
                            if obs.enabled():
                                obs.registry.inc("retries_scheduled")
                                obs.record(
                                    "retry", phase="failure",
                                    trial=self.trace_label,
                                    virtual=(t0 + detect,
                                             t0 + detect + backoff),
                                    cid=int(rep),
                                    attempt=attempts[i] + 1)
                i += 1
            total = [o + c + t
                     for o, c, t in zip(offsets, comp, trans)]

        # deadline: absolute budget or completion quantile over the cohort
        deadline = np.inf
        if rt.deadline is not None:
            deadline = rt.deadline
        elif rt.deadline_quantile < 1.0 and total:
            deadline = float(np.quantile(total, rt.deadline_quantile))
        order = np.argsort(np.asarray(total, np.float64),
                           kind="stable") if total else []
        chosen = set()             # indices into active, by arrival order
        for i in order:
            i = int(i)
            if survived[i] and (total[i] <= deadline
                                or len(chosen) < rt.min_updates):
                chosen.add(i)
        # train + aggregate in dispatch order (matches the legacy loop
        # exactly when nothing is cut)
        included = [i for i in range(len(active)) if i in chosen]
        cut_any = len(included) < sum(survived)
        if included:
            waited = max(total[i] for i in included)
            round_time = max(deadline, waited) if (
                cut_any and np.isfinite(deadline)) else waited
        else:
            round_time = deadline if np.isfinite(deadline) else (
                max(total) if total else 0.0)
        if obs.enabled():
            obs.registry.inc("sync_dispatched", len(active))
            obs.registry.inc("sync_dropouts",
                             len(active) - sum(survived) - len(failed))
            obs.registry.inc("sync_stragglers_cut",
                             sum(survived) - len(included))
        return SyncRoundPlan(active=active, sizes=sizes, comp=comp,
                             trans=trans, included=included,
                             round_time=round_time,
                             offsets=tuple(offsets), failed=tuple(failed),
                             failed_trans=tuple(failed_trans))

    @obs.traced("account_sync_round", phase="account")
    def account_sync_round(self, plan: SyncRoundPlan,
                           hp: HyperParams):
        """Charge one planned sync round to the cost model: critical-path
        times over the included arrivals, exact work/traffic sums over the
        dispatched cohort.  Failed attempts charge their wasted work too:
        their compute extends the CompT critical path, their down-link
        transfer (the dispatch WAS consumed; the upload never happened)
        extends TransT, and their load is already covered by the
        dispatched-cohort sums (sizes include failed slots; down counts
        every active slot, up only included ones)."""
        comp_time = max((plan.comp[i] for i in plan.included), default=0.0)
        trans_time = max((plan.trans[i] for i in plan.included), default=0.0)
        if plan.failed:
            comp_time = max([comp_time]
                            + [plan.comp[i] for i in plan.failed])
            trans_time = max([trans_time] + list(plan.failed_trans))
        return self.srv.cost_model.add_timed_round(
            comp_time=comp_time,
            trans_time=trans_time,
            comp_load=self._c1 * hp.e * float(sum(plan.sizes)),
            trans_load=(self._down * len(plan.active)
                        + self._up * len(plan.included)),
        )

    def _run_sync(self, params) -> FLResult:
        srv, cfg = self.srv, self.srv.config
        hp = HyperParams(m=cfg.m, e=cfg.e)
        history: List[RoundRecord] = []
        accuracy = 0.0
        reached = False

        for r in range(cfg.max_rounds):
            t0 = time.perf_counter()  # noqa: REPRO004 -- measures the RoundRecord.wall info field only; results use self.clock virtual time
            v0 = self.clock.now
            plan = self.plan_sync_round(hp)
            self.clock.advance_to(self.clock.now + plan.round_time)
            included, active = plan.included, plan.active

            if included:
                train_cids = plan.train_cids
                if self.client_exec == "sharded":
                    # aggregation already happened on device (psum across
                    # the clients mesh axis) — no per-client updates exist
                    params = self._sharded_round(params, train_cids, hp.e)
                else:
                    if self.client_exec == "batched":
                        updates, _ = self._batched_cohort(params,
                                                          train_cids, hp.e)
                    else:
                        updates = [srv._client_update(params, cid, hp.e)[0]
                                   for cid in train_cids]
                    params = srv.aggregator(params, updates)
            round_cost = self.account_sync_round(plan, hp)

            if eval_due(r, cfg.eval_every, cfg.max_rounds):
                accuracy = srv._evaluate(params)
            t1 = time.perf_counter()  # noqa: REPRO004 -- RoundRecord.wall is informational; parity ignores it
            wall = t1 - t0
            if obs.enabled():
                obs.record("round", phase="round", trial=self.trace_label,
                           round_idx=r, wall=(t0, t1),
                           virtual=(v0, self.clock.now),
                           n_included=len(included), n_active=len(active))
                obs.counter("t_sim", self.clock.now)
            history.append(RoundRecord(r, hp.m, hp.e, accuracy, round_cost,
                                       wall, sim_time=self.clock.now,
                                       n_updates=len(included)))
            if cfg.log_every and (r + 1) % cfg.log_every == 0:
                print(f"  round {r+1:4d}  acc={accuracy:.4f}  M={hp.m} "
                      f"E={hp.e:g}  arrived={len(included)}/{len(active)} "
                      f"t_sim={self.clock.now:.3g}", flush=True)
            if accuracy >= cfg.target_accuracy:
                reached = True
                break
            hp = srv.tuner.on_round(r, accuracy, round_cost,
                                    srv.cost_model.total, hp)
            hp = hp.clamped(srv.dataset.n_clients, 100.0)

        return FLResult(
            reached_target=reached, rounds=len(history),
            final_accuracy=accuracy,
            total_cost=srv.cost_model.total.copy(), history=history,
            final_m=hp.m, final_e=hp.e, params=params,
            sim_time=self.clock.now)

    def _batched_cohort(self, params, active: List[int], e: float):
        from repro.runtime.batched import batched_local_train
        srv = self.srv
        data = [srv.dataset.client_data(c) for c in active]
        updates = batched_local_train(
            srv.model, params, data, passes=e,
            batch_size=srv.config.batch_size, optimizer=srv.optimizer,
            rng=srv.rng, prox_mu=srv.config.prox_mu, client_ids=active,
            compression=srv.config.compression)
        sizes = [len(y) for _, y in data]
        for upd, n in zip(updates, sizes):
            srv.selector.update(upd.client_id, upd.last_loss, n)
        return updates, sizes

    def _sharded_round(self, params, active: List[int], e: float):
        from repro.runtime.sharded import sharded_fedavg_train
        srv = self.srv
        data = [srv.dataset.client_data(c) for c in active]
        res = sharded_fedavg_train(
            srv.model, params, data, passes=e,
            batch_size=srv.config.batch_size, optimizer=srv.optimizer,
            rng=srv.rng, prox_mu=srv.config.prox_mu, client_ids=active,
            compression=srv.config.compression)
        for cid, loss, n in zip(active, res.last_losses, res.n_examples):
            srv.selector.update(int(cid), float(loss), n)
        return res.params

    # ------------------------------------------------------------------
    # async / buffered: a true event loop over the virtual clock.
    # The loop is factored into plan/apply/account/finish methods over an
    # ``EventLoopState`` (the async analogue of plan_sync_round/
    # account_sync_round) so the vectorized multi-trial sweep runner can
    # drive T trials' event loops off ONE merged queue, replacing only the
    # training step with a packed cohort.
    # ------------------------------------------------------------------
    def init_event_state(self, params, queue=None) -> EventLoopState:
        """Fresh event-loop state with the initial concurrency dispatched at
        t=0.  ``queue`` defaults to the runtime's own ``EventQueue``; the
        sweep runner passes a ``TrialQueueView`` onto its merged queue."""
        cfg, rt = self.srv.config, self.rt
        st = EventLoopState(
            hp=HyperParams(m=cfg.m, e=cfg.e), params=params,
            buffer=FedBuffAggregator(
                buffer_k=rt.buffer_k, server_lr=rt.server_lr,
                staleness_alpha=rt.staleness_alpha,
                staleness_kind=rt.staleness_kind))
        self.fill_event_concurrency(st, 0.0, queue)
        return st

    def dispatch_event(self, st: EventLoopState, cid: int, now: float,
                       queue=None, attempt: int = 0):
        """Send the current global model to one client: snapshot
        ``st.params``/``st.version`` into an ``_InFlight`` record, draw the
        client's mid-round dropout (system rng), and schedule its
        arrival/dropout/failure event at ``now + comp + trans``.

        The dropout draw is kept even when the fleet's failure model then
        overrides the outcome — the system rng stream must stay aligned
        with the failure-free run (bit-parity contract); the failure draw
        itself is stateless (hash of seed/cid/time/attempt) and consumes
        nothing.  ``attempt`` counts retries of the same logical dispatch
        (handle_failure re-dispatches with attempt+1)."""
        queue = self.queue if queue is None else queue
        srv = self.srv
        n = int(srv.dataset.client_sizes[cid])
        comp = self._comp_time(cid, n, st.hp.e)
        trans = self._trans_time(cid)
        st.inflight[cid] = _InFlight(cid, st.params, st.version, st.hp.e,
                                     n, comp, trans, attempt=attempt)
        st.dispatch_log.append((float(now), int(cid), st.version))
        kind = DROPOUT if self._drops(cid) else ARRIVAL
        if self.fleet.has_failures() and self.fleet.fails(cid, now, attempt):
            kind = FAILURE
        if obs.enabled():
            obs.registry.inc("event_dispatched")
        queue.push(now + comp + trans, kind, client_id=cid)

    def handle_failure(self, st: EventLoopState, ev, queue=None):
        """Coordinator half of a FAILURE event: the dispatch was consumed
        (download + the client's compute happened) but the update never
        came back.  Charge the wasted work into the pending window —
        down-link traffic and compute load like a dropout, plus the failed
        attempt's comp time and its down-only transfer into the window's
        comp/trans split (a failure is detected at its would-be arrival,
        so its whole span sits on the window's critical path) — then, if
        the retry budget allows, re-dispatch the SAME client after a
        virtual-time backoff proportional to the failed attempt.  The
        refill pass that follows (caller's fill_event_concurrency) is what
        reassigns the slot to a fresh client when the retry budget is
        spent."""
        queue = self.queue if queue is None else queue
        fl = st.inflight.pop(ev.client_id)
        down_trans = self.fleet.trans_time(fl.client_id, self._down, 0.0)
        st.pend_comp_load += self._c1 * fl.e * fl.n_examples
        st.pend_trans_load += self._down
        st.pend_comp.append(fl.comp_time)
        st.pend_trans.append(down_trans)
        if obs.enabled():
            obs.registry.inc("client_failures")
            obs.record("failure", phase="failure", trial=self.trace_label,
                       virtual=(ev.time - fl.comp_time - fl.trans_time,
                                ev.time),
                       cid=fl.client_id, attempt=fl.attempt)
        if fl.attempt < self.rt.max_retries:
            backoff = self.rt.retry_backoff * (fl.comp_time + fl.trans_time)
            if obs.enabled():
                obs.registry.inc("retries_scheduled")
                obs.record("retry", phase="failure", trial=self.trace_label,
                           virtual=(ev.time, ev.time + backoff),
                           cid=fl.client_id, attempt=fl.attempt + 1)
            self.dispatch_event(st, fl.client_id, ev.time + backoff,
                                queue, attempt=fl.attempt + 1)

    def fill_event_concurrency(self, st: EventLoopState, now: float,
                               queue=None):
        """Top up in-flight clients to M.  The selector is asked for a
        cohort large enough to survive the in-flight exclusion, so
        deterministic rankers (deadline/guided/smallest) hand out their
        next-best candidates instead of re-proposing the one client
        already dispatched (which would collapse concurrency to 1)."""
        queue = self.queue if queue is None else queue
        srv = self.srv
        target = min(st.hp.m, srv.dataset.n_clients)
        if obs.enabled() and self.fleet.churn is not None:
            obs.registry.sample("fleet_size", self.fleet.n_active(now))
        for _ in range(5):               # availability retry passes
            need = target - len(st.inflight)
            if need <= 0:
                return
            k = min(srv.dataset.n_clients, need + len(st.inflight))
            candidates = [int(c) for c in srv.selector.select(k)
                          if int(c) not in st.inflight]
            for cid in candidates:
                if len(st.inflight) >= target:
                    return
                # churn membership first — an absent client consumes no
                # availability draw, keeping the rng stream churn-free
                if not self._is_active(cid, now):
                    continue
                if self._available(cid):
                    self.dispatch_event(st, cid, now, queue)
        # deadlock guard: nothing in flight and nothing queued means the
        # simulation would halt — model a persistent retry succeeding
        if not st.inflight and not queue:
            cohort = [int(c) for c in srv.selector.select(1)]
            if cohort:
                self.dispatch_event(st, cohort[0], now, queue)

    @obs.traced("plan_event", phase="plan")
    def plan_event(self, st: EventLoopState, ev) -> Optional[_InFlight]:
        """Process one popped event's host-side half: retire its in-flight
        record and charge the traffic/compute loads (download always
        happened; compute too — a dropout dies on the way back up, AFTER
        the work was spent).  Returns the in-flight record whose client
        must now train, or None for a dropout (caller refills concurrency
        and moves on).  The caller advances the clock to ``ev.time`` first."""
        fl = st.inflight.pop(ev.client_id)
        if obs.enabled():
            obs.record("inflight", phase="inflight", trial=self.trace_label,
                       virtual=(ev.time - fl.comp_time - fl.trans_time,
                                ev.time),
                       cid=fl.client_id,
                       kind="dropout" if ev.kind == DROPOUT else "arrival")
            if ev.kind == DROPOUT:
                obs.registry.inc("event_dropouts")
        st.pend_comp_load += self._c1 * fl.e * fl.n_examples
        st.pend_trans_load += self._down
        if ev.kind == DROPOUT:
            return None
        st.pend_trans_load += self._up
        st.pend_comp.append(fl.comp_time)
        st.pend_trans.append(fl.trans_time)
        return fl

    @obs.traced("apply_event", phase="apply")
    def apply_event(self, st: EventLoopState, fl: _InFlight,
                    client_params) -> Tuple[bool, int]:
        """Fold one trained arrival into the global model: FedAsync
        staleness-discounted mixing (async — always aggregates) or a
        FedBuff delta-buffer add, flushing through the ``fed_aggregate``
        kernel when K deltas accumulated.  ``client_params`` must be the
        client's locally trained params starting from its dispatch snapshot
        ``fl.params``.  Returns (aggregated, staleness)."""
        rt = self.rt
        staleness = st.version - fl.version
        st.staleness_log.append(int(staleness))
        if obs.enabled():
            obs.registry.observe("staleness", staleness)
        if rt.mode == "async":
            st.params = apply_async_update(
                st.params, client_params, mix=rt.async_mix,
                staleness=staleness, alpha=rt.staleness_alpha,
                kind=rt.staleness_kind)
            return True, staleness
        # buffered
        delta = jax.tree.map(lambda a, b: a - b, client_params, fl.params)  # noqa: REPRO001 -- independent and vectorized engines both run this exact eager op (runner replays apply_event); jitting it would change FMA contraction vs the pinned parity
        st.buffer.add(delta, staleness)
        if st.buffer.full:
            st.params = st.buffer.flush(st.params)
            return True, staleness
        return False, staleness

    @obs.traced("account_event_round", phase="account")
    def account_event_round(self, st: EventLoopState):
        """Charge one aggregation window to the cost model: the virtual
        clock advance since the last aggregation, split by the contributing
        arrivals' own compute/transfer ratio (exact in the one-arrival
        case), plus the exact load sums.  Resets the pending accumulators."""
        dt = self.clock.now - st.last_agg_clock
        csum, tsum = sum(st.pend_comp), sum(st.pend_trans)
        frac = csum / (csum + tsum) if (csum + tsum) > 0 else 0.0
        round_cost = self.srv.cost_model.add_timed_round(
            comp_time=dt * frac, trans_time=dt * (1.0 - frac),
            comp_load=st.pend_comp_load, trans_load=st.pend_trans_load)
        st.pend_comp, st.pend_trans = [], []
        st.pend_comp_load = st.pend_trans_load = 0.0
        st.last_agg_clock = self.clock.now
        return round_cost

    @obs.traced("finish_event_round", phase="finish")
    def finish_event_round(self, st: EventLoopState, staleness: int,
                           wall: float, accuracy: Optional[float] = None):
        """Complete one aggregation: bump the model version, account the
        window, evaluate on schedule, record history, and step the FedTune
        controller — or set ``st.reached`` and stop if the target accuracy
        was hit (the controller does NOT step on the final round).

        ``accuracy`` is the eval hook for the vectorized sweep runner: it
        evaluates every aggregating trial's params in ONE stacked dispatch
        (federated/evaluation.py) and hands each trial its lane's result
        here — bit-identical to the single-trial eval this method would
        otherwise run on schedule."""
        srv, cfg, rt = self.srv, self.srv.config, self.rt
        st.version += 1
        r = len(st.history)
        if obs.enabled():
            obs.record("agg_window", phase="round", trial=self.trace_label,
                       round_idx=r,
                       virtual=(st.last_agg_clock, self.clock.now),
                       staleness=int(staleness))
            obs.counter("t_sim", self.clock.now)
        round_cost = self.account_event_round(st)
        if accuracy is not None:
            st.accuracy = accuracy
        elif eval_due(r, cfg.eval_every, cfg.max_rounds):
            st.accuracy = srv._evaluate(st.params)
        st.history.append(RoundRecord(
            r, st.hp.m, st.hp.e, st.accuracy, round_cost, wall,
            sim_time=self.clock.now,
            n_updates=(1 if rt.mode == "async" else rt.buffer_k)))
        if cfg.log_every and (r + 1) % cfg.log_every == 0:
            print(f"  agg {r+1:4d}  acc={st.accuracy:.4f}  M={st.hp.m} "
                  f"E={st.hp.e:g}  stale={staleness} "
                  f"t_sim={self.clock.now:.3g}", flush=True)
        if st.accuracy >= cfg.target_accuracy:
            st.reached = True
            return
        st.hp = srv.tuner.on_round(r, st.accuracy, round_cost,
                                   srv.cost_model.total, st.hp)
        st.hp = st.hp.clamped(srv.dataset.n_clients, 100.0)

    @obs.traced("account_event_tail", phase="account")
    def account_event_tail(self, st: EventLoopState):
        """Arrivals after the last aggregation (including a partially
        filled FedBuff buffer) did real downloads and compute the clock
        charged for — account their window's loads even though no further
        flush happens."""
        if st.pend_comp_load > 0.0 or st.pend_trans_load > 0.0:
            self.account_event_round(st)

    def event_result(self, st: EventLoopState) -> FLResult:
        """Package a finished event-loop state (standalone or merged)."""
        return FLResult(
            reached_target=st.reached, rounds=len(st.history),
            final_accuracy=st.accuracy,
            total_cost=self.srv.cost_model.total.copy(), history=st.history,
            final_m=st.hp.m, final_e=st.hp.e, params=st.params,
            sim_time=self.clock.now, dispatch_log=st.dispatch_log,
            staleness_log=st.staleness_log)

    def _run_event_loop(self, params) -> FLResult:
        srv, cfg = self.srv, self.srv.config
        st = self.init_event_state(params)
        last_wall = time.perf_counter()  # noqa: REPRO004 -- per-round wall info field; event ordering uses the virtual clock

        while self.queue and len(st.history) < cfg.max_rounds \
                and not st.reached:
            ev = self.queue.pop()
            self.clock.advance_to(ev.time)
            if ev.kind == FAILURE:           # hard failure: retry, refill
                self.handle_failure(st, ev)
                self.fill_event_concurrency(st, self.clock.now)
                continue
            fl = self.plan_event(st, ev)
            if fl is None:                   # dropout: refill and move on
                self.fill_event_concurrency(st, self.clock.now)
                continue
            upd, _n = srv._client_update(fl.params, fl.client_id, fl.e)
            aggregated, staleness = self.apply_event(st, fl, upd.params)
            if aggregated:
                now_wall = time.perf_counter()  # noqa: REPRO004 -- per-round wall info field; event ordering uses the virtual clock
                self.finish_event_round(st, staleness, now_wall - last_wall)
                last_wall = now_wall
                if st.reached:
                    break
            self.fill_event_concurrency(st, self.clock.now)

        self.account_event_tail(st)
        return self.event_result(st)
