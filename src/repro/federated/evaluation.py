"""Evaluation subsystem: jitted accuracy kernels, per-dataset staged test
batches, and stacked multi-trial evaluation.

Evaluation used to live inline on ``FLServer`` (``_evaluate`` plus a
module-level FIFO cache of jitted eval fns).  After the sweep engines
vectorized *training* (trials as vmap lanes), the per-aggregation
evaluation became the dominant cost of vectorized sweeps: T live trials
meant T separate eval dispatches per round even though they share one
model architecture and (per seed) one test set.  This module makes the
trial boundary explicit:

  ``Evaluator``        — one trial's evaluation: a jitted accuracy kernel
                         (shared through a bounded LRU so the T servers of
                         a sweep compile it once) over test batches staged
                         on device once per (dataset, eval_points).
  ``StackedEvaluator`` — T trials' params stacked into one pytree and
                         evaluated by ``jit(vmap(accuracy))`` over the SAME
                         staged batches: one dispatch per test batch
                         evaluates every trial.
  ``evaluate_stacked`` — the grouping entry point the sweep engines call:
                         items grouped by (model, dataset, eval_points),
                         one stacked dispatch per group.

Parity contract (pinned in tests/test_experiments.py): lane i of a stacked
evaluation is BIT-identical to ``Evaluator.evaluate`` on that trial's
params — vmap lanes are computed independently, and the host-side
accumulation (``correct += float(acc) * n`` per batch) is the same float
sequence.  This is what lets the vectorized sweep engines route their
per-aggregation evals through one dispatch while staying bit-identical to
standalone ``FLServer.run()`` calls.

With a multi-device mesh (the sweep's ``--pack sharded``), the stacked
params' trial axis can be laid over the mesh's ``clients`` axis
(``mesh=``): lanes are padded to a multiple of the device count and each
device evaluates its slice of the trials.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs, perf

EVAL_BATCH = 256               # test batch staging granularity (bounds memory)


class EvalFnCache:
    """Bounded LRU of jitted accuracy kernels, keyed per model object and
    variant (single vs stacked).

    Replaces the module-level FIFO dict that used to live in
    federated/server.py: entries move to the back on every hit, so the
    models of a live sweep cannot be evicted mid-sweep by a burst of
    one-shot constructions the way FIFO order allowed.  The cached closure
    keeps ``model`` alive, so an ``id()`` key cannot be recycled while its
    entry exists; the bound keeps a long-lived process looping over fresh
    models from pinning them all forever.
    """

    def __init__(self, capacity: int = 32):
        if capacity < 1:
            raise ValueError(f"EvalFnCache capacity must be >= 1, "
                             f"got {capacity}")
        self.capacity = capacity
        self._fns: "OrderedDict[tuple, Any]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._fns)

    def get(self, model, stacked: bool = False):
        """The jitted accuracy kernel for ``model``: ``(params, x, y) ->
        scalar accuracy`` (or, stacked, ``(T-stacked params, x, y) -> (T,)
        accuracies via vmap lane per trial)."""
        key = (id(model), stacked)
        fn = self._fns.get(key)
        if fn is not None:
            self._fns.move_to_end(key)
            if obs.enabled():
                obs.registry.inc("eval_fn_cache_hits")
            return fn
        if obs.enabled():
            obs.registry.inc("eval_fn_cache_misses")

        def accuracy(params, x, y):
            logits = model.forward(params, x)
            return (logits.argmax(-1) == y).mean()

        fn = (jax.jit(jax.vmap(accuracy, in_axes=(0, None, None)))
              if stacked else jax.jit(accuracy))
        while len(self._fns) >= self.capacity:
            self._fns.popitem(last=False)
        self._fns[key] = fn
        return fn


_SHARED_FN_CACHE = EvalFnCache()

# staged test batches, shared across every Evaluator over one dataset: the
# test set never changes across rounds OR trials, so it goes to the device
# once per (dataset, eval_points) instead of once per server.  Entries pin
# the dataset object so the id() key cannot be recycled while they live.
_BATCH_CACHE_MAX = 16
_batch_cache: "OrderedDict[tuple, tuple]" = OrderedDict()


def staged_batches(dataset, eval_points: int,
                   batch_size: int = EVAL_BATCH) -> List[tuple]:
    """The dataset's test set as a list of on-device ``(x, y, n)`` batches,
    staged once per (dataset, eval_points) and shared by every evaluator."""
    key = (id(dataset), eval_points, batch_size)
    hit = _batch_cache.get(key)
    if hit is not None:
        _batch_cache.move_to_end(key)
        if obs.enabled():
            obs.registry.inc("eval_batch_cache_hits")
        return hit[1]
    if obs.enabled():
        obs.registry.inc("eval_batch_cache_misses")
    x, y = dataset.test_data(eval_points)
    batches = [
        (jnp.asarray(x[i:i + batch_size]), jnp.asarray(y[i:i + batch_size]),
         len(y[i:i + batch_size])) for i in range(0, len(y), batch_size)]
    while len(_batch_cache) >= _BATCH_CACHE_MAX:
        _batch_cache.popitem(last=False)
    _batch_cache[key] = (dataset, batches)
    return batches


def eval_due(round_idx: int, eval_every: int, max_rounds: int) -> bool:
    """The shared evaluation schedule: every ``eval_every`` rounds and on
    the final round of the budget.  One definition for the legacy loop,
    the runtime engine, and the sweep engines — the schedule is part of
    the bit-parity contract."""
    return (round_idx + 1) % eval_every == 0 or round_idx == max_rounds - 1


def _tree_stack(trees: Sequence[Any]):
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *trees)


class Evaluator:
    """One trial's evaluation: jitted accuracy kernel + staged test batches.

    ``fn_cache`` defaults to the process-wide shared LRU so the T servers
    of a sweep (or repeated benchmark constructions over one model) share
    a single compilation; tests inject a tiny cache to pin eviction
    behavior."""

    def __init__(self, model, dataset, eval_points: int,
                 fn_cache: Optional[EvalFnCache] = None):
        self.model = model
        self.dataset = dataset
        self.eval_points = eval_points
        self.fn_cache = fn_cache if fn_cache is not None else _SHARED_FN_CACHE

    def evaluate(self, params) -> float:
        """Accuracy of ``params`` over the staged test batches."""
        fn = self.fn_cache.get(self.model)
        correct, total = 0.0, 0
        with perf.timed("eval"), obs.span("eval", phase="eval", n_lanes=1):
            for bx, by, n in staged_batches(self.dataset, self.eval_points):
                correct += float(fn(params, bx, by)) * n
                total += n
        return correct / total


class StackedEvaluator:
    """T trials' evaluation as one workload: a T-stacked params pytree
    through ``jit(vmap(accuracy))`` over the shared staged batches — one
    dispatch per test batch instead of one per (trial, batch).

    Lane i is bit-identical to ``Evaluator.evaluate(params_list[i])``:
    vmap lanes are independent and the per-batch host accumulation is the
    same float sequence."""

    def __init__(self, model, dataset, eval_points: int,
                 fn_cache: Optional[EvalFnCache] = None):
        self.model = model
        self.dataset = dataset
        self.eval_points = eval_points
        self.fn_cache = fn_cache if fn_cache is not None else _SHARED_FN_CACHE

    def evaluate(self, params_list: Sequence[Any],
                 mesh=None, pad_to: Optional[int] = None) -> List[float]:
        """Per-trial accuracies for a list of params pytrees.  With
        ``mesh``, the trial axis is laid over the mesh's first axis
        (lanes padded to a multiple of the device count).  ``pad_to``
        pads the lane axis up to a caller-chosen width first (extra lanes
        repeat lane 0 and are discarded) — the sweep engines key it off
        the live-lane mask (pow2 of the due count) so the compiled
        stacked shape stays stable as trials retire and fresh ones are
        admitted mid-flight, instead of recompiling for every distinct
        live count.  Padding is bit-parity-safe: vmap lanes are
        independent, so lane i never sees the padding."""
        t = len(params_list)
        if t == 0:
            return []
        if t == 1:
            # a singleton group gains nothing from the stacked variant;
            # route it through the single-trial kernel (bit-identical)
            return [Evaluator(self.model, self.dataset, self.eval_points,
                              self.fn_cache).evaluate(params_list[0])]
        stacked_list = list(params_list)
        if pad_to is not None and pad_to > t:
            stacked_list = stacked_list + [stacked_list[0]] * (pad_to - t)
        if mesh is not None:
            n_dev = int(np.prod(mesh.devices.shape))
            pad = (-len(stacked_list)) % n_dev
            stacked_list = stacked_list + [stacked_list[0]] * pad
        stacked = _tree_stack(stacked_list)
        if mesh is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P
            stacked = jax.device_put(
                stacked, NamedSharding(mesh, P(mesh.axis_names[0])))
        fn = self.fn_cache.get(self.model, stacked=True)
        correct = [0.0] * t
        total = 0
        with perf.timed("eval"), obs.span("eval_stacked", phase="eval",
                                          n_lanes=t):
            for bx, by, n in staged_batches(self.dataset, self.eval_points):
                accs = np.asarray(fn(stacked, bx, by))
                for i in range(t):
                    correct[i] += float(accs[i]) * n
                total += n
        return [c / total for c in correct]


def _pow2_lanes(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def evaluate_stacked(items: Sequence[Tuple[Any, Any, int, Any]],
                     mesh=None, pad_pow2: bool = False) -> List[float]:
    """Batch-evaluate many trials: ``items`` holds one ``(model, dataset,
    eval_points, params)`` per trial; trials sharing a (model, dataset,
    eval_points) group execute as ONE stacked dispatch per test batch.
    Returns accuracies in item order.

    ``pad_pow2`` pads each group's lane axis to a pow2 of its LIVE size
    (parity-safe — see ``StackedEvaluator.evaluate``), bounding the set
    of compiled stacked shapes as a draining or continuously-batched
    pool's due count churns."""
    groups: Dict[tuple, List[int]] = {}
    for i, (model, dataset, eval_points, _params) in enumerate(items):
        groups.setdefault((id(model), id(dataset), eval_points),
                          []).append(i)
    out: List[float] = [0.0] * len(items)
    for idx in groups.values():
        model, dataset, eval_points, _ = items[idx[0]]
        pad_to = _pow2_lanes(len(idx)) if pad_pow2 else None
        accs = StackedEvaluator(model, dataset, eval_points).evaluate(
            [items[i][3] for i in idx], mesh=mesh, pad_to=pad_to)
        for i, acc in zip(idx, accs):
            out[i] = acc
    return out
