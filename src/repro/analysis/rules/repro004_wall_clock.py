"""REPRO004 — wall clock or host randomness inside virtual-clock code.

The simulator's clock is the event queue's virtual time and its only
legal stochasticity flows from seeded generators (``server rng`` /
``system_seed``).  ``time.*`` reads, ``datetime.now``, the global
``random`` module, unseeded ``np.random``, ``os.urandom`` and
``secrets`` all smuggle host nondeterminism into results — or worse,
into event ordering.  Allowlisted by design: the ``obs/`` tracer and
``perf`` shim (they *measure* wall time, that's their job) and the
store's write-latency metric (``experiments/store.py``, explicitly
carved out by the rule spec).  Wall-time measurements that feed purely
informational fields (e.g. a RoundRecord's ``wall``) stay in scope and
carry per-site justifications instead, so every exemption is visible.
"""

from __future__ import annotations

import ast

from ..core import FileContext, Rule, register
from ..scopes import dotted_parts

SCOPED_DIRS = {"runtime", "experiments", "federated", "core"}
ALLOWLIST_SUFFIXES = (
    "obs",                       # directory: the wall-clock tracer itself
)
ALLOWLIST_FILES = {
    "perf.py",                   # wall-clock phase counters by contract
    "experiments/store.py",      # store_write_s latency metric
}

TIME_FUNCS = {"time", "perf_counter", "perf_counter_ns", "monotonic",
              "monotonic_ns", "process_time", "time_ns", "sleep"}
DATETIME_NOW = {"now", "utcnow", "today"}
RANDOM_MODULE_FUNCS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "normalvariate", "gauss", "seed", "getrandbits",
}


@register
class WallClockInVirtualTime(Rule):
    id = "REPRO004"
    name = "wall-clock-or-host-randomness"

    def _allowlisted(self, rel: str) -> bool:
        parts = rel.split("/")
        if any(p in ALLOWLIST_SUFFIXES for p in parts):
            return True
        return any(rel.endswith(f) for f in ALLOWLIST_FILES)

    def check_file(self, ctx: FileContext):
        parts = set(ctx.rel.split("/"))
        if not parts & SCOPED_DIRS:
            return
        if self._allowlisted(ctx.rel):
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                self._check_call(ctx, node)

    def _check_call(self, ctx: FileContext, node: ast.Call):
        chain = dotted_parts(node.func)
        if not chain:
            return
        base, last = chain[0], chain[-1]
        if base == "time" and last in TIME_FUNCS:
            ctx.add(node, self.id,
                    f"wall-clock call `{'.'.join(chain)}` in virtual-clock "
                    "code — results must depend only on the event queue's "
                    "virtual time (or justify-suppress for informational "
                    "wall fields)")
        elif base == "datetime" and last in DATETIME_NOW:
            ctx.add(node, self.id,
                    f"wall-clock call `{'.'.join(chain)}` in virtual-clock "
                    "code — results must depend only on virtual time")
        elif base == "random" and last in RANDOM_MODULE_FUNCS \
                and len(chain) == 2:
            ctx.add(node, self.id,
                    f"global `random.{last}` is host randomness — draw "
                    "from a seeded np.random.Generator owned by the "
                    "server/system instead")
        elif base in {"np", "numpy"} and len(chain) >= 2 \
                and chain[1] == "random":
            if last == "default_rng":
                if not node.args and not node.keywords:
                    ctx.add(node, self.id,
                            "`np.random.default_rng()` without a seed is "
                            "host randomness — thread a seed from the "
                            "trial/system config")
            else:
                ctx.add(node, self.id,
                        f"global `np.random.{last}` draws from unseeded "
                        "process state — use a seeded Generator instead")
        elif base == "os" and last == "urandom":
            ctx.add(node, self.id,
                    "`os.urandom` is host randomness — virtual-clock code "
                    "must derive all stochasticity from seeds")
        elif base == "secrets":
            ctx.add(node, self.id,
                    f"`secrets.{last}` is host randomness — virtual-clock "
                    "code must derive all stochasticity from seeds")
        elif base == "uuid" and last == "uuid4":
            ctx.add(node, self.id,
                    "`uuid.uuid4` is host randomness — derive ids from "
                    "trial keys or seeded generators")
