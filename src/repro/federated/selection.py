"""Participant selection strategies (beyond-paper; paper §6 'Extensions').

  random  — the paper's setting (uniform without replacement).
  guided  — Oort-lite utility selection: utility_k = last_loss_k * sqrt(n_k)
            with epsilon-greedy exploration.  Clients that hurt the model
            most (high loss) and carry more data are preferred.
  smallest— deadline-style: prefer clients with the least data (bounds the
            straggler term max_k n_k in CompT, eq. 2).
  deadline— heterogeneity-aware: prefer clients with the smallest *expected
            round time* (data size / device speed, runtime fleet profile),
            with epsilon-greedy exploration so slow clients still
            contribute occasionally (avoids fast-device bias).
"""

from __future__ import annotations

from typing import Dict

import numpy as np


class Selector:
    name = "random"

    def __init__(self, n_clients: int, rng: np.random.Generator):
        self.n_clients = n_clients
        self.rng = rng

    def select(self, m: int) -> np.ndarray:
        return self.rng.choice(self.n_clients, size=m, replace=False)

    def update(self, client_id: int, loss: float, n_examples: int):
        pass


class GuidedSelector(Selector):
    name = "guided"

    def __init__(self, n_clients: int, rng: np.random.Generator,
                 epsilon: float = 0.2):
        super().__init__(n_clients, rng)
        self.epsilon = epsilon
        self.utility = np.full(n_clients, np.inf)  # unexplored = max utility

    def select(self, m: int) -> np.ndarray:
        m = min(m, self.n_clients)
        n_explore = int(round(self.epsilon * m))
        n_exploit = m - n_explore
        order = np.argsort(-np.nan_to_num(self.utility, posinf=1e30))
        exploit = order[:n_exploit]
        rest = np.setdiff1d(np.arange(self.n_clients), exploit)
        explore = self.rng.choice(rest, size=min(n_explore, len(rest)),
                                  replace=False)
        return np.concatenate([exploit, explore]).astype(np.int64)

    def update(self, client_id: int, loss: float, n_examples: int):
        self.utility[client_id] = float(loss) * np.sqrt(max(n_examples, 1))


class SmallestFirstSelector(Selector):
    name = "smallest"

    def __init__(self, n_clients: int, rng: np.random.Generator,
                 client_sizes=None):
        super().__init__(n_clients, rng)
        self.sizes = np.asarray(client_sizes)

    def select(self, m: int) -> np.ndarray:
        m = min(m, self.n_clients)
        # jitter to avoid always picking the identical smallest set
        noisy = self.sizes + self.rng.uniform(0, 1, self.n_clients)
        return np.argsort(noisy)[:m]


class DeadlineAwareSelector(Selector):
    """Ranks clients by expected dispatch->arrival time under the runtime's
    device fleet; an epsilon fraction of each cohort is still drawn uniformly
    from the remainder so stragglers are not starved of participation."""
    name = "deadline"

    def __init__(self, n_clients: int, rng: np.random.Generator,
                 est_times, epsilon: float = 0.1):
        super().__init__(n_clients, rng)
        self.est_times = np.asarray(est_times, np.float64)
        self.epsilon = epsilon

    def select(self, m: int) -> np.ndarray:
        m = min(m, self.n_clients)
        n_explore = int(round(self.epsilon * m))
        n_fast = m - n_explore
        # jitter breaks ties between identical devices
        noisy = self.est_times * (1.0 + self.rng.uniform(
            0, 1e-6, self.n_clients))
        fast = np.argsort(noisy)[:n_fast]
        rest = np.setdiff1d(np.arange(self.n_clients), fast)
        explore = self.rng.choice(rest, size=min(n_explore, len(rest)),
                                  replace=False)
        return np.concatenate([fast, explore]).astype(np.int64)


def get_selector(name: str, n_clients: int, rng: np.random.Generator,
                 client_sizes=None, est_times=None) -> Selector:
    if name == "random":
        return Selector(n_clients, rng)
    if name == "guided":
        return GuidedSelector(n_clients, rng)
    if name == "smallest":
        return SmallestFirstSelector(n_clients, rng, client_sizes)
    if name == "deadline":
        if est_times is None:
            if client_sizes is None:
                raise ValueError(
                    "deadline selection needs est_times (from a runtime "
                    "fleet) or client_sizes as a completion-time proxy")
            # no fleet wired in: every client looks equally fast, fall back
            # to data size as the completion-time proxy
            est_times = np.asarray(client_sizes, np.float64)
        return DeadlineAwareSelector(n_clients, rng, est_times)
    raise KeyError(name)
