"""Parity tests for the clients-as-mesh-axis sharded execution path
(runtime/sharded.py), pinned against the batched path the same way
tests/test_runtime.py pins batched-vs-sequential.

The multi-device cases need >1 XLA device; CI's multi-device job provides
a 4-device CPU mesh via

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m pytest -x -q tests/test_sharded.py

On a single device those cases skip and the engine-level tests verify the
transparent sharded -> batched fallback instead."""

import jax
import numpy as np
import pytest

from repro.configs.paper_models import MLPConfig
from repro.core import CostModel
from repro.data.synthetic import DataSpec, make_dataset
from repro.federated import FLConfig, FLServer, get_aggregator
from repro.models import build_model
from repro.optim.optimizers import get_optimizer
from repro.runtime import (RuntimeConfig, batched_local_train,
                           sharded_fedavg_train)
from repro.runtime.engine import EventDrivenRuntime

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs a multi-device mesh (XLA_FLAGS="
           "--xla_force_host_platform_device_count=4)")


def small_dataset(seed=1):
    return make_dataset(DataSpec(
        name="shard_test", n_classes=4, shape=(12,), n_train_clients=24,
        n_test_clients=8, size_log_mean=2.5, size_log_std=0.5, seed=seed))


def mk_server(*, rt=None, max_rounds=4, m=5, e=2.0, aggregator="fedavg",
              compression=None):
    ds = small_dataset()
    model = build_model(MLPConfig(name="mlp_shard", in_dim=12, hidden=(16,),
                                  n_classes=4))
    n_params = sum(p.size for p in jax.tree.leaves(
        model.init(jax.random.PRNGKey(0))))
    return FLServer(
        model, ds, get_aggregator(aggregator),
        get_optimizer("sgd", 0.05, momentum=0.9),
        CostModel(flops_per_example=2 * n_params, param_count=n_params),
        FLConfig(m=m, e=e, batch_size=4, target_accuracy=0.99,
                 max_rounds=max_rounds, eval_points=128,
                 compression=compression),
        runtime_config=rt)


def tree_close(a, b, atol):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=atol)


# ---------------------------------------------------------------------------
# update-for-update parity with the batched path
# ---------------------------------------------------------------------------

@multidevice
def test_sharded_matches_batched_fedavg_aggregate():
    """The on-device psum weighted mean == FedAvg over the batched path's
    per-client params, same rng, up to float reassociation."""
    srv = mk_server()
    params = srv.model.init(jax.random.PRNGKey(0))
    cids = [0, 3, 7, 11, 15, 16, 20]   # 7 clients: not a multiple of D
    data = [srv.dataset.client_data(c) for c in cids]
    bat = batched_local_train(srv.model, params, data, passes=2.0,
                              batch_size=4, optimizer=srv.optimizer,
                              rng=np.random.default_rng(42),
                              client_ids=cids)
    expected = get_aggregator("fedavg")(params, bat)
    res = sharded_fedavg_train(srv.model, params, data, passes=2.0,
                               batch_size=4, optimizer=srv.optimizer,
                               rng=np.random.default_rng(42))
    assert res.n_steps == [u.n_steps for u in bat]
    assert res.n_examples == [u.n_examples for u in bat]
    np.testing.assert_allclose(res.last_losses,
                               [u.last_loss for u in bat], rtol=1e-4)
    tree_close(expected, res.params, atol=1e-5)


@multidevice
def test_sharded_fedprox_parity():
    srv = mk_server()
    params = srv.model.init(jax.random.PRNGKey(0))
    data = [srv.dataset.client_data(c) for c in (2, 5, 9)]
    bat = batched_local_train(srv.model, params, data, passes=1.0,
                              batch_size=4, optimizer=srv.optimizer,
                              rng=np.random.default_rng(9), prox_mu=0.1)
    expected = get_aggregator("fedavg")(params, bat)
    res = sharded_fedavg_train(srv.model, params, data, passes=1.0,
                               batch_size=4, optimizer=srv.optimizer,
                               rng=np.random.default_rng(9), prox_mu=0.1)
    tree_close(expected, res.params, atol=1e-5)


@multidevice
def test_sharded_zero_step_client_enters_mean_at_global():
    """A client whose fractional pass rounds to zero steps contributes its
    weight at the global params, matching the batched/sequential paths."""
    srv = mk_server()
    params = srv.model.init(jax.random.PRNGKey(0))
    rngd = np.random.default_rng(0)
    data = [(rngd.normal(size=(12, 12)).astype(np.float32),
             rngd.integers(0, 4, 12).astype(np.int32)),
            (rngd.normal(size=(1, 12)).astype(np.float32),
             rngd.integers(0, 4, 1).astype(np.int32))]   # round(0.4*1) == 0
    bat = batched_local_train(srv.model, params, data, passes=0.4,
                              batch_size=4, optimizer=srv.optimizer,
                              rng=np.random.default_rng(7))
    expected = get_aggregator("fedavg")(params, bat)
    res = sharded_fedavg_train(srv.model, params, data, passes=0.4,
                               batch_size=4, optimizer=srv.optimizer,
                               rng=np.random.default_rng(7))
    assert res.n_steps[1] == 0
    tree_close(expected, res.params, atol=1e-5)


# ---------------------------------------------------------------------------
# engine integration: third client-execution mode
# ---------------------------------------------------------------------------

@multidevice
def test_sharded_sync_runtime_matches_batched_sync():
    bat = mk_server(rt=RuntimeConfig(mode="sync",
                                     client_exec="batched")).run()
    shd = mk_server(rt=RuntimeConfig(mode="sync",
                                     client_exec="sharded")).run()
    np.testing.assert_allclose([h.accuracy for h in bat.history],
                               [h.accuracy for h in shd.history], atol=1e-5)
    np.testing.assert_allclose(np.array(bat.total_cost.as_tuple()),
                               np.array(shd.total_cost.as_tuple()),
                               rtol=1e-9)
    tree_close(bat.params, shd.params, atol=1e-4)


@multidevice
def test_sharded_compressed_matches_batched():
    """The per-lane upload round trip runs inside the shard_map body,
    before the fused aggregation — compressed sharded rounds agree with
    compressed batched rounds (up to the usual float reassociation)."""
    seq = mk_server(rt=RuntimeConfig(mode="sync", client_exec="batched"),
                    compression="int8").run()
    shd_srv = mk_server(rt=RuntimeConfig(mode="sync", client_exec="sharded"),
                        compression="int8")
    eng = EventDrivenRuntime(shd_srv, config=shd_srv.runtime_config)
    assert eng.client_exec == "sharded"
    shd = shd_srv.run()
    np.testing.assert_allclose([h.accuracy for h in seq.history],
                               [h.accuracy for h in shd.history], atol=1e-3)
    np.testing.assert_allclose(np.array(seq.total_cost.as_tuple()),
                               np.array(shd.total_cost.as_tuple()),
                               rtol=1e-6)


def test_client_exec_resolution_and_fallbacks():
    srv = mk_server(rt=RuntimeConfig(mode="sync", client_exec="sharded"))
    eng = EventDrivenRuntime(srv, config=srv.runtime_config)
    expected = "batched" if jax.device_count() == 1 else "sharded"
    assert eng.client_exec == expected

    # upload compression no longer forces a fallback: it runs as a lane
    # transform inside the batched/sharded cohorts
    srv = mk_server(rt=RuntimeConfig(mode="sync", client_exec="batched"),
                    compression="int8")
    eng = EventDrivenRuntime(srv, config=srv.runtime_config)
    assert eng.client_exec == "batched"

    # legacy boolean still selects the batched path
    srv = mk_server(rt=RuntimeConfig(mode="sync", batched=True))
    eng = EventDrivenRuntime(srv, config=srv.runtime_config)
    assert eng.client_exec == "batched"

    # non-sync modes always run the sequential client loop
    srv = mk_server(rt=RuntimeConfig(mode="async", client_exec="sharded"))
    eng = EventDrivenRuntime(srv, config=srv.runtime_config)
    assert eng.client_exec == "sequential"

    # non-FedAvg aggregation needs per-client updates
    srv = mk_server(rt=RuntimeConfig(mode="sync", client_exec="sharded"),
                    aggregator="fednova")
    eng = EventDrivenRuntime(srv, config=srv.runtime_config)
    assert eng.client_exec == "batched"

    with pytest.raises(ValueError, match="client_exec"):
        EventDrivenRuntime(mk_server(),
                           config=RuntimeConfig(client_exec="warp"))


def test_sharded_request_still_runs_on_any_device_count():
    """client_exec='sharded' must produce a working run everywhere: on one
    device it falls back to batched; on many it shards.  Either way the
    result matches the batched run exactly (up to float reassociation)."""
    ref = mk_server(rt=RuntimeConfig(mode="sync",
                                     client_exec="batched")).run()
    out = mk_server(rt=RuntimeConfig(mode="sync",
                                     client_exec="sharded")).run()
    np.testing.assert_allclose([h.accuracy for h in ref.history],
                               [h.accuracy for h in out.history], atol=1e-5)
    assert out.sim_time == ref.sim_time
