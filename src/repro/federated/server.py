"""FL server: round orchestration, participant selection, cost accounting,
evaluation, and the tuner hook (FedTune plugs in here).

This is the *simulation* loop used for the paper's experiments (small
models, CPU).  Since the event-driven runtime landed (repro.runtime), the
server is a thin facade: ``run()`` hands orchestration to the runtime engine
(sync / async / buffered execution over a device fleet), and the original
synchronous-homogeneous loop survives as ``run_legacy()`` — the runtime's
sync mode over a homogeneous fleet reproduces it round for round, which
``tests/test_runtime.py`` pins down.

The datacenter execution path — participants as mesh shards with psum
aggregation — lives in launch/train.py and is what the multi-pod dry-run
lowers.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costs import CostModel, SystemCost
from repro.core.tuner import HyperParams, Tuner
from repro.data.synthetic import FederatedDataset
from repro.federated.aggregation import Aggregator, ClientUpdate
from repro.federated.client import local_train
from repro.models.registry import Model
from repro.optim.optimizers import Optimizer


@dataclass
class FLConfig:
    m: int = 20                    # initial participants per round
    e: float = 20.0                # initial local passes
    batch_size: int = 5
    target_accuracy: float = 0.8
    max_rounds: int = 500
    eval_points: int = 1024
    prox_mu: float = 0.0
    seed: int = 0
    eval_every: int = 1
    log_every: int = 0             # 0 = silent
    selection: str = "random"      # random | guided | smallest | deadline
    compression: Optional[str] = None  # None | "int8" upload deltas


@dataclass
class RoundRecord:
    round_idx: int
    m: int
    e: float
    accuracy: float
    cost: SystemCost
    wall_time: float
    sim_time: float = 0.0          # virtual clock at the end of the round
    n_updates: int = -1            # arrivals aggregated (-1 = legacy loop)


@dataclass
class FLResult:
    reached_target: bool
    rounds: int
    final_accuracy: float
    total_cost: SystemCost
    history: List[RoundRecord]
    final_m: int
    final_e: float
    params: Any = None             # final global model parameters
    sim_time: float = 0.0          # total virtual wall-clock (runtime modes)
    dispatch_log: Optional[List[tuple]] = None   # async/buffered: every
                                   # dispatch as (virtual t, cid, version)
    staleness_log: Optional[List[int]] = None    # async/buffered: staleness
                                   # of each applied (non-dropout) arrival


_eval_fn_cache = {}
_EVAL_CACHE_MAX = 32


def _get_eval_fn(model: Model):
    """Jitted accuracy kernel, cached per model so the T servers of a sweep
    (or repeated benchmark constructions over one model) share a single
    compilation.  The cached closure keeps ``model`` alive, so the id key
    cannot be recycled while the entry exists; the cache is bounded (FIFO
    eviction) so a long-lived process looping over fresh models does not
    pin them all forever."""
    key = id(model)
    if key not in _eval_fn_cache:
        while len(_eval_fn_cache) >= _EVAL_CACHE_MAX:
            _eval_fn_cache.pop(next(iter(_eval_fn_cache)))

        @jax.jit
        def eval_fn(params, x, y):
            logits = model.forward(params, x)
            return (logits.argmax(-1) == y).mean()
        _eval_fn_cache[key] = eval_fn
    return _eval_fn_cache[key]


class FLServer:
    def __init__(self, model: Model, dataset: FederatedDataset,
                 aggregator: Aggregator, optimizer: Optimizer,
                 cost_model: CostModel, config: FLConfig,
                 tuner: Optional[Tuner] = None,
                 fleet=None, runtime_config=None):
        self.model = model
        self.dataset = dataset
        self.aggregator = aggregator
        self.optimizer = optimizer
        self.cost_model = cost_model
        self.config = config
        self.tuner = tuner or Tuner()
        self.rng = np.random.default_rng(config.seed)
        self._eval_fn = None
        self._eval_batches = None
        self.fleet = fleet
        self.runtime_config = runtime_config
        from repro.federated.selection import get_selector
        est_times = None
        if fleet is not None:
            # deadline-aware selection signal: expected dispatch->arrival
            # time per client (download + E passes of compute + upload)
            from repro.federated.compression import upload_factor
            c1 = cost_model.train_flops_per_example
            down, up = cost_model.traffic_halves(
                upload_factor(config.compression))
            est_times = np.array([
                fleet.est_round_time(k, float(dataset.client_sizes[k]),
                                     config.e, c1, down, up)
                for k in range(dataset.n_clients)])
        self.selector = get_selector(config.selection, dataset.n_clients,
                                     self.rng,
                                     client_sizes=dataset.client_sizes,
                                     est_times=est_times)

    # ------------------------------------------------------------------
    def _evaluate(self, params) -> float:
        if self._eval_fn is None:
            self._eval_fn = _get_eval_fn(self.model)
        if self._eval_batches is None:
            # the test set never changes across rounds: stage it on device
            # once (batched to bound memory) instead of re-uploading every
            # evaluation
            x, y = self.dataset.test_data(self.config.eval_points)
            bs = 256
            self._eval_batches = [
                (jnp.asarray(x[i:i + bs]), jnp.asarray(y[i:i + bs]),
                 len(y[i:i + bs])) for i in range(0, len(y), bs)]
        correct = 0.0
        total = 0
        for bx, by, n in self._eval_batches:
            correct += float(self._eval_fn(params, bx, by)) * n
            total += n
        return correct / total

    # ------------------------------------------------------------------
    def _client_update(self, params, cid: int, e: float
                       ) -> Tuple[ClientUpdate, int]:
        """Run one client's local training against ``params``.  Shared by the
        legacy loop and the event-driven runtime so both consume the server
        rng stream identically (batch permutations)."""
        cfg = self.config
        x, y = self.dataset.client_data(int(cid))
        upd = local_train(
            self.model, params, x, y, passes=e,
            batch_size=cfg.batch_size, optimizer=self.optimizer,
            rng=self.rng, prox_mu=cfg.prox_mu)
        if cfg.compression:
            from repro.federated.compression import compress_delta
            upd = upd._replace(params=compress_delta(
                params, upd.params, cfg.compression))
        upd = upd._replace(client_id=int(cid))
        self.selector.update(int(cid), upd.last_loss, len(y))
        return upd, len(y)

    # ------------------------------------------------------------------
    def run(self, params=None) -> FLResult:
        """Execute FL through the event-driven runtime.  Mode and fleet come
        from ``runtime_config`` / ``fleet`` (defaults: sync execution over a
        homogeneous unit fleet == the legacy loop's behavior)."""
        from repro.runtime.engine import EventDrivenRuntime, RuntimeConfig
        rt = EventDrivenRuntime(self, fleet=self.fleet,
                                config=self.runtime_config or RuntimeConfig())
        return rt.run(params)

    # ------------------------------------------------------------------
    def run_legacy(self, params=None) -> FLResult:
        """The original synchronous, homogeneous round loop (paper setting).
        Kept as the reference the runtime's sync mode is verified against."""
        cfg = self.config
        if params is None:
            params = self.model.init(jax.random.PRNGKey(cfg.seed))
        hp = HyperParams(m=cfg.m, e=cfg.e)
        history: List[RoundRecord] = []
        accuracy = 0.0
        reached = False

        for r in range(cfg.max_rounds):
            t0 = time.perf_counter()
            m = min(hp.m, self.dataset.n_clients)
            participants = self.selector.select(m)
            updates: List[ClientUpdate] = []
            examples = []
            for cid in participants:
                upd, n = self._client_update(params, int(cid), hp.e)
                updates.append(upd)
                examples.append(n)
            params = self.aggregator(params, updates)
            from repro.federated.compression import upload_factor
            round_cost = self.cost_model.add_round(
                examples, hp.e,
                upload_factor=upload_factor(cfg.compression))

            if (r + 1) % cfg.eval_every == 0 or r == cfg.max_rounds - 1:
                accuracy = self._evaluate(params)
            wall = time.perf_counter() - t0
            history.append(RoundRecord(r, hp.m, hp.e, accuracy,
                                       round_cost, wall))
            if cfg.log_every and (r + 1) % cfg.log_every == 0:
                print(f"  round {r+1:4d}  acc={accuracy:.4f}  M={hp.m} "
                      f"E={hp.e:g}  wall={wall:.2f}s", flush=True)
            if accuracy >= cfg.target_accuracy:
                reached = True
                break
            hp = self.tuner.on_round(r, accuracy, round_cost,
                                     self.cost_model.total, hp)
            hp = hp.clamped(self.dataset.n_clients, 100.0)

        return FLResult(
            reached_target=reached,
            rounds=len(history),
            final_accuracy=accuracy,
            total_cost=self.cost_model.total.copy(),
            history=history,
            final_m=hp.m,
            final_e=hp.e,
            params=params,
        )
