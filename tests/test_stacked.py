"""Scan-over-layers execution must match the unrolled reference exactly."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import lm as lm_mod
from repro.models import stacked as st

KEY = jax.random.PRNGKey(0)
ARCHS = ("gemma2-2b", "recurrentgemma-9b", "xlstm-350m", "dbrx-132b",
         "seamless-m4t-medium", "qwen2-7b", "internvl2-1b")


def _mk(arch, n_layers=4):
    cfg = reduced(get_config(arch), n_layers=n_layers)
    params = lm_mod.init_params(cfg, KEY)
    return cfg, params


@pytest.mark.parametrize("arch", ARCHS)
def test_stack_roundtrip(arch):
    cfg, params = _mk(arch)
    back = st.unstack_params(st.stack_params(params, cfg), cfg)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        assert (a == b).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_stacked_loss_matches_unrolled(arch):
    cfg, params = _mk(arch)
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
             "weight": jnp.linspace(0.5, 1.5, B)}
    if cfg.frontend is not None:
        batch["frontend"] = jax.random.normal(
            KEY, (B, cfg.frontend.seq_len, cfg.frontend.feature_dim))
    l1, m1 = lm_mod.loss_fn(params, cfg, batch)
    l2, m2 = st.loss_fn(st.stack_params(params, cfg), cfg, batch, remat=True)
    assert abs(float(l1 - l2)) < 5e-5
    assert abs(float(m1["acc"] - m2["acc"])) < 1e-6


@pytest.mark.parametrize("arch", ("gemma2-2b", "recurrentgemma-9b",
                                  "xlstm-350m", "dbrx-132b"))
def test_stacked_decode_matches_unrolled(arch):
    cfg, params = _mk(arch)
    pst = st.stack_params(params, cfg)
    B, S = 2, 12
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    cache = lm_mod.init_cache(cfg, B, max_len=S + 4)
    _, cache = lm_mod.prefill(params, cfg, tokens[:, :S - 1], cache,
                              use_kernel=False)
    ref, _ = lm_mod.decode_step(params, cfg, tokens[:, S - 1],
                                jnp.int32(S - 1), cache)
    got, _ = st.decode_step(pst, cfg, tokens[:, S - 1], jnp.int32(S - 1),
                            st.stack_cache(cache, cfg))
    assert float(jnp.abs(got - ref).max()) < 5e-4


def test_find_cycle_patterns():
    assert st.find_cycle(get_config("gemma2-2b")) == (2, 13, 0)
    assert st.find_cycle(get_config("recurrentgemma-9b")) == (3, 12, 2)
    assert st.find_cycle(get_config("xlstm-350m")) == (2, 12, 0)
    assert st.find_cycle(get_config("qwen2-7b")) == (1, 28, 0)
