"""Vectorized client execution: run a whole cohort's local training as ONE
compiled step sequence instead of a Python loop over clients.

The sequential path (federated/client.py) dispatches T_k jitted micro-steps
per client; at M >= 16 participants the Python/dispatch overhead dominates
CPU wall-clock.  Here the cohort is padded to a common step count T = max_k
T_k, client batches are stacked into (T, M, B, ...) arrays, and a single
``lax.scan`` over steps runs a ``vmap`` over clients inside — the per-step
matmuls become batched matmuls over the cohort, and the interpreter is out
of the loop.  Clients that run out of real batches keep computing on padding
but their params/optimizer state are frozen by a step mask, so results match
the sequential loop exactly (up to float reassociation).

Padding waste is bounded by SIZE BUCKETING: clients are grouped by their
step count rounded up to the next power of two and each bucket runs as its
own cohort, so a single data-rich straggler (lognormal client sizes have a
long tail) cannot force the whole cohort to its step count — within a
bucket, padding is at most 2x, and the pow2 rounding keeps the set of
compiled (T, M) shapes small across rounds.

Batch order per client comes from the same ``client_batches`` generator and
the same rng stream as the sequential path (streams are materialized in
client order BEFORE bucketing), so the two paths are update-for-update
comparable (tests/test_runtime.py pins the parity).
"""

from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.data.loader import client_batches
from repro.federated.aggregation import ClientUpdate
from repro.models.registry import Model
from repro.optim.optimizers import Optimizer

_batched_step_cache = {}


def make_client_step(model: Model, optimizer: Optimizer, prox_mu: float):
    """One micro-step of one client's local training (shared by the batched
    and sharded cohort paths): (params, opt_state, batch) -> updated state
    plus the step loss, with the FedProx proximal term folded in."""

    def loss(params, batch, global_params):
        l, metrics = model.loss_fn(params, batch)
        if prox_mu > 0.0:
            sq = sum(jnp.sum((a - b) ** 2) for a, b in zip(
                jax.tree.leaves(params), jax.tree.leaves(global_params)))
            l = l + 0.5 * prox_mu * sq
        return l, metrics

    def one_client(params, opt_state, bx, by, bm, global_params):
        batch = {"x": bx, "y": by, "mask": bm}
        (l, _), grads = jax.value_and_grad(loss, has_aux=True)(
            params, batch, global_params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        return params, opt_state, l

    return one_client


def cohort_scan(one_client, params_b, opt_b, xs, ys, masks, active,
                global_params, *, global_in_axis=None):
    """``lax.scan`` over steps with a ``vmap`` over clients inside — the
    cohort body shared by the batched (whole cohort on one device), sharded
    (per-shard slice of the cohort), and multi-trial sweep (clients of many
    trials packed flat) execution paths.

    xs: (T, M, B, ...); active: (T, M) bool step mask freezing clients
    that ran out of real batches.  ``global_in_axis`` is the vmap axis for
    ``global_params``: None (default) broadcasts one global model to every
    client; 0 gives each client its own reference params — what the sweep
    runner uses to pack clients of trials whose global models differ."""

    def scan_step(carry, inp):
        params_b, opt_b, last_loss = carry
        bx, by, bm, act = inp
        new_p, new_o, l = jax.vmap(
            one_client, in_axes=(0, 0, 0, 0, 0, global_in_axis))(
                params_b, opt_b, bx, by, bm, global_params)

        def keep(new, old):
            gate = act.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(gate, new, old)

        params_b = jax.tree.map(keep, new_p, params_b)
        opt_b = jax.tree.map(keep, new_o, opt_b)
        last_loss = jnp.where(act, l, last_loss)
        return (params_b, opt_b, last_loss), None

    m = active.shape[1]
    init = (params_b, opt_b, jnp.zeros((m,), jnp.float32))
    (params_b, opt_b, last_loss), _ = jax.lax.scan(
        scan_step, init, (xs, ys, masks, active))
    return params_b, last_loss


def _make_cohort_fn(model: Model, optimizer: Optimizer, prox_mu: float):
    key = (id(model), id(optimizer), prox_mu)
    if key in _batched_step_cache:
        return _batched_step_cache[key]

    one_client = make_client_step(model, optimizer, prox_mu)

    @jax.jit
    def run_cohort(params_b, opt_b, xs, ys, masks, active, global_params):
        """xs: (T, M, B, ...); active: (T, M) bool step mask."""
        return cohort_scan(one_client, params_b, opt_b, xs, ys, masks,
                           active, global_params)

    _batched_step_cache[key] = run_cohort
    return run_cohort


def _stack_streams(streams, batch_size: int, t_pad: int):
    """Pad a bucket's batch streams into (T, M, B, ...) arrays."""
    m = len(streams)
    bx0, by0, _ = streams[0][0]
    feat_shape = bx0.shape[1:]
    xs = np.zeros((t_pad, m, batch_size) + feat_shape, np.float32)
    ys = np.zeros((t_pad, m, batch_size), by0.dtype)
    masks = np.zeros((t_pad, m, batch_size), np.bool_)
    active = np.zeros((t_pad, m), np.bool_)
    for i, stream in enumerate(streams):
        for t, (bx, by, bm) in enumerate(stream):
            xs[t, i] = bx
            ys[t, i] = by
            masks[t, i] = bm
            active[t, i] = True
    return xs, ys, masks, active


def _pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


def materialize_streams(data, batch_size: int, passes: float,
                        rng: np.random.Generator):
    """Materialize every client's batch stream IN CLIENT ORDER — the rng
    contract shared by the sequential, batched, and sharded paths (batch
    permutations must consume the server rng identically).  Returns
    (streams, per-client step counts)."""
    streams = [list(client_batches(x, y, batch_size, passes, rng))
               for x, y in data]
    return streams, [len(s) for s in streams]


def bucket_by_steps(n_steps: Sequence[int]):
    """Size-bucket client indices by pow2-rounded step count to bound
    padding waste; 0-step clients are left out (they never train)."""
    buckets = {}
    for i, t in enumerate(n_steps):
        if t == 0:
            continue
        buckets.setdefault(_pow2(t), []).append(i)
    return buckets


def note_pack_metrics(t_pad: int, m_pad: int, n_lanes: int,
                      real_steps: int):
    """Pack-shape metrics for one bucket dispatch: lanes/steps actually
    used vs the padded compiled shape.  ``padding_waste`` is the fraction
    of the (m_pad, t_pad) step grid spent on padding — the price of
    bounding the compiled shape set, and the series the bucketing and
    coalescing heuristics should be judged against.  Shared by every
    cohort runner (this module's standalone path and the sweep runner's
    batched/sharded/event packs); callers gate on ``obs.enabled()``."""
    padded_steps = m_pad * t_pad
    obs.registry.inc("pack_dispatches")
    obs.registry.inc("pack_lanes_real", n_lanes)
    obs.registry.inc("pack_lanes_padded", m_pad)
    obs.registry.inc("pack_steps_real", real_steps)
    obs.registry.inc("pack_steps_padded", padded_steps)
    obs.registry.sample("pack_width", n_lanes, t_pad=t_pad, m_pad=m_pad)
    obs.registry.sample(
        "padding_waste",
        1.0 - real_steps / padded_steps if padded_steps else 0.0,
        t_pad=t_pad)
    obs.registry.observe("pack_width_lanes", n_lanes)


def batched_local_train(model: Model, global_params,
                        data: Sequence[Tuple[np.ndarray, np.ndarray]], *,
                        passes: float, batch_size: int, optimizer: Optimizer,
                        rng: np.random.Generator, prox_mu: float = 0.0,
                        client_ids: Optional[Sequence[int]] = None,
                        compression: Optional[str] = None
                        ) -> List[ClientUpdate]:
    """Train all clients in ``data`` from ``global_params`` concurrently.
    Returns one ClientUpdate per client (in input order), matching
    ``local_train`` run sequentially with the same rng.  ``compression``
    applies the upload quantize->dequantize round trip to every trained
    lane (federated/compression.py), as the sequential path does per
    client."""
    run_cohort = _make_cohort_fn(model, optimizer, prox_mu)
    streams, n_steps = materialize_streams(data, batch_size, passes, rng)
    assert max(n_steps) > 0, "cohort with zero local steps"

    buckets = bucket_by_steps(n_steps)

    params_out: List[Any] = [global_params] * len(data)  # 0-step clients
    loss_out = np.zeros(len(data), np.float64)
    for t_pad in sorted(buckets):
        idx = buckets[t_pad]
        xs, ys, masks, active = _stack_streams(
            [streams[i] for i in idx], batch_size, t_pad)
        m = len(idx)
        if obs.enabled():
            note_pack_metrics(t_pad, m, m,
                              sum(n_steps[i] for i in idx))
        global_b = jax.tree.map(
            lambda p: jnp.broadcast_to(p, (m,) + p.shape), global_params)
        opt_b = jax.vmap(optimizer.init)(global_b)
        params_b, last_loss = run_cohort(
            global_b, opt_b, jnp.asarray(xs), jnp.asarray(ys),
            jnp.asarray(masks), jnp.asarray(active), global_params)
        if compression not in (None, "none"):
            from repro.federated.compression import compress_delta_lanes
            params_b = compress_delta_lanes(global_b, params_b)
        last_loss = np.asarray(last_loss)
        for j, i in enumerate(idx):
            params_out[i] = jax.tree.map(lambda p, j=j: p[j], params_b)
            loss_out[i] = float(last_loss[j])

    updates = []
    for i, (x, y) in enumerate(data):
        cid = int(client_ids[i]) if client_ids is not None else -1
        updates.append(ClientUpdate(
            params=params_out[i], n_examples=len(y), n_steps=n_steps[i],
            last_loss=loss_out[i], client_id=cid))
    return updates
