"""ResNet-10/18/26/34 (BasicBlock) for the paper's measurement study.

GroupNorm is used instead of BatchNorm (standard in FL to avoid non-IID
batch-statistics leakage across clients — noted in DESIGN.md); everything
else follows He et al. CIFAR-style stem (3x3, no max-pool) since inputs are
32x32 spectrograms / images.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.paper_models import ResNetConfig


def _conv_init(key, shape, dtype):
    fan_in = shape[0] * shape[1] * shape[2]
    return (jax.random.normal(key, shape) * jnp.sqrt(2.0 / fan_in)).astype(dtype)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _groupnorm(x, scale, bias, groups=8, eps=1e-5):
    n, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.reshape(n, h, w, g, c // g).astype(jnp.float32)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(n, h, w, c) * scale + bias).astype(x.dtype)


def _init_block(key, c_in, c_out, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "conv1": _conv_init(k1, (3, 3, c_in, c_out), dtype),
        "gn1_s": jnp.ones((c_out,), dtype), "gn1_b": jnp.zeros((c_out,), dtype),
        "conv2": _conv_init(k2, (3, 3, c_out, c_out), dtype),
        "gn2_s": jnp.ones((c_out,), dtype), "gn2_b": jnp.zeros((c_out,), dtype),
    }
    if c_in != c_out:
        p["proj"] = _conv_init(k3, (1, 1, c_in, c_out), dtype)
    return p


def init_params(cfg: ResNetConfig, key, dtype=jnp.float32):
    widths = [cfg.width * (2 ** i) for i in range(4)]
    ks = jax.random.split(key, 2 + sum(cfg.stage_blocks))
    params = {
        "stem": _conv_init(ks[0], (3, 3, cfg.in_channels, widths[0]), dtype),
        "stem_gn_s": jnp.ones((widths[0],), dtype),
        "stem_gn_b": jnp.zeros((widths[0],), dtype),
        "stages": [],
    }
    idx = 1
    c_in = widths[0]
    for stage, n_blocks in enumerate(cfg.stage_blocks):
        c_out = widths[stage]
        blocks = []
        for b in range(n_blocks):
            blocks.append(_init_block(ks[idx], c_in, c_out, dtype))
            idx += 1
            c_in = c_out
        params["stages"].append(blocks)
    k_head = ks[idx]
    params["head_w"] = (jax.random.normal(k_head, (c_in, cfg.n_classes))
                        * jnp.sqrt(1.0 / c_in)).astype(dtype)
    params["head_b"] = jnp.zeros((cfg.n_classes,), dtype)
    return params


def _block_apply(p, x, stride):
    h = _conv(x, p["conv1"], stride)
    h = jax.nn.relu(_groupnorm(h, p["gn1_s"], p["gn1_b"]))
    h = _conv(h, p["conv2"])
    h = _groupnorm(h, p["gn2_s"], p["gn2_b"])
    if "proj" in p:
        x = _conv(x, p["proj"], stride)
    elif stride != 1:
        x = x[:, ::stride, ::stride]
    return jax.nn.relu(x + h)


def forward(params, cfg: ResNetConfig, images):
    """images: (B, H, W, C) -> logits (B, n_classes)."""
    x = _conv(images, params["stem"])
    x = jax.nn.relu(_groupnorm(x, params["stem_gn_s"], params["stem_gn_b"]))
    for stage, blocks in enumerate(params["stages"]):
        for b, p in enumerate(blocks):
            stride = 2 if (stage > 0 and b == 0) else 1
            x = _block_apply(p, x, stride)
    x = x.mean(axis=(1, 2))
    return x @ params["head_w"] + params["head_b"]


def flops_per_example(cfg: ResNetConfig) -> float:
    """Analytic forward FLOPs for one input (multiply-adds x2)."""
    hw = cfg.image_size ** 2
    widths = [cfg.width * (2 ** i) for i in range(4)]
    total = 2 * 9 * cfg.in_channels * widths[0] * hw
    c_in = widths[0]
    res = hw
    for stage, n_blocks in enumerate(cfg.stage_blocks):
        c_out = widths[stage]
        for b in range(n_blocks):
            stride = 2 if (stage > 0 and b == 0) else 1
            res = res // (stride * stride)
            total += 2 * 9 * c_in * c_out * res
            total += 2 * 9 * c_out * c_out * res
            if c_in != c_out:
                total += 2 * c_in * c_out * res
            c_in = c_out
    total += 2 * c_in * cfg.n_classes
    return float(total)
