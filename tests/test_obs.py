"""Observability subsystem: zero-cost-when-disabled, bit-parity-neutral
when enabled (sync + async sweeps), a schema-valid Perfetto trace with one
track per trial lane on both clocks, the perf shim's back-compat surface,
and the trace_report CLI round-trip."""

import importlib.util
import json
import os
import time

import numpy as np
import pytest

from repro import obs, perf
from repro.experiments.grid import TrialSpec
from repro.experiments.runner import run_vectorized
from repro.obs.export import (VIRTUAL_PID, WALL_PID, chrome_trace,
                              load_schema, read_metrics_jsonl,
                              trace_paths_for, validate_chrome_trace,
                              write_chrome_trace, write_metrics_jsonl)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_SPAN, Tracer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with tracing off and empty buffers, so
    span/metric state cannot leak across tests (or into other files)."""
    obs.disable()
    obs.tracer.clear()
    obs.registry.reset()
    yield
    obs.disable()
    obs.tracer.clear()
    obs.registry.reset()


def tiny_spec(**kw):
    base = dict(dataset="emnist", aggregator="fedavg", seed=0,
                tuner="fedtune", m0=3, e0=1.0, rounds=3,
                target_accuracy=0.99, batch_size=5, eval_points=128)
    base.update(kw)
    return TrialSpec(**base)


def assert_bitexact(plain, traced):
    for p, t in zip(plain, traced):
        assert p.history_acc == t.history_acc
        assert p.history_m == t.history_m
        assert p.history_e == t.history_e
        assert p.final_accuracy == t.final_accuracy
        assert (p.final_m, p.final_e) == (t.final_m, t.final_e)
        np.testing.assert_allclose(p.cost, t.cost, rtol=0, atol=0)
        assert p.reached == t.reached and p.rounds == t.rounds
        assert p.dispatch_log == t.dispatch_log
        assert p.staleness_log == t.staleness_log


# ---------------------------------------------------------------------------
# registry + perf shim
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_histograms_series():
    reg = MetricsRegistry()
    reg.inc("a")
    reg.inc("a", 2.5)
    reg.gauge("g", 7)
    for v in range(10):
        reg.observe("h", v)
    reg.sample("s", 4, step=0, engine="sync")
    assert reg.counter_value("a") == 3.5
    assert reg.gauges()["g"] == 7.0
    h = reg.histogram_summary("h")
    assert h["count"] == 10 and h["min"] == 0 and h["max"] == 9
    assert h["mean"] == pytest.approx(4.5)
    assert reg.series("s") == [{"name": "s", "value": 4.0, "step": 0,
                               "engine": "sync"}]
    snap = reg.snapshot()
    assert snap["counters"]["a"] == 3.5 and snap["n_series"] == 1
    reg.reset()
    assert reg.counter_value("a") == 0.0 and reg.series() == []


def test_perf_shim_back_compat():
    """The pre-obs perf surface must keep working unchanged — the
    benchmark suite and the federated layers call it every round."""
    perf.reset()
    with perf.timed("train"):
        time.sleep(0.002)
    perf.add("train", 1.0)
    perf.add("eval", 0.25)
    assert perf.seconds("train") > 1.0
    assert perf.calls("train") == 2
    assert perf.calls("missing") == 0 and perf.seconds("missing") == 0.0
    snap = perf.snapshot()
    assert set(snap) == {"train", "eval"} and snap["eval"] == 0.25
    assert perf.calls_snapshot() == {"train": 2, "eval": 1}
    perf.reset()
    assert perf.snapshot() == {}


def test_perf_and_obs_share_one_registry():
    with perf.timed("train"):
        pass
    assert obs.registry.phase_call_count("train") == 1
    perf.reset()     # resets the WHOLE registry, metrics included
    obs.registry.inc("x")
    perf.reset()
    assert obs.registry.counter_value("x") == 0.0


# ---------------------------------------------------------------------------
# zero-cost-when-disabled
# ---------------------------------------------------------------------------

def test_disabled_tracer_hands_out_the_shared_null_span():
    assert not obs.enabled()
    s = obs.span("anything", phase="train", trial="t", n=3)
    assert s is NULL_SPAN
    with s as inner:
        inner.set(more=1)      # attribute sink, no storage
    obs.record("x", virtual=(0, 1))
    obs.counter("c", 1)
    assert obs.tracer.spans == [] and obs.tracer.counters == []


def test_disabled_fast_path_is_cheap():
    """A sweep makes a handful of span calls per round; 100k disabled
    calls finishing in well under a second means the per-round cost is
    unmeasurable (generous bound to stay robust on loaded CI workers)."""
    t0 = time.perf_counter()
    for _ in range(100_000):
        with obs.span("s"):
            pass
    assert time.perf_counter() - t0 < 1.0


def test_enabled_spans_capture_dual_clocks():
    class FakeClock:
        now = 2.0
    obs.enable()
    clk = FakeClock()
    with obs.span("round", phase="round", trial="t0", round_idx=3,
                  clock=clk, n=5):
        clk.now = 6.0
    obs.disable()
    (sp,) = obs.tracer.spans
    assert sp.name == "round" and sp.trial == "t0" and sp.round_idx == 3
    assert sp.virtual_t0 == 2.0 and sp.virtual_t1 == 6.0
    assert sp.virtual_dur == 4.0 and sp.wall_dur >= 0.0
    assert sp.attrs == {"n": 5}


def test_enable_resets_previous_buffers():
    obs.enable()
    obs.record("a", virtual=(0, 1))
    obs.enable()               # default reset=True: fresh capture window
    assert obs.tracer.spans == []
    obs.enable(reset=False)
    obs.record("b", virtual=(0, 1))
    assert len(obs.tracer.spans) == 1


# ---------------------------------------------------------------------------
# bit-parity: traced == untraced, pinned for sync and async sweeps
# ---------------------------------------------------------------------------

def test_traced_sync_sweep_is_bit_exact():
    specs = [tiny_spec(seed=s, rounds=2) for s in range(4)]
    plain = run_vectorized(specs)
    obs.enable()
    traced = run_vectorized(specs)
    obs.disable()
    assert_bitexact(plain, traced)
    assert len(obs.tracer.spans) > 0     # tracing actually happened
    assert obs.registry.counter_value("pack_dispatches") > 0


def test_traced_async_sweep_is_bit_exact_and_fills_staleness():
    specs = [tiny_spec(seed=s, mode="async", m0=2, rounds=3)
             for s in range(4)]
    plain = run_vectorized(specs)
    obs.enable()
    traced = run_vectorized(specs)
    obs.disable()
    assert_bitexact(plain, traced)
    stale = obs.registry.histogram_summary("staleness")
    assert stale["count"] == sum(len(t.staleness_log) for t in traced)
    assert obs.registry.counter_value("event_dispatched") > 0
    assert obs.registry.series("lanes_live")


# ---------------------------------------------------------------------------
# chrome trace export + checked-in schema
# ---------------------------------------------------------------------------

def _traced_sweep_trace(tmp_path):
    specs = [tiny_spec(seed=s, rounds=2) for s in range(2)]
    obs.enable()
    run_vectorized(specs)
    obs.disable()
    path = str(tmp_path / "sweep.trace.json")
    trace = write_chrome_trace(path)
    return specs, path, trace


def test_exported_trace_validates_and_has_per_lane_tracks(tmp_path):
    specs, path, trace = _traced_sweep_trace(tmp_path)
    assert validate_chrome_trace(trace) == []
    with open(path) as f:
        assert validate_chrome_trace(json.load(f)) == []
    # one named track per trial lane, on BOTH clock processes
    names = {(ev["pid"], ev["args"]["name"])
             for ev in trace["traceEvents"]
             if ev["ph"] == "M" and ev["name"] == "thread_name"}
    for spec in specs:
        for pid in (WALL_PID, VIRTUAL_PID):
            assert any(p == pid and spec.key() in n for p, n in names), \
                (pid, spec.key())
    # the virtual-clock process carries per-round spans for each lane
    virt = [ev for ev in trace["traceEvents"]
            if ev["ph"] == "X" and ev["pid"] == VIRTUAL_PID]
    assert {ev["name"] for ev in virt} >= {"round"}
    # and the counter track samples simulated time on the wall process
    assert any(ev["ph"] == "C" and ev["name"] == "t_sim"
               for ev in trace["traceEvents"])


def test_schema_validator_catches_breakage():
    schema = load_schema()
    ok = {"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 0, "name": "a", "ts": 0.0,
         "dur": 1.0, "args": {}},
        {"ph": "X", "pid": 1, "tid": 0, "name": "b", "ts": 2.0,
         "dur": 1.0, "args": {}},
    ]}
    assert validate_chrome_trace(ok, schema) == []
    assert validate_chrome_trace({}, schema)                  # no traceEvents
    missing_pid = {"traceEvents": [
        {"ph": "X", "tid": 0, "name": "a", "ts": 0.0, "dur": 1.0,
         "args": {}}]}
    assert any("missing" in e for e in
               validate_chrome_trace(missing_pid, schema))
    unknown_ph = {"traceEvents": [
        {"ph": "Z", "pid": 1, "tid": 0, "name": "a", "args": {}}]}
    assert any("ph" in e for e in validate_chrome_trace(unknown_ph, schema))
    backwards = {"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 0, "name": "a", "ts": 5.0,
         "dur": 1.0, "args": {}},
        {"ph": "X", "pid": 1, "tid": 0, "name": "b", "ts": 1.0,
         "dur": 1.0, "args": {}}]}
    assert any("track" in e for e in
               validate_chrome_trace(backwards, schema))
    # monotonicity is PER track: interleaved tracks may each restart
    two_tracks = {"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 0, "name": "a", "ts": 5.0,
         "dur": 1.0, "args": {}},
        {"ph": "X", "pid": 1, "tid": 1, "name": "b", "ts": 1.0,
         "dur": 1.0, "args": {}}]}
    assert validate_chrome_trace(two_tracks, schema) == []
    negative = {"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 0, "name": "a", "ts": 0.0,
         "dur": -1.0, "args": {}}]}
    assert any("negative" in e for e in
               validate_chrome_trace(negative, schema))


def test_every_track_ts_is_monotonic_in_export(tmp_path):
    _specs, _path, trace = _traced_sweep_trace(tmp_path)
    last = {}
    for ev in trace["traceEvents"]:
        if ev["ph"] == "M":
            continue
        track = (ev["pid"], ev["tid"])
        assert ev["ts"] >= last.get(track, -1.0)
        last[track] = ev["ts"]


# ---------------------------------------------------------------------------
# metrics JSONL + path derivation
# ---------------------------------------------------------------------------

def test_metrics_jsonl_round_trip(tmp_path):
    obs.enable()
    obs.registry.sample("lanes_live", 4, step=0, engine="sync")
    obs.registry.inc("pack_steps_real", 30)
    obs.registry.inc("pack_steps_padded", 40)
    obs.registry.observe("staleness", 2)
    with perf.timed("train"):
        pass
    obs.disable()
    path = str(tmp_path / "m.jsonl")
    n = write_metrics_jsonl(path)
    rows = read_metrics_jsonl(path)
    assert len(rows) == n
    by_kind = {}
    for r in rows:
        by_kind.setdefault(r["kind"], []).append(r)
    assert {"kind": "sample", "name": "lanes_live", "value": 4.0,
            "step": 0, "engine": "sync"} in by_kind["sample"]
    counters = {r["name"]: r["value"] for r in by_kind["counter"]}
    assert counters["pack_steps_real"] == 30.0
    (h,) = by_kind["histogram"]
    assert h["name"] == "staleness" and h["count"] == 1
    (p,) = by_kind["phase"]
    assert p["name"] == "train" and p["calls"] == 1


def test_trace_paths_derive_from_the_store():
    assert trace_paths_for("runs/sweep.jsonl") == (
        "runs/sweep.trace.json", "runs/sweep.metrics.jsonl")
    assert trace_paths_for("runs/sweep.jsonl", "x/t.trace.json") == (
        "x/t.trace.json", "x/t.metrics.jsonl")
    assert trace_paths_for("out", "t.json") == ("t.json", "t.metrics.jsonl")


# ---------------------------------------------------------------------------
# trace_report CLI round-trip
# ---------------------------------------------------------------------------

def _load_trace_report():
    path = os.path.join(REPO, "tools", "trace_report.py")
    spec = importlib.util.spec_from_file_location("trace_report", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_trace_report_round_trips_a_traced_sweep(tmp_path, capsys):
    specs = [tiny_spec(seed=s, rounds=2) for s in range(2)]
    obs.enable()
    run_vectorized(specs)
    obs.disable()
    trace_path, metrics_path = trace_paths_for(str(tmp_path / "s.jsonl"))
    write_chrome_trace(trace_path)
    write_metrics_jsonl(metrics_path)

    tr = _load_trace_report()
    rep = tr.report(trace_path, metrics_path)
    assert rep["valid"] and not rep["errors"]
    assert len(rep["lanes"]) == len(specs)
    for lane in rep["lanes"]:
        assert 0.0 < lane["occupancy"] <= 1.0
        assert lane["t_sim_s"] > 0
    assert rep["phases"]["train"]["calls"] > 0
    met = rep["metrics"]
    assert met["mean_lanes_live"] == pytest.approx(2.0)
    assert 0.0 <= met["padding_waste"] < 1.0
    assert met["phase_calls"]["train"] > 0     # perf.calls surfaced

    assert tr.main([trace_path, "--metrics", metrics_path]) == 0
    out = capsys.readouterr().out
    assert "wall-clock phases" in out and "virtual-clock lanes" in out
    assert tr.main([trace_path, "--json"]) == 0
    assert json.loads(capsys.readouterr().out)["valid"]


def test_trace_report_rejects_an_invalid_trace(tmp_path, capsys):
    bad = str(tmp_path / "bad.trace.json")
    with open(bad, "w") as f:
        json.dump({"traceEvents": [
            {"ph": "X", "tid": 0, "name": "a", "ts": 0.0, "dur": 1.0,
             "args": {}}]}, f)
    tr = _load_trace_report()
    assert tr.main([bad]) == 2
    assert "SCHEMA VIOLATION" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# engine-level span taxonomy
# ---------------------------------------------------------------------------

def test_sync_sweep_emits_the_macro_and_round_span_taxonomy():
    # one seed, two preferences: the trials share a dataset (and test
    # set), so their per-aggregation evals stack into one dispatch
    specs = [tiny_spec(rounds=2, preference=p)
             for p in ((1.0, 0.0, 0.0, 0.0), (0.25, 0.25, 0.25, 0.25))]
    obs.enable()
    run_vectorized(specs)
    obs.disable()
    names = {sp.name for sp in obs.tracer.spans}
    assert {"PLAN", "PACK", "TRAIN", "APPLY", "EVAL",
            "plan_sync_round", "round", "eval_stacked"} <= names
    rounds = [sp for sp in obs.tracer.spans if sp.name == "round"]
    assert all(sp.virtual_dur is not None and sp.virtual_dur > 0
               for sp in rounds)
    assert {sp.trial for sp in rounds} == {s.key() for s in specs}


def test_event_sweep_emits_collect_pack_apply_and_inflight_spans():
    specs = [tiny_spec(seed=s, mode="async", m0=2, rounds=2)
             for s in range(2)]
    obs.enable()
    run_vectorized(specs)
    obs.disable()
    names = {sp.name for sp in obs.tracer.spans}
    assert {"COLLECT", "PACK", "APPLY", "EVAL", "plan_event", "apply_event",
            "finish_event_round", "inflight", "agg_window"} <= names
    infl = [sp for sp in obs.tracer.spans if sp.name == "inflight"]
    # in-flight windows are virtual-only: comp+trans long, zero wall width
    assert all(sp.virtual_dur > 0 and sp.wall_dur == 0.0 for sp in infl)
