"""Scan-over-layers execution (production train path).

Unrolling 24-40 transformer blocks makes XLA compile each block separately;
stacking the parameters of repeating layers and running ``lax.scan`` over
them compiles ONE cycle body — ~10x faster compiles and much smaller HLO,
which matters on the 256/512-chip dry-runs.  Heterogeneous block patterns
(RecurrentGemma's rglru/rglru/attn, Gemma-2's local/global, xLSTM's
mlstm/slstm) are handled by detecting the minimal repeating cycle: the scan
body applies one full cycle; layers beyond the last full cycle run unrolled.

``stack_params`` / ``unstack_params`` convert between the per-layer list
layout (simulator, checkpoints) and the stacked layout (distributed steps).
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm as lm_mod
from repro.models.common import rmsnorm, shard_bse


# ---------------------------------------------------------------------------
# cycle detection / (un)stacking
# ---------------------------------------------------------------------------

def find_cycle(cfg: ModelConfig) -> Tuple[int, int, int]:
    """Returns (cycle_len, n_full_cycles, n_rest_layers)."""
    specs = cfg.layers
    n = len(specs)
    for p in range(1, n + 1):
        n_full = n // p
        if n_full < 2:
            break
        if all(specs[i] == specs[i % p] for i in range(n)):
            return p, n_full, n - n_full * p
    return n, 1, 0


def _stack_list(layers: List[Any]):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def stack_params(params: Dict[str, Any], cfg: ModelConfig) -> Dict[str, Any]:
    p, n_full, n_rest = find_cycle(cfg)
    layers = params["layers"]
    out = {k: v for k, v in params.items() if k != "layers"}
    if n_full >= 2:
        out["stacked"] = tuple(
            _stack_list([layers[c * p + pos] for c in range(n_full)])
            for pos in range(p))
        out["rest"] = list(layers[n_full * p:])
    else:
        out["stacked"] = ()
        out["rest"] = list(layers)
    if cfg.is_encoder_decoder and len(params["encoder"]["layers"]) >= 2:
        enc = dict(params["encoder"])
        enc["stacked"] = (_stack_list(enc.pop("layers")),)
        out["encoder"] = enc
    return out


def unstack_params(params_st: Dict[str, Any], cfg: ModelConfig) -> Dict[str, Any]:
    p, n_full, _ = find_cycle(cfg)
    out = {k: v for k, v in params_st.items()
           if k not in ("stacked", "rest")}
    layers = []
    if params_st["stacked"]:
        per_pos = [
            [jax.tree.map(lambda x, c=c: x[c], st) for c in range(n_full)]
            for st in params_st["stacked"]]
        for c in range(n_full):
            for pos in range(p):
                layers.append(per_pos[pos][c])
    layers.extend(params_st["rest"])
    out["layers"] = layers
    if cfg.is_encoder_decoder and "stacked" in params_st.get("encoder", {}):
        enc = dict(params_st["encoder"])
        st = enc.pop("stacked")[0]
        n_enc = cfg.encoder.n_layers
        enc["layers"] = [jax.tree.map(lambda x, c=c: x[c], st)
                         for c in range(n_enc)]
        out["encoder"] = enc
    return out


def init_params_stacked(cfg: ModelConfig, key, dtype=jnp.float32):
    return stack_params(lm_mod.init_params(cfg, key, dtype), cfg)


# ---------------------------------------------------------------------------
# scanned forward / loss
# ---------------------------------------------------------------------------

def _apply_blocks(params_st, cfg: ModelConfig, x, positions, *,
                  enc_out=None, enc_pos=None, remat=True, use_kernel=True):
    p, n_full, _ = find_cycle(cfg)

    def cycle_body(x, layer_tuple):
        # barrier: stops XLA from hoisting the bf16->f32 convert of the
        # whole (n_cycles, B, S, d) residual-save stack out of the backward
        # loop (which would materialize it at 2x size).
        x = jax.lax.optimization_barrier(x)
        aux = jnp.zeros((), jnp.float32)
        for pos in range(p):
            x, a = lm_mod._block(layer_tuple[pos], cfg, cfg.layers[pos], x,
                                 positions, enc_out=enc_out, enc_pos=enc_pos,
                                 use_kernel=use_kernel)
            aux = aux + a.astype(jnp.float32)
        return x, aux

    body = jax.checkpoint(cycle_body) if remat else cycle_body
    aux_total = jnp.zeros((), jnp.float32)
    if params_st["stacked"]:
        def scan_body(x, lt):
            return body(x, lt)
        x, auxs = jax.lax.scan(scan_body, x, tuple(params_st["stacked"]))
        aux_total = aux_total + auxs.sum()
    for i, lp in enumerate(params_st["rest"]):
        spec = cfg.layers[n_full * p + i] if params_st["stacked"] \
            else cfg.layers[i]

        def blk(lp_, x_, spec=spec):
            return lm_mod._block(lp_, cfg, spec, x_, positions,
                                 enc_out=enc_out, enc_pos=enc_pos,
                                 use_kernel=use_kernel)

        if remat:
            blk = jax.checkpoint(blk)
        x, a = blk(lp, x)
        aux_total = aux_total + a.astype(jnp.float32)
    return x, aux_total


def _encode_scanned(params_st, cfg: ModelConfig, frames, *, remat=True,
                    use_kernel=True):
    from repro.configs.base import LayerSpec
    from repro.models import attention as attn_mod
    from repro.models import ffn as ffn_mod

    enc = params_st["encoder"]
    x = jnp.einsum("btf,fd->btd", frames, params_st["frontend_proj"])
    pos = jnp.arange(frames.shape[1])
    enc_spec = LayerSpec()

    def enc_body(x, lp):
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        x = x + attn_mod.attention(lp["mixer"], cfg, enc_spec, h, pos,
                                   causal=False, use_kernel=use_kernel)
        h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = x + ffn_mod.mlp(lp["ffn"], h2, cfg.act)
        return x, None

    body = jax.checkpoint(enc_body) if remat else enc_body
    x, _ = jax.lax.scan(lambda x, lp: body(x, lp), x, enc["stacked"][0])
    return rmsnorm(x, enc["final_norm"], cfg.norm_eps), pos


# ---------------------------------------------------------------------------
# scanned decode
# ---------------------------------------------------------------------------

def stack_cache(cache: Dict[str, Any], cfg: ModelConfig) -> Dict[str, Any]:
    """Convert a per-layer cache list into the scan-stacked layout."""
    p, n_full, _ = find_cycle(cfg)
    layers = cache["layers"]
    out = {k: v for k, v in cache.items() if k != "layers"}
    if n_full >= 2:
        out["stacked"] = tuple(
            _stack_list([layers[c * p + pos] for c in range(n_full)])
            for pos in range(p))
        out["rest"] = list(layers[n_full * p:])
    else:
        out["stacked"] = ()
        out["rest"] = list(layers)
    return out


def init_cache_stacked(cfg: ModelConfig, batch: int, max_len: int, **kw):
    return stack_cache(lm_mod.init_cache(cfg, batch, max_len, **kw), cfg)


def _decode_mixer(lp, cfg, spec, h, pos, st):
    from repro.configs.base import (MIX_ATTN, MIX_MLSTM, MIX_RGLRU)
    from repro.models import attention as attn_mod
    from repro.models import recurrent as rec_mod
    from repro.models import xlstm as xlstm_mod

    if spec.mixer == MIX_ATTN:
        return attn_mod.decode_attention(lp["mixer"], cfg, spec, h, pos, st)
    if spec.mixer == MIX_RGLRU:
        return rec_mod.rglru_decode_step(lp["mixer"], h, st)
    if spec.mixer == MIX_MLSTM:
        return xlstm_mod.mlstm_decode_step(lp["mixer"], h, st, cfg)
    return xlstm_mod.slstm_decode_step(lp["mixer"], h, st, cfg)


def _decode_block(lp, cfg, spec, x, pos, st, enc_out, enc_pos):
    from repro.configs.base import FFN_DENSE, FFN_NONE
    from repro.models import attention as attn_mod
    from repro.models import ffn as ffn_mod

    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    mix, st = _decode_mixer(lp, cfg, spec, h, pos, st)
    x = x + mix
    if enc_out is not None:
        hc = rmsnorm(x, lp["ln_cross"], cfg.norm_eps)
        pos_q = jnp.asarray(pos, jnp.int32)[None]
        x = x + attn_mod.attention(lp["cross"], cfg, spec, hc, pos_q,
                                   causal=False, kv_input=enc_out,
                                   kv_positions=enc_pos, rope=False,
                                   use_kernel=False)
    if spec.ffn != FFN_NONE:
        h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if spec.ffn == FFN_DENSE:
            x = x + ffn_mod.mlp(lp["ffn"], h2, cfg.act)
        else:
            # drop-free MoE on the serving path (see lm.decode_step)
            out, _ = ffn_mod.moe_ffn_dense(lp["ffn"], h2, cfg.moe, cfg.act)
            x = x + out
    return x, st


def prefill(params_st, cfg: ModelConfig, tokens, cache_st, *, frontend=None,
            use_kernel=True):
    """Scan-over-layers prompt pass (bounds liveness to one cycle)."""
    p, n_full, _ = find_cycle(cfg)
    enc_out = enc_pos = None
    if cfg.is_encoder_decoder:
        enc_out, enc_pos = _encode_scanned(params_st, cfg, frontend,
                                           remat=False, use_kernel=use_kernel)
        cache_st = dict(cache_st, enc_out=enc_out)
        x = lm_mod._embed_inputs(params_st, cfg, tokens, None)
    else:
        x = lm_mod._embed_inputs(params_st, cfg, tokens, frontend)
    positions = jnp.arange(x.shape[1])

    new_stacked = []
    if params_st["stacked"]:
        def body(x, inp):
            lts, sts = inp
            new_sts = []
            for i in range(p):
                x, st = lm_mod._prefill_block(
                    lts[i], cfg, cfg.layers[i], x, positions, sts[i],
                    enc_out=enc_out, enc_pos=enc_pos, use_kernel=use_kernel)
                new_sts.append(st)
            return x, tuple(new_sts)

        x, new_st = jax.lax.scan(
            body, x, (tuple(params_st["stacked"]),
                      tuple(cache_st["stacked"])))
        new_stacked = list(new_st)
    new_rest = []
    for i, (lp, st) in enumerate(zip(params_st["rest"], cache_st["rest"])):
        spec = cfg.layers[n_full * p + i] if params_st["stacked"] \
            else cfg.layers[i]
        x, st = lm_mod._prefill_block(lp, cfg, spec, x, positions, st,
                                      enc_out=enc_out, enc_pos=enc_pos,
                                      use_kernel=use_kernel)
        new_rest.append(st)
    logits = lm_mod._unembed(params_st, cfg, x[:, -1:])
    return logits[:, 0], dict(cache_st, stacked=tuple(new_stacked),
                              rest=new_rest)


def decode_step(params_st, cfg: ModelConfig, token, pos, cache_st):
    """Scan-over-layers decode: one token. Mirrors lm.decode_step."""
    p, n_full, _ = find_cycle(cfg)
    x = params_st["embed"][token][:, None] * jnp.sqrt(
        float(cfg.d_model)).astype(params_st["embed"].dtype)
    enc_out = cache_st.get("enc_out")
    enc_pos = (jnp.arange(enc_out.shape[1]) if enc_out is not None else None)

    new_stacked = []
    if params_st["stacked"]:
        def body(x, inp):
            lts, sts = inp
            new_sts = []
            for i in range(p):
                x, st = _decode_block(lts[i], cfg, cfg.layers[i], x, pos,
                                      sts[i], enc_out, enc_pos)
                new_sts.append(st)
            return x, tuple(new_sts)

        x, new_st = jax.lax.scan(
            body, x, (tuple(params_st["stacked"]), tuple(cache_st["stacked"])))
        new_stacked = list(new_st)
    new_rest = []
    for i, (lp, st) in enumerate(zip(params_st["rest"], cache_st["rest"])):
        spec = cfg.layers[n_full * p + i] if params_st["stacked"] \
            else cfg.layers[i]
        x, st = _decode_block(lp, cfg, spec, x, pos, st, enc_out, enc_pos)
        new_rest.append(st)
    logits = lm_mod._unembed(params_st, cfg, x)
    new_cache = dict(cache_st, stacked=tuple(new_stacked), rest=new_rest)
    return logits[:, 0], new_cache


def loss_fn(params_st, cfg: ModelConfig, batch, *, remat=True,
            use_kernel=True):
    """Same contract as lm.loss_fn, over stacked params."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    frontend = batch.get("frontend")
    enc_out = enc_pos = None
    if cfg.is_encoder_decoder:
        enc_out, enc_pos = _encode_scanned(params_st, cfg, frontend,
                                           remat=remat, use_kernel=use_kernel)
        x = lm_mod._embed_inputs(params_st, cfg, tokens, None)
    else:
        x = lm_mod._embed_inputs(params_st, cfg, tokens, frontend)
    positions = jnp.arange(x.shape[1])
    x, aux_total = _apply_blocks(params_st, cfg, x, positions,
                                 enc_out=enc_out, enc_pos=enc_pos,
                                 remat=remat, use_kernel=use_kernel)
    x = shard_bse(x)
    if x.shape[1] != labels.shape[1]:
        x = x[:, x.shape[1] - labels.shape[1]:]
    weight = batch.get("weight")
    mask = labels >= 0
    tok_w = mask.astype(jnp.float32)
    if weight is not None:
        tok_w = tok_w * weight[:, None].astype(jnp.float32)
    ce, acc = lm_mod.chunked_ce(params_st, cfg, x, labels, tok_w)
    loss = ce + aux_total
    return loss, {"ce": ce, "aux": aux_total, "acc": acc}
