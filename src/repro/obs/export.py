"""Exporters: Chrome trace-event JSON, metrics JSONL, schema validation.

Chrome trace layout (loadable at https://ui.perfetto.dev or
chrome://tracing):

* ``pid 1`` — **wall clock** process.  ``tid 0`` is the orchestrator
  (sweep macro-steps: PLAN/COLLECT/PACK/TRAIN/APPLY/EVAL); each trial
  lane gets its own tid in first-seen order.  ``ts``/``dur`` are host
  microseconds normalized to the earliest span.
* ``pid 2`` — **virtual clock** process.  One tid per trial lane; spans
  are simulated federated seconds (rounds, in-flight client windows,
  aggregation windows) scaled to microseconds so 1 virtual second reads
  as 1 ms on the timeline.
* A ``ph "C"`` counter track (e.g. ``t_sim``) rides on the wall process
  so simulated-time progress is visible against host time.

``validate_chrome_trace`` checks traces against the checked-in
``trace_schema.json`` (required fields per ph, numeric/nonnegative ts
and dur, monotonic ts per (pid, tid) track) without depending on the
``jsonschema`` package.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.obs.metrics import MetricsRegistry, registry
from repro.obs.trace import Span, tracer

WALL_PID = 1
VIRTUAL_PID = 2
ORCHESTRATOR_TID = 0
SCHEMA_PATH = os.path.join(os.path.dirname(__file__), "trace_schema.json")

# Virtual seconds -> trace microseconds.  1e3 makes one simulated second
# read as one millisecond in Perfetto, keeping smoke sweeps (t_sim ~1e2)
# and paper-scale runs (t_sim ~1e5) both navigable.
VIRTUAL_US_PER_S = 1e3


def load_schema(path: Optional[str] = None) -> Dict[str, Any]:
    with open(path or SCHEMA_PATH, encoding="utf-8") as f:
        return json.load(f)


def _span_args(sp: Span) -> Dict[str, Any]:
    args: Dict[str, Any] = {}
    if sp.phase is not None:
        args["phase"] = sp.phase
    if sp.trial is not None:
        args["trial"] = sp.trial
    if sp.lane is not None:
        args["lane"] = sp.lane
    if sp.round_idx is not None:
        args["round"] = sp.round_idx
    for k, v in sp.attrs.items():
        args[k] = v
    return args


def chrome_trace(spans: Optional[Sequence[Span]] = None,
                 counters: Optional[Iterable[Tuple[str, float, float]]] = None,
                 ) -> Dict[str, Any]:
    """Build the trace object; defaults to the global tracer's buffers."""
    if spans is None:
        spans = tracer.spans
    if counters is None:
        counters = tracer.counters

    trial_tid: Dict[str, int] = {}

    def tid_for(trial: Optional[str]) -> int:
        if trial is None:
            return ORCHESTRATOR_TID
        if trial not in trial_tid:
            trial_tid[trial] = len(trial_tid) + 1
        return trial_tid[trial]

    wall_origins = [sp.wall_t0 for sp in spans]
    wall_origins += [t for (_n, t, _v) in counters]
    t0 = min(wall_origins) if wall_origins else 0.0

    events: List[Dict[str, Any]] = []
    for sp in spans:
        tid = tid_for(sp.trial)
        args = _span_args(sp)
        # host-only spans always get a wall event; dual-clock spans get
        # both; retroactive virtual-only spans (wall_dur == 0 with a
        # virtual extent) skip the wall track to avoid zero-width noise
        if sp.virtual_t0 is None or sp.wall_dur > 0.0:
            events.append({
                "ph": "X", "pid": WALL_PID, "tid": tid, "name": sp.name,
                "cat": sp.phase or "span",
                "ts": (sp.wall_t0 - t0) * 1e6,
                "dur": max(sp.wall_dur, 0.0) * 1e6,
                "args": args,
            })
        if sp.virtual_t0 is not None and sp.virtual_t1 is not None:
            events.append({
                "ph": "X", "pid": VIRTUAL_PID, "tid": tid, "name": sp.name,
                "cat": sp.phase or "span",
                "ts": sp.virtual_t0 * VIRTUAL_US_PER_S,
                "dur": max(sp.virtual_t1 - sp.virtual_t0, 0.0)
                       * VIRTUAL_US_PER_S,
                "args": args,
            })
    for name, wall_t, value in counters:
        events.append({
            "ph": "C", "pid": WALL_PID, "tid": ORCHESTRATOR_TID,
            "name": name, "ts": (wall_t - t0) * 1e6,
            "args": {"value": value},
        })

    # a single global sort by ts makes every (pid, tid) track monotonic,
    # which the checked-in schema requires
    events.sort(key=lambda e: e["ts"])

    metadata: List[Dict[str, Any]] = [
        {"ph": "M", "pid": WALL_PID, "tid": ORCHESTRATOR_TID,
         "name": "process_name", "args": {"name": "wall clock (host)"}},
        {"ph": "M", "pid": VIRTUAL_PID, "tid": ORCHESTRATOR_TID,
         "name": "process_name", "args": {"name": "virtual clock (simulated)"}},
        {"ph": "M", "pid": WALL_PID, "tid": ORCHESTRATOR_TID,
         "name": "thread_name", "args": {"name": "orchestrator"}},
        {"ph": "M", "pid": VIRTUAL_PID, "tid": ORCHESTRATOR_TID,
         "name": "thread_name", "args": {"name": "orchestrator"}},
    ]
    for trial, tid in sorted(trial_tid.items(), key=lambda kv: kv[1]):
        for pid in (WALL_PID, VIRTUAL_PID):
            metadata.append({"ph": "M", "pid": pid, "tid": tid,
                             "name": "thread_name",
                             "args": {"name": f"lane {tid - 1}: {trial}"}})

    return {"traceEvents": metadata + events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str,
                       spans: Optional[Sequence[Span]] = None,
                       counters=None) -> Dict[str, Any]:
    trace = chrome_trace(spans, counters)
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(trace, f)
    return trace


def validate_chrome_trace(trace: Dict[str, Any],
                          schema: Optional[Dict[str, Any]] = None,
                          ) -> List[str]:
    """Return a list of violations (empty == valid)."""
    if schema is None:
        schema = load_schema()
    errors: List[str] = []
    for key in schema.get("top_level_required", []):
        if key not in trace:
            errors.append(f"missing top-level key {key!r}")
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        errors.append("traceEvents is not a list")
        return errors

    allowed_ph = set(schema.get("allowed_ph", []))
    base_required = schema.get("event_required", [])
    ph_required = schema.get("ph_required", {})
    numeric = set(schema.get("numeric_fields", []))
    nonneg = set(schema.get("nonnegative_fields", []))
    last_ts: Dict[Tuple[Any, Any], float] = {}

    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if allowed_ph and ph not in allowed_ph:
            errors.append(f"event {i}: ph {ph!r} not in {sorted(allowed_ph)}")
            continue
        required = list(base_required) + list(ph_required.get(ph, []))
        missing = [k for k in required if k not in ev]
        if missing:
            errors.append(f"event {i} (ph={ph}): missing {missing}")
            continue
        bad_num = [k for k in numeric if k in ev
                   and not isinstance(ev[k], (int, float))]
        if bad_num:
            errors.append(f"event {i}: non-numeric {bad_num}")
            continue
        neg = [k for k in nonneg if k in ev and ev[k] < 0]
        if neg:
            errors.append(f"event {i}: negative {neg}")
        if ph != "M" and "ts" in ev and schema.get("monotonic_ts_per_track"):
            track = (ev.get("pid"), ev.get("tid"))
            prev = last_ts.get(track)
            if prev is not None and ev["ts"] < prev:
                errors.append(f"event {i}: ts {ev['ts']} < previous "
                              f"{prev} on track {track}")
            last_ts[track] = ev["ts"]
    return errors


def trace_paths_for(out_path: str,
                    trace_path: Optional[str] = None) -> Tuple[str, str]:
    """(trace, metrics) paths for a run whose result store is ``out_path``.

    Default: drop the store's ``.jsonl`` suffix and add ``.trace.json`` /
    ``.metrics.jsonl`` — keeping the trace next to the sweep store.  An
    explicit ``trace_path`` overrides the trace location; its companion
    metrics file sits next to IT (swapping a ``.json`` suffix)."""
    if trace_path is not None:
        base = trace_path[:-5] if trace_path.endswith(".json") else trace_path
        if base.endswith(".trace"):
            base = base[: -len(".trace")]
        return trace_path, base + ".metrics.jsonl"
    base = out_path[:-6] if out_path.endswith(".jsonl") else out_path
    return base + ".trace.json", base + ".metrics.jsonl"


# ---- metrics JSONL ----------------------------------------------------


def metrics_rows(reg: Optional[MetricsRegistry] = None) -> List[Dict[str, Any]]:
    """Flatten a registry into self-describing JSONL rows."""
    if reg is None:
        reg = registry
    rows: List[Dict[str, Any]] = []
    for row in reg.series():
        rows.append({"kind": "sample", **row})
    for name, value in sorted(reg.counters().items()):
        rows.append({"kind": "counter", "name": name, "value": value})
    for name, value in sorted(reg.gauges().items()):
        rows.append({"kind": "gauge", "name": name, "value": value})
    for name, summary in reg.histograms().items():
        rows.append({"kind": "histogram", "name": name, **summary})
    for name, secs in sorted(reg.phase_snapshot().items()):
        rows.append({"kind": "phase", "name": name, "seconds": secs,
                     "calls": reg.phase_call_count(name)})
    return rows


def write_metrics_jsonl(path: str,
                        reg: Optional[MetricsRegistry] = None) -> int:
    rows = metrics_rows(reg)
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        for row in rows:
            f.write(json.dumps(row) + "\n")
    return len(rows)


def read_metrics_jsonl(path: str) -> List[Dict[str, Any]]:
    rows = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows
