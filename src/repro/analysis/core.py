"""Analyzer core: findings, the rule registry, noqa handling, driver.

Design notes
------------
* One parse per file; the same ``ast`` tree object is shared between the
  jit-scope pass ([`scopes`](scopes.py)) and every rule, so scope lookups
  key on node identity.
* Findings are value objects sorted by ``(path, line, col, rule,
  message)`` — the reporters emit them in exactly that order, which is
  what makes two runs byte-identical.
* Suppression is ``# noqa: REPRO0xx -- justification``.  A noqa without
  the ``-- justification`` tail does NOT suppress: the finding is kept
  and annotated, so an empty excuse can't sneak past the ratchet.  The
  comment must sit on the finding's line or within the flagged
  statement's header span (multi-line calls anchor on any of their own
  lines; compound statements anchor on the header only, never on body
  lines).
"""

from __future__ import annotations

import ast
import re
import tokenize
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

from .scopes import FuncNode, RepoScopes

_NOQA_RE = re.compile(
    r"#\s*noqa:\s*(?P<codes>REPRO\d{3}(?:\s*,\s*REPRO\d{3})*)"
    r"(?:\s*--\s*(?P<why>\S.*))?")


@dataclass(frozen=True)
class Finding:
    path: str        # posix path as reported (stable across runs)
    line: int
    col: int
    rule: str
    message: str

    def sort_key(self):
        return (self.path, self.line, self.col, self.rule, self.message)

    def to_dict(self) -> dict:
        return {"path": self.path, "line": self.line, "col": self.col,
                "rule": self.rule, "message": self.message}

    # Baseline identity deliberately omits line/col so a pure line-shift
    # upstream of an accepted finding doesn't count as "new".
    def baseline_key(self) -> Tuple[str, str, str]:
        return (self.path, self.rule, self.message)


@dataclass(frozen=True)
class Suppression:
    finding: Finding
    justification: str


class RuleError(Exception):
    """Internal analyzer failure (exit code 2 territory)."""


_REGISTRY: Dict[str, "Rule"] = {}


def register(cls):
    """Class decorator: instantiate and index a rule by its id."""
    rule = cls()
    if rule.id in _REGISTRY:
        raise RuleError(f"duplicate rule id {rule.id}")
    _REGISTRY[rule.id] = rule
    return cls


def all_rules() -> List["Rule"]:
    # import for side effect: each module registers its rule(s)
    from . import rules as _rules  # noqa: F401 (registration import)
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


class Rule:
    """Base rule: subclasses set ``id``/``name`` and walk ``ctx.tree``."""

    id = "REPRO000"
    name = "base"

    def check_file(self, ctx: "FileContext") -> None:
        raise NotImplementedError


class FileContext:
    """Everything a rule needs about one file, plus the finding sink."""

    def __init__(self, path: Path, rel: str, source: str, tree: ast.Module,
                 scopes: RepoScopes):
        self.path = path
        self.rel = rel              # reported path (posix)
        self.source = source
        self.tree = tree
        self.scopes = scopes
        self.raw: List[Tuple[Finding, Tuple[int, int]]] = []
        self._parents: Dict[int, ast.AST] = {}
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[id(child)] = parent

    # ---- tree navigation ----------------------------------------------

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(id(node))

    def ancestors(self, node: ast.AST):
        cur = self.parent(node)
        while cur is not None:
            yield cur
            cur = self.parent(cur)

    def enclosing_function(self, node: ast.AST):
        for anc in self.ancestors(node):
            if isinstance(anc, FuncNode):
                return anc
        return None

    def in_traced_scope(self, node: ast.AST) -> bool:
        fn = self.enclosing_function(node)
        return fn is not None and self.scopes.is_traced(fn)

    def enclosing_loop(self, node: ast.AST):
        """Nearest For/While above ``node`` without crossing a def."""
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.For, ast.While)):
                return anc
            if isinstance(anc, FuncNode):
                return None
        return None

    # ---- findings ------------------------------------------------------

    def add(self, node: ast.AST, rule: str, message: str):
        """Report ``rule`` at ``node``; noqa may sit on any line of the
        node's own span — capped at the header for compound statements so
        a comment deep inside a loop body can't silence the loop."""
        line = getattr(node, "lineno", 1)
        end = getattr(node, "end_lineno", line) or line
        body = getattr(node, "body", None)
        if isinstance(body, list) and body and hasattr(body[0], "lineno"):
            end = max(line, body[0].lineno - 1)
        col = getattr(node, "col_offset", 0)
        finding = Finding(path=self.rel, line=line, col=col, rule=rule,
                          message=message)
        self.raw.append((finding, (line, end)))


def parse_noqa(source: str) -> Dict[int, Dict[str, Optional[str]]]:
    """line -> {code: justification-or-None} from real COMMENT tokens
    (a '# noqa:' inside a string literal is not a suppression)."""
    out: Dict[int, Dict[str, Optional[str]]] = {}
    lines = source.splitlines(keepends=True)
    try:
        tokens = list(tokenize.generate_tokens(iter(lines).__next__))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return out
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _NOQA_RE.search(tok.string)
        if not m:
            continue
        why = m.group("why")
        codes = [c.strip() for c in m.group("codes").split(",")]
        entry = out.setdefault(tok.start[0], {})
        for code in codes:
            entry[code] = why.strip() if why else None
    return out


@dataclass
class FileResult:
    findings: List[Finding]
    suppressed: List[Suppression]


def apply_noqa(ctx: FileContext) -> FileResult:
    noqa = parse_noqa(ctx.source)
    findings: List[Finding] = []
    suppressed: List[Suppression] = []
    for finding, (start, end) in ctx.raw:
        verdict: Optional[Suppression] = None
        unjustified = False
        for line in range(start, end + 1):
            entry = noqa.get(line)
            if not entry or finding.rule not in entry:
                continue
            why = entry[finding.rule]
            if why:
                verdict = Suppression(finding, why)
                break
            unjustified = True
        if verdict is not None:
            suppressed.append(verdict)
        elif unjustified:
            findings.append(Finding(
                path=finding.path, line=finding.line, col=finding.col,
                rule=finding.rule,
                message=finding.message
                + " [noqa without '-- justification' — not suppressed]"))
        else:
            findings.append(finding)
    return FileResult(findings, suppressed)


# ---- driver ------------------------------------------------------------


@dataclass
class AnalysisResult:
    findings: List[Finding]
    suppressed: List[Suppression]
    errors: List[str]           # unparsable files etc -> exit 2
    n_files: int = 0


def iter_py_files(paths: Iterable[Path]) -> List[Path]:
    out = []
    for p in paths:
        if p.is_dir():
            out.extend(q for q in p.rglob("*.py") if q.is_file())
        elif p.suffix == ".py":
            out.append(p)
    return sorted(set(out))


def report_path(file: Path, root: Path) -> str:
    """Stable reported path: anchored at ``src/`` when the file lives in
    an src-layout tree (so cwd doesn't leak into reports), else relative
    to the scan root."""
    resolved = file.resolve()
    parts = resolved.parts
    for i in range(len(parts) - 1, 0, -1):
        if parts[i - 1] == "src" and parts[i] == "repro":
            return "/".join(parts[i - 1:])
    try:
        return resolved.relative_to(root.resolve()).as_posix()
    except ValueError:
        return file.as_posix()


def analyze_paths(paths: List[Path]) -> AnalysisResult:
    files = iter_py_files(paths)
    root = paths[0] if paths and paths[0].is_dir() else Path(".")
    scopes = RepoScopes()
    contexts: List[FileContext] = []
    errors: List[str] = []
    for file in files:
        rel = report_path(file, root)
        try:
            source = file.read_text(encoding="utf-8")
            tree = ast.parse(source, filename=str(file))
        except (SyntaxError, UnicodeDecodeError, OSError) as e:
            errors.append(f"{rel}: {type(e).__name__}: {e}")
            continue
        scopes.add_file(rel, tree)
        contexts.append(FileContext(file, rel, source, tree, scopes))
    scopes.resolve()

    findings: List[Finding] = []
    suppressed: List[Suppression] = []
    rules = all_rules()
    for ctx in contexts:
        for rule in rules:
            rule.check_file(ctx)
        res = apply_noqa(ctx)
        findings.extend(res.findings)
        suppressed.extend(res.suppressed)
    findings.sort(key=Finding.sort_key)
    suppressed.sort(key=lambda s: s.finding.sort_key())
    return AnalysisResult(findings=findings, suppressed=suppressed,
                          errors=sorted(errors), n_files=len(files))
