"""Paper Fig. 4 / Table 3: CompT, TransT, CompL, TransL when a different
number of participants M and number of training passes E are used."""

from __future__ import annotations

import numpy as np

from benchmarks.common import BenchSettings, emit, run_fl

M_GRID = (1, 5, 10)
E_GRID = (0.5, 1, 2, 4)


def main(settings: BenchSettings):
    rows = {}
    for m in M_GRID:
        for e in E_GRID:
            res = run_fl("emnist", settings, m=m, e=e)
            c = res.total_cost
            rows[(m, e)] = c
            emit(f"fig4/M={m}/E={e}", res.wall * 1e6,
                 f"rounds={res.rounds};acc={res.final_accuracy:.3f};"
                 f"CompT={c.comp_t:.3g};TransT={c.trans_t:.3g};"
                 f"CompL={c.comp_l:.3g};TransL={c.trans_l:.3g}")

    # Table 3 sign checks (monotone trends across the grid), reported as
    # fractions of adjacent pairs following the paper's directions.
    def trend(metric, axis):
        agree = total = 0
        for (m, e), c in rows.items():
            nxt = (m + 4, e) if axis == "m" else (m, e * 2)
            if nxt in rows:
                total += 1
                agree += (getattr(rows[nxt], metric)
                          > getattr(rows[(m, e)], metric))
        return agree / max(total, 1)

    emit("table3/CompL_up_with_M", 0.0, f"frac={trend('comp_l', 'm'):.2f}")
    emit("table3/TransL_up_with_M", 0.0, f"frac={trend('trans_l', 'm'):.2f}")
    emit("table3/CompT_up_with_E", 0.0, f"frac={trend('comp_t', 'e'):.2f}")
    emit("table3/CompL_up_with_E", 0.0, f"frac={trend('comp_l', 'e'):.2f}")
    return rows
