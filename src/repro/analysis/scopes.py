"""Jit-scope model: which functions execute under a JAX tracing transform.

The parity rules need to distinguish *traced* code (compiled once, FMA
contraction and tracer semantics apply) from *eager* code (host-side
``jnp`` dispatch, one kernel per op).  Decorators alone are not enough:
``compression._roundtrip_leaf`` carries no decorator but only ever runs
inside ``jax.vmap(...)`` / jitted callers, so REPRO001 must treat it as
traced while flagging the byte-identical pattern at module level.

A function counts as TRACED when any of:

1. it is decorated with a tracing wrapper (``jax.jit``, ``vmap``,
   ``pmap``, ``shard_map``, ``grad``, ``value_and_grad``, or a
   ``functools.partial`` of one) — ``obs.traced`` is a span decorator,
   not a transform, and deliberately does NOT count;
2. its name is passed as the first positional argument to a tracing
   wrapper call anywhere in the scanned tree (``jax.vmap(f)``,
   ``lax.scan(body, ...)``, ``shard_map(body, mesh, ...)``);
3. it is defined lexically inside a traced function; or
4. it has at least one known intra-repo call site and *all* of them are
   in traced functions (fixpoint over a simple-name call graph).

Everything else — including module-level statements — is eager.  The
call graph matches callees by simple name across the whole scanned tree,
which is deliberately coarse: a merge across same-named functions can
only make code *look* traced, i.e. relax REPRO001 (missed finding, safe
direction) rather than invent one.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

# Final attribute name of a call/decorator that puts its operand under a
# JAX trace.  ``scan`` covers ``lax.scan``; ``traced`` (repro.obs) is
# intentionally absent.
TRACE_WRAPPERS = {
    "jit", "vmap", "pmap", "shard_map", "scan", "grad", "value_and_grad",
}

FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef)


def final_name(node: ast.AST) -> Optional[str]:
    """`jax.lax.scan` -> 'scan', `jit` -> 'jit', else None."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def dotted_parts(node: ast.AST) -> List[str]:
    """`tr.eng.clock` -> ['tr', 'eng', 'clock'] (best effort)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return list(reversed(parts))


def _is_trace_wrapper(func: ast.AST) -> bool:
    """True when ``func`` names a tracing transform, unwrapping
    ``functools.partial(jax.jit, ...)``."""
    name = final_name(func)
    if name in TRACE_WRAPPERS:
        return True
    if isinstance(func, ast.Call) and final_name(func.func) == "partial":
        return bool(func.args) and final_name(func.args[0]) in TRACE_WRAPPERS
    return False


def _decorated_traced(node) -> bool:
    for dec in node.decorator_list:
        if _is_trace_wrapper(dec):
            return True
        # @partial(jax.jit, static_argnums=...) / @jit(...) as a call
        if isinstance(dec, ast.Call) and _is_trace_wrapper(dec):
            return True
        if isinstance(dec, ast.Call) and final_name(dec.func) in TRACE_WRAPPERS:
            return True
    return False


@dataclass
class FunctionInfo:
    """One function def, keyed by node identity across passes."""
    node: object
    module: str                      # repo-relative path of the file
    simple_name: str
    qualname: str
    parent: Optional["FunctionInfo"]
    decorated_traced: bool
    callees: Set[str] = field(default_factory=set)
    traced: bool = False


class RepoScopes:
    """Cross-file scope index; build once, query from every rule."""

    def __init__(self):
        self._by_node: Dict[int, FunctionInfo] = {}
        self._functions: List[FunctionInfo] = []
        self._wrapped_names: Set[str] = set()
        # simple name -> infos of every function with that name
        self._by_simple: Dict[str, List[FunctionInfo]] = {}

    # ---- pass 1: per-file collection ----------------------------------

    def add_file(self, module: str, tree: ast.Module):
        self._collect(module, tree, parent=None, prefix="")
        for call in ast.walk(tree):
            if (isinstance(call, ast.Call) and _is_trace_wrapper(call.func)
                    and call.args):
                first = call.args[0]
                name = final_name(first)
                if name is not None:
                    self._wrapped_names.add(name)

    def _collect(self, module: str, node: ast.AST,
                 parent: Optional[FunctionInfo], prefix: str):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, FuncNode):
                qual = f"{prefix}{child.name}"
                info = FunctionInfo(
                    node=child, module=module, simple_name=child.name,
                    qualname=qual, parent=parent,
                    decorated_traced=_decorated_traced(child))
                info.callees = self._own_calls(child)
                self._by_node[id(child)] = info
                self._functions.append(info)
                self._by_simple.setdefault(child.name, []).append(info)
                self._collect(module, child, info, prefix=qual + ".")
            elif isinstance(child, ast.ClassDef):
                self._collect(module, child, parent,
                              prefix=f"{prefix}{child.name}.")
            else:
                self._collect(module, child, parent, prefix=prefix)

    @staticmethod
    def _own_calls(func) -> Set[str]:
        """Simple names called directly in ``func``'s body, excluding
        nested function bodies (those get their own FunctionInfo)."""
        out: Set[str] = set()

        def walk(node):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, FuncNode):
                    continue
                if isinstance(child, ast.Call):
                    name = final_name(child.func)
                    if name:
                        out.add(name)
                walk(child)

        walk(func)
        return out

    # ---- pass 2: propagation ------------------------------------------

    def resolve(self):
        for info in self._functions:
            info.traced = (info.decorated_traced
                           or info.simple_name in self._wrapped_names)
        # lexical nesting under a traced def
        changed = True
        while changed:
            changed = False
            for info in self._functions:
                if not info.traced and info.parent and info.parent.traced:
                    info.traced = True
                    changed = True
            # all-call-sites-traced fixpoint
            for info in self._functions:
                if info.traced:
                    continue
                sites = [f for f in self._functions
                         if info.simple_name in f.callees]
                if sites and all(s.traced for s in sites):
                    info.traced = True
                    changed = True

    # ---- queries -------------------------------------------------------

    def info(self, func_node) -> Optional[FunctionInfo]:
        return self._by_node.get(id(func_node))

    def is_traced(self, func_node) -> bool:
        info = self._by_node.get(id(func_node))
        return bool(info and info.traced)
