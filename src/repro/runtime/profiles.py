"""Per-client device profiles for the heterogeneous runtime.

The paper (and ``core/costs.py``) assumes homogeneous clients, so CompT is
``C1 * E * max_k n_k``: every client computes at unit speed and transfers at
unit bandwidth.  A ``Fleet`` generalizes this: each client k gets a compute
``speed_k`` (relative FLOP/s), link bandwidths ``up_bw_k`` / ``down_bw_k``
(relative bytes/s), an availability probability (chance the client answers a
dispatch at all), and a dropout probability (chance it dies mid-round after
doing the work).  Virtual times are expressed in the same units as the
paper's overheads: with the reference rates at 1.0, a homogeneous unit fleet
reproduces eqs. (2)-(5) exactly — compute time IS ``C1 * E * n_k`` and
transfer time IS ``C2`` — so the legacy cost model is the special case.

Named profiles (``--het <name>``):
  homogeneous — unit fleet; the paper's setting.
  mild        — 3 device classes (1.5x/1x/0.5x) with 20% lognormal jitter.
  stragglers  — 85% unit devices, 15% 10x-slower tail (the FedBuff regime).
  mobile      — slow, narrow links, flaky availability (cross-device FL).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from typing import Callable, Dict, Optional, Tuple

import numpy as np


def hash01(*ints: int) -> float:
    """Stateless uniform draw in [0, 1) from a tuple of non-negative ints.

    Failure and churn decisions must be pure functions of the virtual
    clock: the sync planner, the async event loop, and the buffered event
    loop all ask "does client k fail at time t?" at DIFFERENT points in
    their sequential rng streams, so consuming the shared ``sys_rng``
    would desynchronize the engines (and break the failure-rate-0
    bit-parity contract the moment a rate goes nonzero).  A seeded-hash
    draw keyed on (seed, cid, time, attempt) gives every engine the same
    answer with zero stream consumption."""
    seq = np.random.SeedSequence(list(ints))  # noqa: REPRO004 -- entropy is the explicit int tuple, not process state
    return float(seq.generate_state(1)[0] / 2**32)


def _time_bits(t: float) -> int:
    """The virtual instant as hashable entropy (exact float64 bits, so two
    engines asking about the same instant agree to the last ulp)."""
    return int(np.float64(t).view(np.uint64))


# -- vectorized stateless draws (client-state virtualization) ---------------
#
# ``hash01`` pays a SeedSequence construction per draw (~10us) — fine for
# the engines' per-dispatch failure checks, hopeless for deriving a
# million-client cohort's device parameters.  ``_hash01_many`` is the bulk
# counterpart: a numpy-vectorized splitmix64 finalizer over client ids, so
# a VirtualFleet can gather any cohort's draws in one array pass.  It is a
# DIFFERENT hash domain from ``hash01`` (virtual-fleet device draws never
# have to match a materialized sample_fleet's rng sequence — determinism
# and K-independence per cid are the contract, pinned in test_runtime.py);
# the failure model keeps ``hash01`` itself so a VirtualFleet's ``fails``
# answers bit-match a materialized Fleet's.

_SM64 = dict(gamma=np.uint64(0x9E3779B97F4A7C15),
             m1=np.uint64(0xBF58476D1CE4E5B9),
             m2=np.uint64(0x94D049BB133111EB))


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer (Steele et al.), elementwise over uint64."""
    with np.errstate(over="ignore"):
        x = (x ^ (x >> np.uint64(30))) * _SM64["m1"]
        x = (x ^ (x >> np.uint64(27))) * _SM64["m2"]
    return x ^ (x >> np.uint64(31))


def _hash01_many(seed: int, salt: int, cids) -> np.ndarray:
    """Uniform [0, 1) per client id, vectorized: hash(seed, salt, cid) via
    splitmix64.  A given (seed, salt, cid) always maps to the same draw —
    independent of how many other clients exist or which cohort asks."""
    c = np.asarray(cids, dtype=np.uint64)
    with np.errstate(over="ignore"):
        stream = _mix64(np.uint64(seed & 0xFFFFFFFFFFFFFFFF)
                        + np.uint64(salt) * _SM64["gamma"])
        x = _mix64((c + stream) * _SM64["gamma"])
    # top 53 bits -> float64 mantissa: strictly < 1.0
    return (x >> np.uint64(11)).astype(np.float64) * (2.0 ** -53)


@dataclass(frozen=True)
class ChurnSchedule:
    """Deterministic fleet membership over virtual time (clients joining
    and leaving between rounds).

    Time is cut into epochs of ``period`` virtual seconds; within an
    epoch membership is frozen (churn happens BETWEEN rounds, not inside
    a client's dispatch->arrival window).  Epoch 0 is full — a trial's
    first round sees the whole fleet, so a schedule only perturbs later
    rounds.  In every later epoch each client is away with probability
    ``rate``, drawn by the stateless ``hash01`` on (seed, cid, epoch) —
    a pure function of virtual time, consuming no rng stream, so sync
    and event engines agree bit-for-bit.  ``min_active`` clients are
    guaranteed present (the lowest absent ids are forced back in) so a
    harsh schedule can never empty the fleet under the selector."""
    period: float
    rate: float
    seed: int = 0
    min_active: int = 1

    def __post_init__(self):
        assert self.period > 0, "churn period must be positive"
        assert 0.0 <= self.rate < 1.0, "churn rate must be in [0, 1)"

    def epoch_of(self, t: float) -> int:
        return int(t // self.period)

    def active_mask(self, n_clients: int, t: float) -> np.ndarray:
        return _churn_mask(self, n_clients, self.epoch_of(t))

    @classmethod
    def from_string(cls, text: str, *, seed: int = 0) -> "ChurnSchedule":
        """Parse the TrialSpec encoding ``"period:rate[:min_active]"``
        (e.g. ``"5000:0.3"``)."""
        parts = str(text).split(":")
        if len(parts) not in (2, 3):
            raise ValueError(
                f"bad churn spec {text!r}; expected 'period:rate' or "
                "'period:rate:min_active'")
        period, rate = float(parts[0]), float(parts[1])
        min_active = int(parts[2]) if len(parts) == 3 else 1
        if period <= 0 or not 0.0 <= rate < 1.0 or min_active < 1:
            raise ValueError(
                f"bad churn spec {text!r}; need period > 0, "
                "0 <= rate < 1, min_active >= 1")
        return cls(period=period, rate=rate, seed=seed,
                   min_active=min_active)


@lru_cache(maxsize=512)
def _churn_mask(schedule: ChurnSchedule, n_clients: int,
                epoch: int) -> np.ndarray:
    if epoch == 0:
        return np.ones(n_clients, dtype=bool)
    mask = np.array([hash01(schedule.seed, cid, epoch) >= schedule.rate
                     for cid in range(n_clients)])
    need = schedule.min_active - int(mask.sum())
    if need > 0:
        absent = np.flatnonzero(~mask)
        mask[absent[:need]] = True
    mask.setflags(write=False)     # cached: callers must not mutate
    return mask


@dataclass(frozen=True)
class DeviceClass:
    """One hardware tier inside a profile."""
    name: str
    speed: float          # relative compute rate (1.0 = reference device)
    bandwidth: float      # relative link rate (applied to up and down)
    weight: float         # sampling probability of this tier


@dataclass(frozen=True)
class HeterogeneityProfile:
    name: str
    classes: Tuple[DeviceClass, ...]
    speed_jitter: float = 0.0     # lognormal sigma multiplied onto speed
    availability: float = 1.0     # P(client answers a dispatch)
    dropout: float = 0.0          # P(client dies mid-round; work lost)
    failure: float = 0.0          # P(a dispatch hard-fails; update never
                                  # returns — triggers coordinator retry)

    def __post_init__(self):
        total = sum(c.weight for c in self.classes)
        assert abs(total - 1.0) < 1e-6, "class weights must sum to 1"


PROFILES: Dict[str, HeterogeneityProfile] = {
    "homogeneous": HeterogeneityProfile(
        name="homogeneous",
        classes=(DeviceClass("ref", 1.0, 1.0, 1.0),),
    ),
    "mild": HeterogeneityProfile(
        name="mild",
        classes=(DeviceClass("fast", 1.5, 1.5, 0.3),
                 DeviceClass("mid", 1.0, 1.0, 0.5),
                 DeviceClass("slow", 0.5, 0.6, 0.2)),
        speed_jitter=0.2, availability=0.95, dropout=0.02,
    ),
    "stragglers": HeterogeneityProfile(
        name="stragglers",
        classes=(DeviceClass("ref", 1.0, 1.0, 0.85),
                 DeviceClass("straggler", 0.1, 0.3, 0.15)),
        speed_jitter=0.1, availability=1.0, dropout=0.05,
    ),
    "mobile": HeterogeneityProfile(
        name="mobile",
        classes=(DeviceClass("hi", 0.8, 0.5, 0.25),
                 DeviceClass("mid", 0.5, 0.3, 0.5),
                 DeviceClass("lo", 0.2, 0.1, 0.25)),
        speed_jitter=0.3, availability=0.7, dropout=0.1,
    ),
}


@dataclass
class Fleet:
    """Sampled per-client device parameters (vectorized as arrays)."""
    profile: HeterogeneityProfile
    speed: np.ndarray         # (K,) relative FLOP/s
    up_bw: np.ndarray         # (K,) relative upload bytes/s
    down_bw: np.ndarray       # (K,) relative download bytes/s
    availability: np.ndarray  # (K,) P(answers dispatch)
    dropout: np.ndarray       # (K,) P(dies mid-round)
    ref_flops_per_s: float = 1.0   # unit rates keep times in cost units
    ref_bytes_per_s: float = 1.0
    # --- failure/churn model (PR 9: fault-tolerant elastic serving) -----
    failure: Optional[np.ndarray] = None     # (K,) per-dispatch hazard
    failure_seed: int = 0                    # hash01 domain separation
    failure_fn: Optional[Callable[[int, float, int], bool]] = None
    #   scripted override (tests/faultlib.py): fails(cid, t, attempt)
    churn: Optional[ChurnSchedule] = None    # membership over virtual time

    @property
    def n_clients(self) -> int:
        return len(self.speed)

    # -- failure model --------------------------------------------------
    def has_failures(self) -> bool:
        """Gate: every failure code path in the engines is skipped — and
        draws nothing — unless this is true, which is what keeps the
        fault-free path bit-identical to the pre-failure runtime."""
        if self.failure_fn is not None:
            return True
        return self.failure is not None and bool(np.any(self.failure > 0.0))

    def fails(self, cid: int, t: float, attempt: int = 0) -> bool:
        """Does attempt ``attempt`` dispatched to ``cid`` at virtual time
        ``t`` hard-fail?  Stateless (hash01 on the exact float64 time
        bits) so every engine consuming the same dispatch instant agrees
        without touching any sequential rng stream."""
        if self.failure_fn is not None:
            return bool(self.failure_fn(int(cid), float(t), int(attempt)))
        if self.failure is None:
            return False
        p = float(self.failure[cid])
        if p <= 0.0:
            return False
        return hash01(self.failure_seed, int(cid), _time_bits(t),
                      int(attempt)) < p

    # -- churn ----------------------------------------------------------
    def is_active(self, cid: int, t: float) -> bool:
        """Is ``cid`` a fleet member at virtual time ``t``?  Engines check
        this BEFORE any availability draw so inactive clients consume no
        rng (churn-free runs stay bit-identical)."""
        if self.churn is None:
            return True
        return bool(self.churn.active_mask(self.n_clients, t)[cid])

    def n_active(self, t: float) -> int:
        if self.churn is None:
            return self.n_clients
        return int(self.churn.active_mask(self.n_clients, t).sum())

    def comp_time(self, cid: int, flops: float) -> float:
        """Virtual seconds to run ``flops`` on client ``cid``."""
        return float(flops) / (self.ref_flops_per_s * float(self.speed[cid]))

    def trans_time(self, cid: int, down_units: float, up_units: float) -> float:
        """Virtual seconds to download + upload the given traffic."""
        return (float(down_units) / (self.ref_bytes_per_s
                                     * float(self.down_bw[cid]))
                + float(up_units) / (self.ref_bytes_per_s
                                     * float(self.up_bw[cid])))

    def est_round_time(self, cid: int, n_examples: float, passes: float,
                       flops_per_example: float, down_units: float,
                       up_units: float) -> float:
        """Deadline-aware selection signal: expected dispatch->arrival time
        (download + compute + upload — a fast CPU behind a narrow link is
        correctly ranked slow)."""
        return (self.comp_time(cid, flops_per_example * passes * n_examples)
                + self.trans_time(cid, down_units, up_units))

    def est_round_times(self, cids, n_examples, passes: float,
                        flops_per_example: float, down_units: float,
                        up_units: float) -> np.ndarray:
        """Bulk ``est_round_time`` over a cohort in one vectorized float64
        pass, elementwise bit-identical to the scalar method (same op
        sequence: (fpe * passes) * n, divide, add)."""
        cids = np.asarray(cids)
        n = np.asarray(n_examples, np.float64)
        flops = flops_per_example * passes * n
        comp = flops / (self.ref_flops_per_s * self.speed[cids])
        trans = (float(down_units) / (self.ref_bytes_per_s
                                      * self.down_bw[cids])
                 + float(up_units) / (self.ref_bytes_per_s
                                      * self.up_bw[cids]))
        return comp + trans

    def is_homogeneous(self) -> bool:
        return (np.all(self.speed == self.speed[0])
                and np.all(self.up_bw == self.up_bw[0])
                and np.all(self.down_bw == self.down_bw[0])
                and np.all(self.availability >= 1.0)
                and np.all(self.dropout <= 0.0))


def sample_fleet(profile: "HeterogeneityProfile | str", n_clients: int,
                 *, seed: int = 0) -> Fleet:
    """Draw per-client devices from a profile (deterministic in seed)."""
    if isinstance(profile, str):
        profile = get_profile(profile)
    rng = np.random.default_rng(seed)
    weights = np.array([c.weight for c in profile.classes])
    tier = rng.choice(len(profile.classes), size=n_clients, p=weights)
    speed = np.array([profile.classes[t].speed for t in tier])
    bw = np.array([profile.classes[t].bandwidth for t in tier])
    if profile.speed_jitter > 0:
        speed = speed * rng.lognormal(0.0, profile.speed_jitter, n_clients)
    return Fleet(
        profile=profile,
        speed=speed.astype(np.float64),
        up_bw=bw.astype(np.float64),
        down_bw=bw.astype(np.float64),
        availability=np.full(n_clients, profile.availability),
        dropout=np.full(n_clients, profile.dropout),
        failure=(np.full(n_clients, profile.failure)
                 if profile.failure > 0.0 else None),
        failure_seed=seed,
    )


class _PerClient:
    """A (K,)-array-shaped lazy view: ``view[cid]`` / ``view[cid_array]``
    computes the draw on demand (scalar index -> float, array index ->
    array), so a VirtualFleet exposes the exact attribute surface the
    engines index (``fleet.availability[cid]``…) with O(cohort) work and
    O(1) resident memory regardless of K."""

    def __init__(self, n: int, fn):
        self._n = int(n)
        self._fn = fn

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, idx):
        arr = np.asarray(idx)
        if arr.ndim == 0:
            return float(self._fn(arr.reshape(1))[0])
        return self._fn(arr)


@dataclass
class VirtualFleet:
    """A fleet whose per-client device parameters are DERIVED, not stored:
    speed/bandwidth tier and jitter for client ``cid`` come from the
    stateless ``_hash01_many`` draws on (seed, salt, cid), availability
    and dropout are the profile's constants, and the failure model is the
    same ``hash01`` draw a materialized ``Fleet`` uses — so no (K,) array
    ever exists and ``n_clients`` can be 10^6+ while the cost model only
    ever gathers the selected cohort.  ``materialize()`` builds the
    equivalent array-backed Fleet (same draws per cid; feasible only for
    small K), which is how tests pin virtual==materialized engine
    behavior.  Churn schedules need population-wide masks, so they stay a
    materialized-Fleet feature."""
    profile: HeterogeneityProfile
    n: int
    seed: int = 0
    ref_flops_per_s: float = 1.0
    ref_bytes_per_s: float = 1.0
    failure_rate: float = 0.0
    failure_seed: int = 0
    failure_fn: Optional[Callable[[int, float, int], bool]] = None
    churn: None = None            # see class docstring

    def __post_init__(self):
        self._cum = np.cumsum(
            [c.weight for c in self.profile.classes]).astype(np.float64)
        self._cls_speed = np.array(
            [c.speed for c in self.profile.classes], np.float64)
        self._cls_bw = np.array(
            [c.bandwidth for c in self.profile.classes], np.float64)
        self.speed = _PerClient(self.n, self.speeds)
        self.up_bw = _PerClient(self.n, self.bws)
        self.down_bw = _PerClient(self.n, self.bws)
        self.availability = _PerClient(
            self.n, lambda c: np.full(len(c), self.profile.availability))
        self.dropout = _PerClient(
            self.n, lambda c: np.full(len(c), self.profile.dropout))
        self.failure = (_PerClient(
            self.n, lambda c: np.full(len(c), self.failure_rate))
            if self.failure_rate > 0.0 else None)

    @property
    def n_clients(self) -> int:
        return self.n

    # -- bulk draws (cohort-sized gathers, the virtualization point) -----
    def _tiers(self, cids) -> np.ndarray:
        u = _hash01_many(self.seed, 0, cids)
        return np.minimum(np.searchsorted(self._cum, u, side="right"),
                          len(self._cum) - 1)

    def speeds(self, cids) -> np.ndarray:
        """(len(cids),) relative FLOP/s: tier speed x lognormal jitter."""
        s = self._cls_speed[self._tiers(cids)]
        if self.profile.speed_jitter > 0:
            u1 = _hash01_many(self.seed, 1, cids)
            u2 = _hash01_many(self.seed, 2, cids)
            z = (np.sqrt(-2.0 * np.log1p(-u1))
                 * np.cos(2.0 * np.pi * u2))          # Box-Muller
            s = s * np.exp(self.profile.speed_jitter * z)
        return s

    def bws(self, cids) -> np.ndarray:
        return self._cls_bw[self._tiers(cids)]

    # -- the Fleet method surface the engines/cost model consume ---------
    def has_failures(self) -> bool:
        return self.failure_fn is not None or self.failure_rate > 0.0

    def fails(self, cid: int, t: float, attempt: int = 0) -> bool:
        # exact Fleet.fails draw path: a virtual fleet and its
        # materialization answer identically at every (cid, t, attempt)
        if self.failure_fn is not None:
            return bool(self.failure_fn(int(cid), float(t), int(attempt)))
        if self.failure_rate <= 0.0:
            return False
        return hash01(self.failure_seed, int(cid), _time_bits(t),
                      int(attempt)) < self.failure_rate

    def is_active(self, cid: int, t: float) -> bool:
        return True

    def n_active(self, t: float) -> int:
        return self.n

    def comp_time(self, cid: int, flops: float) -> float:
        return float(flops) / (self.ref_flops_per_s * float(self.speed[cid]))

    def trans_time(self, cid: int, down_units: float,
                   up_units: float) -> float:
        return (float(down_units) / (self.ref_bytes_per_s
                                     * float(self.down_bw[cid]))
                + float(up_units) / (self.ref_bytes_per_s
                                     * float(self.up_bw[cid])))

    def est_round_time(self, cid: int, n_examples: float, passes: float,
                       flops_per_example: float, down_units: float,
                       up_units: float) -> float:
        return (self.comp_time(cid, flops_per_example * passes * n_examples)
                + self.trans_time(cid, down_units, up_units))

    def est_round_times(self, cids, n_examples, passes: float,
                        flops_per_example: float, down_units: float,
                        up_units: float) -> np.ndarray:
        """Bulk ``est_round_time`` over a cohort: one vectorized pass with
        the scalar method's exact op sequence (elementwise float64), so
        ``est_round_times(cids, ...)[i] == est_round_time(cids[i], ...)``
        to the bit."""
        cids = np.asarray(cids)
        n = np.asarray(n_examples, np.float64)
        flops = flops_per_example * passes * n
        comp = flops / (self.ref_flops_per_s * self.speeds(cids))
        bw = self.bws(cids)
        trans = (float(down_units) / (self.ref_bytes_per_s * bw)
                 + float(up_units) / (self.ref_bytes_per_s * bw))
        return comp + trans

    def is_homogeneous(self) -> bool:
        return (len(self.profile.classes) == 1
                and self.profile.speed_jitter == 0.0
                and self.profile.availability >= 1.0
                and self.profile.dropout <= 0.0)

    def materialize(self) -> Fleet:
        """The equivalent (K,)-array Fleet — same per-cid draws."""
        cids = np.arange(self.n)
        return Fleet(
            profile=self.profile,
            speed=self.speeds(cids),
            up_bw=self.bws(cids),
            down_bw=self.bws(cids),
            availability=np.full(self.n, self.profile.availability),
            dropout=np.full(self.n, self.profile.dropout),
            ref_flops_per_s=self.ref_flops_per_s,
            ref_bytes_per_s=self.ref_bytes_per_s,
            failure=(np.full(self.n, self.failure_rate)
                     if self.failure_rate > 0.0 else None),
            failure_seed=self.failure_seed,
            failure_fn=self.failure_fn)


def virtual_fleet(profile: "HeterogeneityProfile | str", n_clients: int,
                  *, seed: int = 0) -> VirtualFleet:
    """A VirtualFleet over a named or explicit profile (deterministic in
    seed; memory independent of ``n_clients``)."""
    if isinstance(profile, str):
        profile = get_profile(profile)
    return VirtualFleet(profile=profile, n=int(n_clients), seed=seed,
                        failure_rate=float(profile.failure),
                        failure_seed=seed)


def homogeneous_fleet(n_clients: int) -> Fleet:
    """The paper's setting: unit devices, always available, never dropping.
    The sync runtime over this fleet reproduces the legacy loop exactly."""
    return sample_fleet("homogeneous", n_clients, seed=0)


def get_profile(name: str) -> HeterogeneityProfile:
    try:
        return PROFILES[name]
    except KeyError:
        raise KeyError(f"unknown profile {name!r}; known: {sorted(PROFILES)}"
                       ) from None
