"""Per-client device profiles for the heterogeneous runtime.

The paper (and ``core/costs.py``) assumes homogeneous clients, so CompT is
``C1 * E * max_k n_k``: every client computes at unit speed and transfers at
unit bandwidth.  A ``Fleet`` generalizes this: each client k gets a compute
``speed_k`` (relative FLOP/s), link bandwidths ``up_bw_k`` / ``down_bw_k``
(relative bytes/s), an availability probability (chance the client answers a
dispatch at all), and a dropout probability (chance it dies mid-round after
doing the work).  Virtual times are expressed in the same units as the
paper's overheads: with the reference rates at 1.0, a homogeneous unit fleet
reproduces eqs. (2)-(5) exactly — compute time IS ``C1 * E * n_k`` and
transfer time IS ``C2`` — so the legacy cost model is the special case.

Named profiles (``--het <name>``):
  homogeneous — unit fleet; the paper's setting.
  mild        — 3 device classes (1.5x/1x/0.5x) with 20% lognormal jitter.
  stragglers  — 85% unit devices, 15% 10x-slower tail (the FedBuff regime).
  mobile      — slow, narrow links, flaky availability (cross-device FL).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DeviceClass:
    """One hardware tier inside a profile."""
    name: str
    speed: float          # relative compute rate (1.0 = reference device)
    bandwidth: float      # relative link rate (applied to up and down)
    weight: float         # sampling probability of this tier


@dataclass(frozen=True)
class HeterogeneityProfile:
    name: str
    classes: Tuple[DeviceClass, ...]
    speed_jitter: float = 0.0     # lognormal sigma multiplied onto speed
    availability: float = 1.0     # P(client answers a dispatch)
    dropout: float = 0.0          # P(client dies mid-round; work lost)

    def __post_init__(self):
        total = sum(c.weight for c in self.classes)
        assert abs(total - 1.0) < 1e-6, "class weights must sum to 1"


PROFILES: Dict[str, HeterogeneityProfile] = {
    "homogeneous": HeterogeneityProfile(
        name="homogeneous",
        classes=(DeviceClass("ref", 1.0, 1.0, 1.0),),
    ),
    "mild": HeterogeneityProfile(
        name="mild",
        classes=(DeviceClass("fast", 1.5, 1.5, 0.3),
                 DeviceClass("mid", 1.0, 1.0, 0.5),
                 DeviceClass("slow", 0.5, 0.6, 0.2)),
        speed_jitter=0.2, availability=0.95, dropout=0.02,
    ),
    "stragglers": HeterogeneityProfile(
        name="stragglers",
        classes=(DeviceClass("ref", 1.0, 1.0, 0.85),
                 DeviceClass("straggler", 0.1, 0.3, 0.15)),
        speed_jitter=0.1, availability=1.0, dropout=0.05,
    ),
    "mobile": HeterogeneityProfile(
        name="mobile",
        classes=(DeviceClass("hi", 0.8, 0.5, 0.25),
                 DeviceClass("mid", 0.5, 0.3, 0.5),
                 DeviceClass("lo", 0.2, 0.1, 0.25)),
        speed_jitter=0.3, availability=0.7, dropout=0.1,
    ),
}


@dataclass
class Fleet:
    """Sampled per-client device parameters (vectorized as arrays)."""
    profile: HeterogeneityProfile
    speed: np.ndarray         # (K,) relative FLOP/s
    up_bw: np.ndarray         # (K,) relative upload bytes/s
    down_bw: np.ndarray       # (K,) relative download bytes/s
    availability: np.ndarray  # (K,) P(answers dispatch)
    dropout: np.ndarray       # (K,) P(dies mid-round)
    ref_flops_per_s: float = 1.0   # unit rates keep times in cost units
    ref_bytes_per_s: float = 1.0

    @property
    def n_clients(self) -> int:
        return len(self.speed)

    def comp_time(self, cid: int, flops: float) -> float:
        """Virtual seconds to run ``flops`` on client ``cid``."""
        return float(flops) / (self.ref_flops_per_s * float(self.speed[cid]))

    def trans_time(self, cid: int, down_units: float, up_units: float) -> float:
        """Virtual seconds to download + upload the given traffic."""
        return (float(down_units) / (self.ref_bytes_per_s
                                     * float(self.down_bw[cid]))
                + float(up_units) / (self.ref_bytes_per_s
                                     * float(self.up_bw[cid])))

    def est_round_time(self, cid: int, n_examples: float, passes: float,
                       flops_per_example: float, down_units: float,
                       up_units: float) -> float:
        """Deadline-aware selection signal: expected dispatch->arrival time
        (download + compute + upload — a fast CPU behind a narrow link is
        correctly ranked slow)."""
        return (self.comp_time(cid, flops_per_example * passes * n_examples)
                + self.trans_time(cid, down_units, up_units))

    def is_homogeneous(self) -> bool:
        return (np.all(self.speed == self.speed[0])
                and np.all(self.up_bw == self.up_bw[0])
                and np.all(self.down_bw == self.down_bw[0])
                and np.all(self.availability >= 1.0)
                and np.all(self.dropout <= 0.0))


def sample_fleet(profile: "HeterogeneityProfile | str", n_clients: int,
                 *, seed: int = 0) -> Fleet:
    """Draw per-client devices from a profile (deterministic in seed)."""
    if isinstance(profile, str):
        profile = get_profile(profile)
    rng = np.random.default_rng(seed)
    weights = np.array([c.weight for c in profile.classes])
    tier = rng.choice(len(profile.classes), size=n_clients, p=weights)
    speed = np.array([profile.classes[t].speed for t in tier])
    bw = np.array([profile.classes[t].bandwidth for t in tier])
    if profile.speed_jitter > 0:
        speed = speed * rng.lognormal(0.0, profile.speed_jitter, n_clients)
    return Fleet(
        profile=profile,
        speed=speed.astype(np.float64),
        up_bw=bw.astype(np.float64),
        down_bw=bw.astype(np.float64),
        availability=np.full(n_clients, profile.availability),
        dropout=np.full(n_clients, profile.dropout),
    )


def homogeneous_fleet(n_clients: int) -> Fleet:
    """The paper's setting: unit devices, always available, never dropping.
    The sync runtime over this fleet reproduces the legacy loop exactly."""
    return sample_fleet("homogeneous", n_clients, seed=0)


def get_profile(name: str) -> HeterogeneityProfile:
    try:
        return PROFILES[name]
    except KeyError:
        raise KeyError(f"unknown profile {name!r}; known: {sorted(PROFILES)}"
                       ) from None
